"""Multi-tenant JobScheduler (repro/core/scheduler.py), single device.

Covers the cooperative time-slicing contract (exactness under
interleaving, policy ordering, per-tenant accounting), the scheduler
edge cases the issue list calls out — duplicate submits sharing one
compiled program, restore-after-kill mid-fleet, a raising job's feed
closing without stalling siblings (the PR-4 leak class) — plus
admission backpressure and the shared FeedBudget arbiter.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (AdmissionQueueFull, JobConfig, JobScheduler,
                        available_policies, resolve_policy, submit)
from repro.core.scheduler import DONE, FAILED
from repro.core.usecases import (Histogram, WordCount, histogram_oracle,
                                 wordcount_oracle)
from repro.data.feed import FeedBudget

VOCAB, N, TASK = 200, 8192, 512


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return rng.integers(0, VOCAB, size=N).astype(np.int32)


def wc_cfg(**kw):
    base = dict(usecase=WordCount(vocab=VOCAB), backend="1s",
                task_size=TASK, push_cap=256, n_procs=1, segment=2)
    base.update(kw)
    return JobConfig(**base)


@dataclasses.dataclass(frozen=True)
class Boom:
    """Raises at trace time — the poisoned tenant."""
    vocab: int

    @property
    def window(self):
        return self.vocab

    def map_emit(self, toks, task_id):
        raise ValueError("boom at trace time")


# ---------------------------------------------------------------------------
# policies / admission
# ---------------------------------------------------------------------------

def test_policy_registry():
    assert available_policies() == ["fair", "fifo", "priority"]
    assert resolve_policy("fifo").name == "fifo"
    with pytest.raises(ValueError, match="nope.*fair"):
        resolve_policy("nope")
    with pytest.raises(TypeError):
        resolve_policy(42)


def test_submit_requires_segmented(tokens):
    sched = JobScheduler()
    with pytest.raises(ValueError, match="segment"):
        sched.submit(wc_cfg(segment=0), tokens)


def test_one_mesh_many_tenants(tokens):
    sched = JobScheduler()
    sched.submit(wc_cfg(), tokens)
    with pytest.raises(ValueError, match="ONE mesh"):
        sched.submit(wc_cfg(n_procs=2), tokens)


def test_duplicate_name_rejected(tokens):
    sched = JobScheduler()
    sched.submit(wc_cfg(), tokens, name="a")
    with pytest.raises(ValueError, match="duplicate"):
        sched.submit(wc_cfg(), tokens, name="a")


def test_admission_backpressure(tokens):
    """The bounded admission queue pushes back on submit; draining the
    fleet reopens it."""
    sched = JobScheduler(max_pending=2)
    sched.submit(wc_cfg(), tokens)
    sched.submit(wc_cfg(), tokens)
    with pytest.raises(AdmissionQueueFull, match="max_pending=2"):
        sched.submit(wc_cfg(), tokens)
    sched.run_until_complete()
    sched.submit(wc_cfg(), tokens)          # open slots again
    res = sched.run_until_complete()
    assert len(res) == 3


# ---------------------------------------------------------------------------
# exactness + accounting under interleaving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["fifo", "fair", "priority"])
def test_interleaved_results_equal_solo(tokens, policy):
    """Time slicing must be invisible in every job's output, for every
    policy — the multi-tenant analogue of streamed == resident."""
    half = tokens[: N // 2]
    oracle_wc = wordcount_oracle(tokens, VOCAB)
    oracle_hist = histogram_oracle(half, VOCAB, 16)
    hist_cfg = JobConfig(usecase=Histogram(vocab=VOCAB, n_bins=16),
                         backend="1s", task_size=TASK, push_cap=256,
                         n_procs=1, segment=2)
    sched = JobScheduler(policy=policy)
    sched.submit(wc_cfg(), tokens, name="wc", tenant="a")
    sched.submit(hist_cfg, half, name="hist", tenant="b", priority=1)
    res = sched.run_until_complete()
    assert res["wc"].records == oracle_wc
    np.testing.assert_array_equal(res["hist"].output, oracle_hist)
    # handles cache their results — a second call is free and identical
    assert sched["wc"].handle.result() is res["wc"]


def test_tenant_accounting(tokens):
    sched = JobScheduler(policy="fair")
    sched.submit(wc_cfg(), tokens, name="a1", tenant="a")
    sched.submit(wc_cfg(), tokens, name="a2", tenant="a")
    sched.submit(wc_cfg(), tokens[: N // 2], name="b", tenant="b")
    sched.run_until_complete()
    n_tasks, half_tasks = N // TASK, N // 2 // TASK
    assert sched.tenants["a"].work == 2 * n_tasks      # repeats all 1
    assert sched.tenants["b"].work == half_tasks
    assert sched.tenants["a"].segments == 2 * ((n_tasks + 1) // 2)
    assert sched.tenants["a"].jobs_done == 2
    assert sched.tenants["b"].jobs_done == 1
    assert sched.tenants["a"].wall > 0
    st = sched.stats()
    assert {j["name"] for j in st["jobs"]} == {"a1", "a2", "b"}
    assert all(j["state"] == DONE for j in st["jobs"])
    for name in ("a1", "a2", "b"):
        assert sched.latency(name) > 0


def test_fair_share_finishes_small_tenant_first(tokens):
    """The headline behavior: under FIFO a small tenant queues behind
    the straggler; under fair share it finishes long before."""
    big, small = tokens, tokens[: 2 * TASK]

    def run(policy):
        sched = JobScheduler(policy=policy, slice_segments=1)
        sched.submit(wc_cfg(segment=1), big, name="big", tenant="batch")
        sched.submit(wc_cfg(segment=1), small, name="small",
                     tenant="interactive")
        sched.run_until_complete()
        return sched.latency("small"), sched.latency("big")

    fifo_small, fifo_big = run("fifo")
    fair_small, fair_big = run("fair")
    assert fifo_small > fifo_big        # FIFO: small waits out the giant
    assert fair_small < fair_big        # fair: small slips through
    assert fair_small < fifo_small


def test_priority_policy_orders_classes(tokens):
    sched = JobScheduler(policy="priority", slice_segments=1)
    sched.submit(wc_cfg(segment=1), tokens, name="low", priority=0)
    sched.submit(wc_cfg(segment=1), tokens, name="high", priority=5)
    sched.run_until_complete()
    assert sched.latency("high") < sched.latency("low")


def test_run_until_complete_is_resumable(tokens):
    sched = JobScheduler(policy="fifo")
    sched.submit(wc_cfg(), tokens, name="a")
    partial = sched.run_until_complete(max_slices=2)
    assert partial == {} and sched["a"].state == "live"
    res = sched.run_until_complete()
    assert res["a"].records == wordcount_oracle(tokens, VOCAB)


# ---------------------------------------------------------------------------
# duplicate submits share ONE compiled program
# ---------------------------------------------------------------------------

def test_duplicate_submit_shares_compiled_program(tokens):
    """K submits of the same JobConfig must share one jitted engine —
    asserted inside the scheduler at admission, observable both through
    n_unique_programs and the handles' segment-fn identity."""
    sched = JobScheduler(policy="fair")
    handles = [sched.submit(wc_cfg(), tokens, name=f"j{i}",
                            tenant=f"t{i}") for i in range(4)]
    res = sched.run_until_complete()
    assert sched.n_unique_programs == 1
    assert len({id(h._seg_fns) for h in handles}) == 1
    oracle = wordcount_oracle(tokens, VOCAB)
    for i in range(4):
        assert res[f"j{i}"].records == oracle
    # a different use-case window really is a second program
    hist_cfg = JobConfig(usecase=Histogram(vocab=VOCAB, n_bins=16),
                         backend="1s", task_size=TASK, push_cap=256,
                         n_procs=1, segment=2)
    sched.submit(hist_cfg, tokens, name="hist")
    sched.run_until_complete()
    assert sched.n_unique_programs == 2


# ---------------------------------------------------------------------------
# failure isolation (the PR-4 leak class, fleet edition)
# ---------------------------------------------------------------------------

def test_raising_job_closes_feed_without_stalling_siblings(tokens):
    """A tenant whose map_emit raises must fail alone: its prefetch
    thread is closed (no leak), its error is kept, and every sibling
    still completes exactly."""
    sched = JobScheduler(policy="fair")
    bad_cfg = JobConfig(usecase=Boom(vocab=VOCAB), backend="1s",
                        task_size=TASK, push_cap=256, n_procs=1,
                        segment=2)
    hb = sched.submit(bad_cfg, tokens, name="bad", tenant="evil")
    hg1 = sched.submit(wc_cfg(), tokens, name="good1")
    hg2 = sched.submit(wc_cfg(), tokens[: N // 2], name="good2")
    res = sched.run_until_complete()
    assert sched["bad"].state == FAILED
    assert isinstance(sched["bad"].error, ValueError)
    assert hb.feed._closed                      # no leaked prefetch thread
    assert sched.tenants["evil"].jobs_failed == 1
    assert set(res) == {"good1", "good2"}
    assert res["good1"].records == wordcount_oracle(tokens, VOCAB)
    assert res["good2"].records == wordcount_oracle(tokens[: N // 2],
                                                    VOCAB)
    assert hg1.feed._closed and hg2.feed._closed    # finished = closed


def test_raise_on_error_fails_fast(tokens):
    sched = JobScheduler(policy="fifo")
    bad_cfg = JobConfig(usecase=Boom(vocab=VOCAB), backend="1s",
                        task_size=TASK, push_cap=256, n_procs=1,
                        segment=2)
    hb = sched.submit(bad_cfg, tokens, name="bad")
    with pytest.raises(ValueError, match="boom"):
        sched.run_until_complete(raise_on_error=True)
    assert hb.feed._closed


# ---------------------------------------------------------------------------
# shared FeedBudget
# ---------------------------------------------------------------------------

def test_feed_budget_arbitrates_prefetch(tokens):
    """A budget smaller than the fleet's combined prefetch appetite must
    deny background reads (counted) without changing any result, and
    every reservation must be returned by the end."""
    budget_bytes = TASK * 4 * 2          # room for ~one 2-task segment
    sched = JobScheduler(policy="fair", max_live_bytes=budget_bytes)
    for i in range(4):
        sched.submit(wc_cfg(segment=1), tokens, name=f"j{i}",
                     tenant=f"t{i}")
    res = sched.run_until_complete()
    oracle = wordcount_oracle(tokens, VOCAB)
    for i in range(4):
        assert res[f"j{i}"].records == oracle
    denials = sum(j.handle.feed.stats.budget_denials for j in sched.jobs)
    assert denials > 0                   # the arbiter actually pushed back
    assert sched.budget.live_bytes == 0  # everything released
    assert sched.budget.denials == denials


def test_feed_budget_always_grants_when_idle():
    """One oversized reservation is granted when nothing is held —
    prefetch degrades to serialized, never to globally disabled."""
    b = FeedBudget(10)
    assert b.try_reserve("a", 100)       # over budget but nothing held
    assert not b.try_reserve("b", 1)     # now it is full
    b.release("a")
    assert b.try_reserve("b", 1)
    b.release("b")
    assert b.live_bytes == 0


def test_ready_and_prime(tokens):
    """ready() reports a landed prefetch without consuming anything;
    prime() starts one for a never-stepped job."""
    h = submit(wc_cfg(segment=1), tokens)
    assert not h.ready()                 # nothing scheduled yet
    h.feed.prime()
    h.feed._pending[2].result()          # wait for the background read
    assert h.ready()
    cursor_before = h.cursor
    assert h.cursor == cursor_before     # ready()/prime() consumed nothing
    assert h.result().records == wordcount_oracle(tokens, VOCAB)
    assert h.ready()                     # done handles are always ready


def test_rebalance_hook_between_slices(tokens):
    """`repro.ft.straggler.rebalance_hook` plugs outer_rebalance in as a
    per-job on_slice hook: it runs between slices (never after the
    final one), re-plans through the job's own feed, and exactness is
    untouched."""
    from repro.ft.straggler import rebalance_hook
    calls = []
    inner = rebalance_hook(drift_threshold=1.0)   # always past threshold

    def hook(handle, slice_stats):
        calls.append(slice_stats.segments)
        return inner(handle, slice_stats)

    sched = JobScheduler(policy="fifo")
    sched.submit(wc_cfg(), tokens, name="a", on_slice=hook)
    res = sched.run_until_complete()
    assert res["a"].records == wordcount_oracle(tokens, VOCAB)
    assert len(calls) >= 2 and all(c == 1 for c in calls)


# ---------------------------------------------------------------------------
# fleet checkpoint / restore (restore-after-kill mid-fleet)
# ---------------------------------------------------------------------------

def _fleet(tmp_path, tokens):
    sched = JobScheduler(policy="fair")
    sched.submit(wc_cfg(), tokens, name="a", tenant="ta")
    sched.submit(wc_cfg(), tokens[: N // 2], name="b", tenant="tb")
    return sched


def test_restore_after_kill_mid_fleet(tmp_path, tokens):
    oracle_a = wordcount_oracle(tokens, VOCAB)
    oracle_b = wordcount_oracle(tokens[: N // 2], VOCAB)
    s1 = _fleet(tmp_path, tokens)
    s1.run_until_complete(max_slices=5)          # mid-fleet, both live
    assert all(j.state == "live" for j in s1.jobs)
    work_at_ckpt = {t: s.work for t, s in s1.tenants.items()}
    s1.checkpoint(str(tmp_path / "fleet"))
    for j in s1.jobs:                            # "kill" the process
        j.handle.close()

    s2 = _fleet(tmp_path, tokens)
    s2.restore(str(tmp_path / "fleet"))
    # accounting resumed, so fair share stays fair across the restart
    assert {t: s.work for t, s in s2.tenants.items()} == work_at_ckpt
    # restore seeks — the resumed feeds never re-read the consumed prefix
    res = s2.run_until_complete()
    assert res["a"].records == oracle_a
    assert res["b"].records == oracle_b
    for j in s2.jobs:
        full = j.handle.plan.n_tasks * TASK * 4
        assert j.handle.feed.stats.bytes_read < full


def test_fleet_checkpoint_names_never_collide(tmp_path):
    """Sanitizing job names for the filesystem must stay injective —
    'job/1' and 'job_1' may not share a snapshot directory (one job
    would silently restore the other's carry)."""
    from repro.ckpt import FleetCheckpoint
    f = FleetCheckpoint(str(tmp_path / "fleet"))
    assert f.manager("job/1").dir != f.manager("job_1").dir
    assert f.manager("job/1").dir == f.manager("job/1").dir  # stable


def test_update_work_ignores_unobserved_ranks():
    """A rank assigned zero work in a slice carries no throughput
    signal; folding it in as ~zero would ratchet it into permanent
    starvation at the next re-plan."""
    from repro.ft.straggler import ThroughputTracker
    tr = ThroughputTracker(n_procs=3)
    tr.update_work([4, 4, 0], 1.0)
    assert tr.rate[2] == 1.0            # prior kept
    assert tr.rate[0] > 1.0             # observed ranks move


def test_restore_rejects_missing_resubmission(tmp_path, tokens):
    s1 = _fleet(tmp_path, tokens)
    s1.run_until_complete(max_slices=3)
    s1.checkpoint(str(tmp_path / "fleet"))
    s2 = JobScheduler(policy="fair")
    s2.submit(wc_cfg(), tokens, name="a", tenant="ta")   # "b" forgotten
    with pytest.raises(ValueError, match="'b'.*not resubmitted"):
        s2.restore(str(tmp_path / "fleet"))


def test_restore_respects_backend_guard(tmp_path, tokens):
    """The per-job snapshot guards still hold through the fleet path: a
    job resubmitted with a different backend is rejected, not corrupted."""
    s1 = _fleet(tmp_path, tokens)
    s1.run_until_complete(max_slices=5)
    s1.checkpoint(str(tmp_path / "fleet"))
    s2 = JobScheduler(policy="fair")
    s2.submit(wc_cfg(backend="2s"), tokens, name="a", tenant="ta")
    s2.submit(wc_cfg(), tokens[: N // 2], name="b", tenant="tb")
    with pytest.raises(ValueError, match="backend"):
        s2.restore(str(tmp_path / "fleet"))
