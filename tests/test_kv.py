"""Property tests for the key-value record machinery (core/kv.py).

These are the system invariants the engines rely on:
  * local_reduce is an exact groupby-sum (vs a numpy oracle), key-sorted,
    sentinel-padded;
  * bucketize partitions records by owner hash, conserving every record
    either into a bucket or the overflow set;
  * merge_sorted(a, b) == local_reduce(a ++ b);
  * mix32 is bijective (no owner-collision bias beyond hashing).
"""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.kv import (KEY_SENTINEL, bucketize, local_reduce,
                           merge_sorted, mix32, owner_of)

SENT = int(KEY_SENTINEL)


def np_groupby(keys, vals):
    out = {}
    for k, v in zip(keys.tolist(), vals.tolist()):
        if k != SENT:
            out[k] = out.get(k, 0) + v
    return out


keys_strategy = st.lists(
    st.one_of(st.integers(0, 50), st.just(SENT)), min_size=1, max_size=200)


@given(keys_strategy, st.integers(0, 3))
@settings(max_examples=60, deadline=None)
def test_local_reduce_matches_groupby(ks, extra_cap):
    keys = np.array(ks, np.int32)
    vals = np.arange(1, len(ks) + 1, dtype=np.int32)
    oracle = np_groupby(keys, vals)
    cap = len(oracle) + extra_cap if oracle else 1 + extra_cap
    uk, uv, n = local_reduce(jnp.array(keys), jnp.array(vals), cap)
    uk, uv = np.asarray(uk), np.asarray(uv)
    assert int(n) == len(oracle)
    got = {int(k): int(v) for k, v in zip(uk, uv) if k != SENT}
    assert got == oracle
    valid = uk[uk != SENT]
    assert (np.diff(valid) > 0).all()           # sorted unique
    assert (uk[len(oracle):] == SENT).all()     # padding clean
    assert (uv[len(oracle):] == 0).all()


@given(keys_strategy)
@settings(max_examples=40, deadline=None)
def test_local_reduce_capacity_overflow_keeps_smallest(ks):
    keys = np.array(ks, np.int32)
    vals = np.ones(len(ks), np.int32)
    oracle = np_groupby(keys, vals)
    if len(oracle) < 2:
        return
    cap = max(1, len(oracle) // 2)
    uk, uv, n = local_reduce(jnp.array(keys), jnp.array(vals), cap)
    uk = np.asarray(uk)
    assert int(n) == len(oracle)                # reports true unique count
    kept = sorted(oracle)[:cap]
    assert [int(k) for k in uk if k != SENT] == kept


@given(st.lists(st.integers(0, 1000), min_size=1, max_size=300),
       st.integers(2, 8), st.integers(1, 64))
@settings(max_examples=60, deadline=None)
def test_bucketize_conserves_records(ks, P, cap):
    keys = np.array(ks, np.int32)
    vals = np.arange(1, len(ks) + 1, dtype=np.int32)
    bk, bv, counts, (ofk, ofv) = bucketize(jnp.array(keys), jnp.array(vals),
                                           P, cap)
    bk, bv = np.asarray(bk), np.asarray(bv)
    ofk, ofv = np.asarray(ofk), np.asarray(ofv)
    owners = np.asarray(owner_of(jnp.array(keys), P))
    # every record lands exactly once: bucket sums + overflow sums == input
    total_in = np_groupby(keys, vals)
    got = np_groupby(np.concatenate([bk.reshape(-1), ofk]),
                     np.concatenate([bv.reshape(-1), ofv]))
    assert got == total_in
    # bucket p only holds keys owned by p
    for p in range(P):
        bucket_keys = bk[p][bk[p] != SENT]
        if bucket_keys.size:
            assert (np.asarray(owner_of(jnp.array(bucket_keys), P)) == p).all()
    # counts consistent with fill
    fill = (bk != SENT).sum(axis=1)
    assert (np.asarray(counts) == fill).all()


@given(st.lists(st.integers(0, 30), min_size=0, max_size=60),
       st.lists(st.integers(0, 30), min_size=0, max_size=60))
@settings(max_examples=40, deadline=None)
def test_merge_sorted_equals_local_reduce_of_concat(a, b):
    cap = 64
    ka = np.array(a + [SENT] * (60 - len(a)), np.int32)
    kb = np.array(b + [SENT] * (60 - len(b)), np.int32)
    va = np.ones(60, np.int32)
    vb = np.ones(60, np.int32) * 2
    va[len(a):] = 0
    vb[len(b):] = 0
    mk, mv = merge_sorted(jnp.array(ka), jnp.array(va), jnp.array(kb),
                          jnp.array(vb), cap)
    ok, ov, _ = local_reduce(jnp.concatenate([jnp.array(ka), jnp.array(kb)]),
                             jnp.concatenate([jnp.array(va), jnp.array(vb)]),
                             cap)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(ok))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(ov))


def test_mix32_bijective_on_range():
    xs = jnp.arange(1 << 16, dtype=jnp.uint32)
    h = np.asarray(mix32(xs))
    assert np.unique(h).size == xs.size


def test_owner_spread_uniform():
    P = 16
    owners = np.asarray(owner_of(jnp.arange(100_000, dtype=jnp.int32), P))
    counts = np.bincount(owners, minlength=P)
    assert counts.min() > 0.8 * counts.mean()
    assert counts.max() < 1.2 * counts.mean()
