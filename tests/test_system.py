"""End-to-end system test: the paper's engine as the ingest stage of the
LM stack — wordcount builds the vocabulary, the trainer overfits a tiny
model on the re-encoded stream, the serve engine generates from it.

Single-device (the multi-device variants live in test_engine/test_train);
this test proves the layers compose.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.config import ShapeConfig, SINGLE_POD, TrainConfig
from repro.configs.registry import get_smoke_config
from repro.core import JobConfig, submit
from repro.core.usecases import WordCount, wordcount_oracle
from repro.data.corpus import zipf_tokens
from repro.launch.specs import make_run
from repro.models.transformer import init_model
from repro.serve.engine import ServeEngine
from repro.train.train_step import init_train_state, make_train_step

pytestmark = pytest.mark.slow


def test_wordcount_to_training_to_serving():
    # 1) ingest: wordcount over a Zipf stream (P=1 mesh — the engine runs
    #    on any mesh size) builds the id->count table
    raw = zipf_tokens(50_000, vocab=4_096, seed=0)
    cfg1 = JobConfig(usecase=WordCount(vocab=4_096), backend="1s",
                     task_size=2_048, push_cap=1_024, n_procs=1)
    counts = submit(cfg1, raw).result().records
    assert counts == wordcount_oracle(raw, 4_096)

    # 2) vocab: keep the top-K words, re-encode the stream (rank ids —
    #    exactly what a production ingest does with engine counts)
    K = 256
    top = sorted(counts, key=counts.get, reverse=True)[: K - 1]
    rank_of = np.zeros(4_096, np.int32)          # 0 = <unk>
    for r, w in enumerate(top):
        rank_of[w] = r + 1
    stream = rank_of[raw]
    assert stream.max() < K

    # 3) train a tiny LM on the re-encoded stream
    cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                              vocab_size=K, dtype="float32",
                              param_dtype="float32")
    run = make_run(cfg, ShapeConfig("t", 32, 4, "train"), SINGLE_POD)
    run = dataclasses.replace(run, train=TrainConfig(
        lr=3e-3, warmup_steps=2, total_steps=40))
    params = init_model(cfg, jax.random.key(0))
    state = init_train_state(cfg, run.train, params)
    step = jax.jit(make_train_step(cfg, run))
    grid = stream[: 4 * 33 * 20].reshape(20, 4, 33)
    losses = []
    for i in range(40):
        g = grid[i % 20]
        batch = {"tokens": jnp.asarray(g[:, :-1]),
                 "labels": jnp.asarray(g[:, 1:])}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (losses[0], losses[-1])

    # 4) serve from the trained params
    eng = ServeEngine(cfg, state.params, max_len=48)
    out = eng.generate(np.asarray(grid[0][:, :16], np.int32), 8)
    assert out.shape == (4, 8)
    assert (out >= 0).all() and (out < K).all()
