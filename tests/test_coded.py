"""Coded shuffle (core/coded.py, collectives.coded_exchange, the 1S
coded engine path): grid replication properties, the bytes model,
config validation, and end-to-end exactness.

Load-bearing properties pinned here:

  * :func:`replicate_grids` puts the IDENTICAL row on every member of a
    code group, covers each original task exactly r times, and carries
    repeats/padding with their task — the structure the XOR decode's
    side-information argument rests on;
  * the bytes model states the multicast accounting fig15 gates on:
    P-1 blocks at r=1 vs 1 + (P/r - 1) at r>1;
  * ``JobSpec`` rejects every composition the decode cannot survive
    (indivisible P, fused_map, co-scheduling) and ``submit`` rejects
    backends that never advertised ``supports_coded``;
  * the full exactness matrix — r ∈ {1,2,3} × partitioner × stealing,
    over skewed repeats on array, mmap, and zipf sources — is
    record-identical to the r=1 run and the host oracle (slow,
    6-device subprocess);
  * an r=2 job checkpointed mid-stream restores and finishes exact, a
    code_rate-mismatched restore fails loudly, and ``replan()`` refuses
    coded handles (slow, 2-device subprocess).
"""
import numpy as np
import pytest

from repro.core import JobConfig, submit
from repro.core.coded import (RECORD_BYTES, group_of, member_of,
                              replicate_grids, shuffle_blocks_per_step,
                              shuffle_bytes)
from repro.core.registry import JobSpec
from repro.core.usecases import WordCount


# ---------------------------------------------------------------------------
# replicate_grids: the host half of the code-group contract
# ---------------------------------------------------------------------------

def test_group_math():
    assert [group_of(q, 2) for q in range(6)] == [0, 0, 1, 1, 2, 2]
    assert [member_of(q, 2) for q in range(6)] == [0, 1, 0, 1, 0, 1]
    assert [group_of(q, 3) for q in range(6)] == [0, 0, 0, 1, 1, 1]


def test_replicate_grids_r1_is_identity():
    ids = np.arange(12, dtype=np.int32).reshape(4, 3)
    reps = np.full((4, 3), 2, np.int32)
    out_ids, out_reps = replicate_grids(ids, reps, 1)
    np.testing.assert_array_equal(out_ids, ids)
    np.testing.assert_array_equal(out_reps, reps)


@pytest.mark.parametrize("P,r", [(6, 2), (6, 3), (4, 2), (8, 4)])
def test_replicate_grids_structure(P, r):
    """Every member of a group carries the identical (P, T*r) row; block
    k of group g is the members' original column-k tasks in rank order;
    each real task id appears exactly r times fleet-wide."""
    rng = np.random.default_rng(P * 10 + r)
    T = 5
    ids = np.arange(P * T, dtype=np.int32).reshape(P, T)
    reps = rng.integers(1, 9, size=(P, T)).astype(np.int32)
    out_ids, out_reps = replicate_grids(ids, reps, r)
    assert out_ids.shape == out_reps.shape == (P, T * r)
    by_task = dict(zip(ids.ravel(), reps.ravel()))
    for g in range(P // r):
        rows = range(g * r, (g + 1) * r)
        for q in rows:
            np.testing.assert_array_equal(out_ids[q], out_ids[g * r])
            np.testing.assert_array_equal(out_reps[q], out_reps[g * r])
        for k in range(T):
            block = out_ids[g * r, k * r:(k + 1) * r]
            np.testing.assert_array_equal(
                block, [ids[q, k] for q in rows])
            # repeats travel with their task
            for j, q in enumerate(rows):
                assert out_reps[g * r, k * r + j] == by_task[ids[q, k]]
    # exactly-r coverage, counting each group's shared row once
    flat = np.concatenate([out_ids[g * r] for g in range(P // r)])
    counts = np.bincount(flat, minlength=P * T)
    np.testing.assert_array_equal(counts, np.full(P * T, 1))
    assert all((out_ids == tid).sum() == r for tid in ids.ravel())


def test_replicate_grids_replicates_padding():
    ids = np.array([[0, 1], [2, -1]], np.int32)
    reps = np.ones((2, 2), np.int32)
    out_ids, _ = replicate_grids(ids, reps, 2)
    # block 1 of the single group is [ids[0,1], ids[1,1]] = [1, -1]
    np.testing.assert_array_equal(out_ids[0], [0, 2, 1, -1])
    np.testing.assert_array_equal(out_ids[1], out_ids[0])


def test_replicate_grids_rejects_indivisible_fleet():
    ids = np.zeros((5, 2), np.int32)
    with pytest.raises(ValueError, match="divisible"):
        replicate_grids(ids, np.ones_like(ids), 2)


# ---------------------------------------------------------------------------
# bytes model: the accounting fig15's CI gate rests on
# ---------------------------------------------------------------------------

def test_shuffle_blocks_per_step():
    # r=1: one unicast bucket per peer
    assert shuffle_blocks_per_step(6, 1) == 5
    # r>1: one coded multicast block + one bucket per spoken-for group
    assert shuffle_blocks_per_step(6, 2) == 3      # ratio 0.60
    assert shuffle_blocks_per_step(6, 3) == 2      # ratio 0.40
    assert shuffle_blocks_per_step(8, 2) == 4
    assert shuffle_blocks_per_step(4, 4) == 1      # one group: XOR only


def test_shuffle_bytes_scales_linearly():
    got = shuffle_bytes(6, 10, 1024, 2)
    assert got == 6 * 10 * 3 * 1024 * RECORD_BYTES
    # the coded win is the blocks ratio, independent of steps/cap
    r1 = shuffle_bytes(6, 7, 512, 1)
    r2 = shuffle_bytes(6, 7, 512, 2)
    assert r2 / r1 == pytest.approx(3 / 5)


# ---------------------------------------------------------------------------
# validation: every composition the decode cannot survive fails loudly
# ---------------------------------------------------------------------------

def _spec(**kw):
    base = dict(vocab=64, task_size=8, push_cap=8, n_procs=4)
    base.update(kw)
    return JobSpec(**base)


def test_jobspec_rejects_bad_code_rates():
    with pytest.raises(ValueError, match="code_rate"):
        _spec(code_rate=0)
    with pytest.raises(ValueError, match="divisible"):
        _spec(n_procs=6, code_rate=4)
    with pytest.raises(ValueError, match="fused_map"):
        _spec(code_rate=2, fused_map=True)
    with pytest.raises(ValueError, match="coslots"):
        _spec(code_rate=2, coslots=2, costride=16)
    assert _spec(n_procs=6, code_rate=3).code_rate == 3


def test_submit_rejects_backend_without_coded_support():
    tokens = np.zeros(64, np.int32)
    cfg = JobConfig(usecase=WordCount(vocab=32), backend="2s",
                    task_size=16, push_cap=16, n_procs=1, code_rate=2)
    with pytest.raises(ValueError, match="supports_coded"):
        submit(cfg, tokens)


# ---------------------------------------------------------------------------
# multi-rank exactness matrix + checkpoint round-trip (subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_coded_exactness_matrix(devices8, tmp_path):
    """r ∈ {1,2,3} × partitioner × stealing over skewed repeats, plus
    mmap- and zipf-sourced arms: every coded run is record-identical to
    the r=1 reference and the host oracle."""
    out = devices8(f"""
        import collections
        import numpy as np
        from repro.core import JobConfig, submit
        from repro.core.planner import plan_input
        from repro.core.usecases import WordCount
        from repro.data.corpus import synth_corpus, zipf_skew_repeats
        from repro.data.source import MmapTokenSource, ZipfSource, read_all

        VOCAB, N, TASK, P = 600, 24576, 512, 6
        tokens = synth_corpus(N, VOCAB, seed=0)
        oracle = dict(collections.Counter(np.asarray(tokens).tolist()))
        T = plan_input(N, TASK, P).tasks_per_proc
        reps = zipf_skew_repeats(P, T, 1.4, mean_rep=3, seed=1)

        def run(src, r, part="hash", stealing=False):
            cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                            task_size=TASK, push_cap=512, n_procs=P,
                            partitioner=part, stealing=stealing,
                            code_rate=r)
            return submit(cfg, src, repeats=reps).result()

        base = run(tokens, 1)
        assert base.records == oracle
        checked = 0
        for r in (2, 3):
            for part in ("hash", "sampled+split"):
                for stealing in (False, True):
                    res = run(tokens, r, part, stealing)
                    assert res.records == base.records == oracle, (
                        r, part, stealing)
                    checked += 1
        # skewed + stolen coded run really steals, at group granularity
        stolen = run(tokens, 3, stealing=True)
        assert stolen.n_steals > 0
        w = stolen.work_per_rank.reshape(-1, 3)
        assert (w == w[:, :1]).all(), w    # members of a group agree

        path = {str(tmp_path)!r} + "/coded.bin"
        np.asarray(tokens).tofile(path)
        res = run(MmapTokenSource(path), 2, stealing=True)
        assert res.records == oracle
        checked += 1

        zsrc = ZipfSource(N, vocab=VOCAB, seed=4)
        zoracle = dict(collections.Counter(
            np.asarray(read_all(zsrc)).tolist()))
        assert run(zsrc, 1).records == zoracle
        res = run(ZipfSource(N, vocab=VOCAB, seed=4), 3)
        assert res.records == zoracle
        checked += 1
        print("CODED-OK", checked, int(stolen.n_steals))
    """, n_devices=6)
    assert "CODED-OK" in out


@pytest.mark.slow
def test_coded_checkpoint_round_trip_and_guards(devices8, tmp_path):
    """An r=2 job snapshotted mid-stream restores and finishes exact;
    restoring the snapshot into an r=1 handle fails loudly; replan()
    refuses coded handles outright."""
    out = devices8(f"""
        import collections
        import numpy as np
        import pytest
        from repro.core import JobConfig, submit
        from repro.core.usecases import WordCount
        from repro.data.corpus import synth_corpus
        from repro.ckpt.checkpoint import CheckpointManager

        VOCAB, N, TASK, P = 300, 8192, 256, 2
        tokens = synth_corpus(N, VOCAB, seed=3)
        oracle = dict(collections.Counter(np.asarray(tokens).tolist()))

        def cfg(r, segment=0):
            return JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                             task_size=TASK, push_cap=256, n_procs=P,
                             segment=segment, code_rate=r)

        mgr = CheckpointManager({str(tmp_path)!r} + "/ck")
        h = submit(cfg(2, segment=2), tokens)
        h.step()
        h.checkpoint(mgr)
        mgr.wait()
        _, extra = mgr.peek()
        assert extra["code_rate"] == 2
        h2 = submit(cfg(2, segment=2), tokens).restore(mgr)
        assert h2.result().records == oracle

        with pytest.raises(ValueError, match="code_rate"):
            submit(cfg(1, segment=2), tokens).restore(mgr)

        with pytest.raises(ValueError, match="code_rate"):
            submit(cfg(2, segment=2), tokens).replan(
                np.zeros((P, 1), np.int32))
        print("CKPT-OK")
    """, n_devices=2)
    assert "CKPT-OK" in out
