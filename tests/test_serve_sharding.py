"""Serve-sharding (§Perf cell 2) correctness: the expert-TP decode path
(`expert_tp_axis`) computes the same function as the unpartitioned layer,
and the serve param specs carry no FSDP axes."""
import dataclasses

import numpy as np
import jax

from repro.config import MeshConfig
from repro.configs.registry import get_smoke_config
from repro.distributed import sharding as shd


def test_serve_param_specs_have_no_fsdp():
    cfg = dataclasses.replace(get_smoke_config("llama4-maverick-400b-a17b"),
                              expert_tp_axis="data")
    mesh_cfg = MeshConfig((16, 16), ("data", "model"))
    from repro.models.transformer import init_model
    params = jax.eval_shape(lambda: init_model(cfg, jax.random.key(0)))
    specs = shd.param_specs(params, cfg, mesh_cfg, "serve")
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, spec in flat:
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            if "we_" in keys:
                assert set(axes) <= {"model", "data"}, (keys, spec)
            else:
                # dense leaves: model-TP only — nothing re-gathers per step
                assert set(axes) <= {"model"}, (keys, spec)


def test_expert_tp_decode_matches_reference(devices8):
    out = devices8("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.registry import get_smoke_config
        from repro.distributed.mesh import local_mesh
        from repro.models import moe as moe_mod

        base = get_smoke_config("llama4-maverick-400b-a17b")
        cfg_ref = dataclasses.replace(
            base, dtype="float32", param_dtype="float32", top_k=2,
            capacity_factor=8.0)
        cfg_tp = dataclasses.replace(cfg_ref, expert_tp_axis="data")
        p = moe_mod.init_moe(cfg_ref, jax.random.key(0))
        mesh = local_mesh((2, 4), ("data", "model"))
        # decode shape: S=1, batch sharded over data
        x = jax.random.normal(jax.random.key(1), (4, 1, cfg_ref.d_model),
                              jnp.float32)
        y_ref, aux_ref = moe_mod.moe_forward(cfg_ref, p, x)
        y_tp, aux_tp = moe_mod.moe_forward(cfg_tp, p, x, mesh=mesh,
                                           dp_entry="data")
        np.testing.assert_allclose(np.asarray(y_tp), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(float(aux_tp), float(aux_ref), rtol=1e-5)
        print("EXPERT-TP-OK")
    """)
    assert "EXPERT-TP-OK" in out
