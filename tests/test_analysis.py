"""Pytest gate for fleetlint (repro.analysis).

Three layers, mirroring the acceptance criteria:

  * the *shipping* matrix — every backend x use-case program and every
    pallas kernel must lint clean (in-process at P=1 here; the CI
    analysis job repeats it at P=8, and a slow subprocess test below
    covers P=8 from the suite too);
  * the *mutant corpus* — every rule has a known-bad seed that must
    fire and a near-miss twin that must stay completely quiet;
  * taint-lattice unit tests — targeted programs proving the abstract
    interpreter's fixpoints and control-dependence tracking are not
    vacuous.
"""
import dataclasses

import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis import corpus, lint, rules
from repro.analysis.taint import Finding
from repro.core.registry import get_backend, JobSpec
from repro.core.usecase import as_map_fn


# ---------------------------------------------------------------------------
# mutant corpus: every rule fires on its seed, never on the near-miss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [m.name for m in corpus.MUTANTS])
def test_mutant_corpus(name):
    mutant = next(m for m in corpus.MUTANTS if m.name == name)
    got = corpus.run_mutant(mutant)
    if mutant.fires:
        assert any(f.rule == mutant.rule for f in got), \
            f"{name}: expected {mutant.rule} to fire, got {got}"
    else:
        assert got == [], f"{name}: near-miss must stay quiet, got {got}"


def test_every_rule_covered_by_corpus():
    rules_fired = {m.rule for m in corpus.MUTANTS if m.fires}
    rules_guarded = {m.rule for m in corpus.MUTANTS if not m.fires}
    expected = {"SPMD001", "SPMD002", "REP001",
                "PAL001", "PAL002", "PAL003"}
    assert rules_fired == expected
    assert rules_guarded == expected


# ---------------------------------------------------------------------------
# shipping matrix: every backend x use-case program lints clean
# ---------------------------------------------------------------------------

_MATRIX = [(b, c, s)
           for b in ("1s", "2s")
           for c, _ in corpus.SHIPPING_CASES
           for s in ((False, True) if b == "1s" else (False,))]


@pytest.mark.parametrize("bname,cname,stealing", _MATRIX)
def test_shipping_programs_clean(bname, cname, stealing):
    backend = get_backend(bname)
    usecase = dict(corpus.SHIPPING_CASES)[cname]
    mesh = corpus.procs_mesh()
    spec = JobSpec(vocab=usecase.window, task_size=8, push_cap=16,
                   n_procs=int(mesh.devices.size), segment=2,
                   stealing=stealing)
    for handle in backend.trace_handles(spec, as_map_fn(usecase), mesh,
                                        tag=f"{bname}/{cname}"):
        got = rules.check_program(handle)
        assert got == [], f"{handle.name}: {[str(f) for f in got]}"


@pytest.mark.parametrize("stealing", [False, True])
def test_coscheduled_engine_lints_clean(stealing):
    """The composite WorkDomain program ('1s' with coslots=2): the
    key-window offset and the psum-maintained carry.job_work row must
    satisfy the same replication contract as the solo engine."""
    backend = get_backend("1s")
    usecase = dict(corpus.SHIPPING_CASES)["wordcount"]
    mesh = corpus.procs_mesh()
    spec = JobSpec(vocab=usecase.window * 2, task_size=8, push_cap=16,
                   n_procs=int(mesh.devices.size), segment=2,
                   stealing=stealing, coslots=2, costride=2)
    handles = backend.trace_handles(spec, as_map_fn(usecase), mesh,
                                    tag="1s/wordcount+cosched")
    # the new carry row is part of the asserted replication contract
    assert any("carry.job_work" in h.replicated_out for h in handles)
    for handle in handles:
        got = rules.check_program(handle)
        assert got == [], f"{handle.name}: {[str(f) for f in got]}"


@pytest.mark.parametrize("kname", [k.name for k in
                                   corpus.shipping_kernels()])
def test_shipping_kernels_clean(kname):
    kc = next(k for k in corpus.shipping_kernels() if k.name == kname)
    got = rules.check_kernel(kc)
    assert got == [], f"{kname}: {[str(f) for f in got]}"


def test_analysis_not_vacuous_on_real_engine():
    """Over-asserting the contract on a *real* engine program must fire
    REP001 — proof the taint interpreter actually reaches the engine's
    outputs rather than trivially passing everything."""
    backend = get_backend("1s")
    usecase = dict(corpus.SHIPPING_CASES)["wordcount"]
    mesh = corpus.procs_mesh()
    spec = JobSpec(vocab=usecase.window, task_size=8, push_cap=16,
                   n_procs=int(mesh.devices.size), segment=2)
    _, _, fin = backend.trace_handles(spec, as_map_fn(usecase), mesh)
    # keys/values land on rank 0 only — claiming them replicated is wrong
    bogus = dataclasses.replace(
        fin, replicated_out=("keys", "values", "combine_overflow"))
    got = rules.check_program(bogus)
    assert any(f.rule == "REP001" and f.where in ("keys", "values")
               for f in got), got
    # ... while the shipped contract (overflow only) is clean
    assert rules.check_program(fin) == []


# ---------------------------------------------------------------------------
# taint-lattice unit tests
# ---------------------------------------------------------------------------

def _check(body, **kw):
    mesh = corpus.procs_mesh(1)
    handle = corpus._sm_handle("unit", body, mesh, **kw)
    return rules.check_program(handle)


def test_static_loop_preserves_replication():
    # fori_loop with static bounds lowers to scan: a replicated carry
    # stays replicated through the fixpoint
    def body(x):
        acc = lax.fori_loop(0, 4, lambda i, a: a + 1, x.sum())
        return acc[None]

    assert _check(body, replicated_in=("x0",),
                  replicated_out=("total",)) == []


def test_rank_dependent_trip_count_taints_carry():
    # fori_loop with a traced, axis_index-derived bound lowers to while:
    # the carry diverges with the trip count even if its updates do not
    def body(x):
        n = lax.axis_index("procs") + 1
        acc = lax.fori_loop(0, n, lambda i, a: a + 1, x.sum())
        return acc[None]

    got = _check(body, replicated_in=("x0",), replicated_out=("total",))
    assert [f.rule for f in got] == ["REP001"], got


def test_collective_under_rank_dependent_loop_fires_spmd002():
    def body(x):
        n = lax.axis_index("procs") + 1
        acc = lax.fori_loop(
            0, n, lambda i, a: a + lax.psum(jnp.int32(1), "procs"),
            x.sum())
        return acc[None]

    got = _check(body)
    assert any(f.rule == "SPMD002" for f in got), got


def test_psum_launders_taint_but_shuffle_does_not():
    def psum_body(x):
        return lax.psum(x.sum(), "procs")[None]

    def perm_body(x):
        return lax.ppermute(x.sum(), "procs", [(0, 0)])[None]

    assert _check(psum_body, replicated_out=("total",)) == []
    got = _check(perm_body, replicated_out=("total",))
    assert [f.rule for f in got] == ["REP001"], got


def test_varying_cond_output_is_varying():
    # both branches are pure, but a rank-divergent predicate makes the
    # *choice* rank-dependent — output must come out varying
    def body(x):
        pred = lax.axis_index("procs") == 0
        out = lax.cond(pred, lambda v: v + 1, lambda v: v - 1, x.sum())
        return out[None]

    got = _check(body, replicated_in=("x0",), replicated_out=("total",))
    assert [f.rule for f in got] == ["REP001"], got


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_selftest_passes():
    assert lint.main(["--selftest"]) == 0


def test_cli_kernels_clean_json(capsys):
    import json
    assert lint.main(["--kernels", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["checked"] == {"kernels": 6}
    assert payload["findings"] == []


def test_cli_waiver_matching():
    f = Finding("PAL002", "moe_dispatch", "output 0", "msg")
    assert lint._is_waived(f, [("PAL002", "moe")])
    assert lint._is_waived(f, [("PAL002", "output 0")])
    assert not lint._is_waived(f, [("PAL001", "moe")])
    assert not lint._is_waived(f, [("PAL002", "flash")])
    with pytest.raises(SystemExit):
        lint._parse_waivers(["PAL002"])


# ---------------------------------------------------------------------------
# full matrix at P=8 (what the CI analysis job sees)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleetlint_clean_at_p8(devices8):
    out = devices8("""
        from repro.analysis import lint
        rc = lint.main(["--all"])
        assert rc == 0, rc
        print("LINT-P8-CLEAN")
    """)
    assert "LINT-P8-CLEAN" in out
