"""Pipeline parallelism (GPipe over the pod axis): exact equivalence with
the non-pipelined loss/grads, and a 2-step PP training run."""


def test_gpipe_matches_reference_loss_and_grads(devices8):
    out = devices8("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding
        from repro.config import MeshConfig
        from repro.configs.registry import get_smoke_config
        from repro.distributed.mesh import local_mesh
        from repro.distributed.pipeline import gpipe_loss_fn, pp_param_specs
        from repro.models.transformer import init_model, loss_fn

        cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                                  dtype="float32", param_dtype="float32")
        mesh = local_mesh((2, 2), ("pod", "data"))
        mesh_cfg = MeshConfig((2, 2), ("pod", "data"))
        params = init_model(cfg, jax.random.key(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
        ref_loss, _ = loss_fn(cfg, params, batch)
        specs = pp_param_specs(jax.eval_shape(lambda: params), cfg,
                               mesh_cfg)
        p_sh = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs))
        for M in (2, 4, 8):
            pp = jax.jit(lambda p, b: gpipe_loss_fn(
                cfg, p, b, mesh=mesh, n_microbatches=M)[0])
            np.testing.assert_allclose(float(pp(p_sh, batch)),
                                       float(ref_loss), rtol=1e-5)
        pp4 = jax.jit(lambda p, b: gpipe_loss_fn(
            cfg, p, b, mesh=mesh, n_microbatches=4)[0])
        g_ref = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
        g_pp = jax.jit(jax.grad(pp4))(p_sh, batch)
        for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pp)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       atol=2e-4, rtol=2e-3)
        print("GPIPE-EXACT")
    """, n_devices=4)
    assert "GPIPE-EXACT" in out


def test_pp_train_step_descends(devices8):
    out = devices8("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.config import MeshConfig, TrainConfig
        from repro.configs.registry import get_smoke_config
        from repro.distributed.mesh import local_mesh
        from repro.distributed.pipeline import (make_pp_train_step,
                                                pp_param_specs)
        from repro.models.transformer import init_model
        from repro.optim.adamw import AdamWState
        from repro.train.train_step import TrainState, init_train_state

        cfg = dataclasses.replace(get_smoke_config("codeqwen1.5-7b"),
                                  dtype="float32", param_dtype="float32")
        mesh = local_mesh((2, 2), ("pod", "data"))
        mesh_cfg = MeshConfig((2, 2), ("pod", "data"))
        tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        params = init_model(cfg, jax.random.key(0))
        state = init_train_state(cfg, tcfg, params)
        p_specs = pp_param_specs(jax.eval_shape(lambda: params), cfg,
                                 mesh_cfg)
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
        state_sh = TrainState(p_sh, AdamWState(
            NamedSharding(mesh, P()), p_sh, p_sh), None)
        state = jax.device_put(state, state_sh)
        step = jax.jit(make_pp_train_step(cfg, tcfg, mesh=mesh,
                                          n_microbatches=4))
        rng = np.random.default_rng(1)
        batch = {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(
                    rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
        losses = []
        for _ in range(6):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(losses))
        assert losses[-1] < losses[0], losses
        # stage sharding preserved through the update
        blk = jax.tree.leaves(state.params["blocks"])[0]
        assert "pod" in str(blk.sharding.spec)
        print("PP-TRAIN-OK", losses[0], losses[-1])
    """, n_devices=4)
    assert "PP-TRAIN-OK" in out
