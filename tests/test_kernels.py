"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels are TPU-targeted (pl.pallas_call + BlockSpec); on this CPU container
they execute via ``interpret=True`` (the kernel body runs in Python), which
validates the block decomposition, masking and online-softmax logic exactly.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.flash_decode import ops as fd_ops, ref as fd_ref
from repro.kernels.moe_dispatch import ops as moe_ops, ref as moe_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref
from repro.kernels.wordcount_hash import ops as wc_ops, ref as wc_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# wordcount_hash — Map-phase histogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,vocab,hash_mod", [
    (256, 128, 0), (1024, 512, 0), (4096, 1000, 0),
    (1024, 512, 8), (2048, 300, 16),
])
def test_wordcount_hist_sweep(n, vocab, hash_mod):
    keys = jax.random.randint(jax.random.key(n), (n,), 0, vocab)
    keys = keys.astype(jnp.int32)
    got = wc_ops.wordcount_hist(keys, vocab, hash_mod=hash_mod,
                                interpret=True)
    want = wc_ref.hist_ref(keys, vocab, hash_mod=hash_mod)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wordcount_hist_with_sentinels():
    from repro.core.kv import KEY_SENTINEL
    keys = jnp.array([1, 2, 1, int(KEY_SENTINEL), 3, int(KEY_SENTINEL)],
                     jnp.int32)
    keys = jnp.pad(keys, (0, 250), constant_values=int(KEY_SENTINEL))
    got = wc_ops.wordcount_hist(keys, 8, interpret=True)
    want = wc_ref.hist_ref(keys, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got[1]) == 2 and int(got[2]) == 1 and int(got[3]) == 1


# ---------------------------------------------------------------------------
# flash_attention — prefill/train attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,dtype", [
    (2, 256, 4, 4, 64, True, 0, jnp.float32),
    (1, 512, 8, 2, 64, True, 0, jnp.float32),      # GQA 4:1
    (2, 256, 4, 1, 128, True, 0, jnp.float32),     # MQA
    (1, 384, 4, 4, 64, False, 0, jnp.float32),     # bidirectional (encoder)
    (1, 512, 4, 4, 64, True, 128, jnp.float32),    # sliding window
    (2, 256, 4, 4, 64, True, 0, jnp.bfloat16),
    (1, 640, 4, 2, 64, True, 256, jnp.bfloat16),   # SWA + GQA + ragged S
])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.key(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 block_q=128, block_kv=128, interpret=True)
    want = fa_ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# flash_decode — one-token query vs long KV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,t,dtype", [
    (2, 512, 8, 2, 64, 300, jnp.float32),
    (1, 1024, 4, 4, 64, 1023, jnp.float32),
    (4, 256, 8, 1, 128, 17, jnp.float32),          # MQA, short fill
    (2, 512, 8, 2, 64, 300, jnp.bfloat16),
])
def test_flash_decode_sweep(B, S, H, KV, hd, t, dtype):
    ks = jax.random.split(jax.random.key(S + t), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = fd_ops.flash_decode(q, k, v, jnp.int32(t), block_kv=128,
                              interpret=True)
    want = fd_ref.flash_decode_ref(q, k, v, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_decode_masks_future_slots():
    """Entries at positions >= t must not contribute."""
    B, S, H, KV, hd = 1, 256, 2, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    t = 64
    out1 = fd_ops.flash_decode(q, k, v, jnp.int32(t), block_kv=64,
                               interpret=True)
    k2 = k.at[:, t:].set(999.0)
    v2 = v.at[:, t:].set(-999.0)
    out2 = fd_ops.flash_decode(q, k2, v2, jnp.int32(t), block_kv=64,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# moe_dispatch — token→expert bucket slots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,E", [(256, 8), (1024, 16), (512, 64), (333, 7)])
def test_moe_bucket_slots_sweep(T, E):
    eids = jax.random.randint(jax.random.key(T * E), (T,), 0, E)
    eids = eids.astype(jnp.int32)
    got = moe_ops.bucket_slots(eids, E, interpret=True)
    want = moe_ref.bucket_slots_ref(eids, E)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, want)


# ---------------------------------------------------------------------------
# ssd_scan — Mamba2 chunked state-space duality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Ph,N,G,chunk,dtype", [
    (2, 512, 4, 64, 32, 1, 128, jnp.float32),
    (1, 256, 8, 32, 16, 1, 64, jnp.float32),
    (1, 384, 4, 64, 32, 1, 128, jnp.float32),      # ragged S vs chunk
    (2, 256, 4, 64, 16, 1, 128, jnp.bfloat16),
])
def test_ssd_scan_sweep(B, S, H, Ph, N, G, chunk, dtype):
    ks = jax.random.split(jax.random.key(S + N), 5)
    x = jax.random.normal(ks[0], (B, S, H, Ph), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32))
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    C = jax.random.normal(ks[4], (B, S, G, N), dtype)
    y, st = ssd_ops.ssd(x, dt, A, Bm, C, chunk=chunk, interpret=True)
    yr, str_ = ssd_ref.ssd_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(st, np.float32),
                               np.asarray(str_, np.float32), **_tol(dtype))


def test_ssd_scan_carries_initial_state():
    """Streaming invariant: scan(x, init=s0) == scan of concatenated halves."""
    B, S, H, Ph, N = 1, 256, 2, 32, 16
    ks = jax.random.split(jax.random.key(9), 5)
    x = jax.random.normal(ks[0], (B, S, H, Ph), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32))
    Bm = jax.random.normal(ks[3], (B, S, 1, N), jnp.float32)
    C = jax.random.normal(ks[4], (B, S, 1, N), jnp.float32)
    y_full, st_full = ssd_ops.ssd(x, dt, A, Bm, C, chunk=64, interpret=True)
    h = S // 2
    y1, st1 = ssd_ops.ssd(x[:, :h], dt[:, :h], A, Bm[:, :h], C[:, :h],
                          chunk=64, interpret=True)
    y2, st2 = ssd_ops.ssd(x[:, h:], dt[:, h:], A, Bm[:, h:], C[:, h:],
                          chunk=64, init_state=st1, interpret=True)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                               atol=2e-3, rtol=2e-3)
