"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels are TPU-targeted (pl.pallas_call + BlockSpec); on this CPU container
they execute via ``interpret=True`` (the kernel body runs in Python), which
validates the block decomposition, masking and online-softmax logic exactly.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.flash_decode import ops as fd_ops, ref as fd_ref
from repro.kernels.moe_dispatch import ops as moe_ops, ref as moe_ref
from repro.kernels.ssd_scan import ops as ssd_ops, ref as ssd_ref
from repro.kernels.wordcount_hash import ops as wc_ops, ref as wc_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# wordcount_hash — Map-phase histogram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,vocab,hash_mod", [
    (256, 128, 0), (1024, 512, 0), (4096, 1000, 0),
    (1024, 512, 8), (2048, 300, 16),
])
def test_wordcount_hist_sweep(n, vocab, hash_mod):
    keys = jax.random.randint(jax.random.key(n), (n,), 0, vocab)
    keys = keys.astype(jnp.int32)
    got = wc_ops.wordcount_hist(keys, vocab, hash_mod=hash_mod,
                                interpret=True)
    want = wc_ref.hist_ref(keys, vocab, hash_mod=hash_mod)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_wordcount_hist_with_sentinels():
    from repro.core.kv import KEY_SENTINEL
    keys = jnp.array([1, 2, 1, int(KEY_SENTINEL), 3, int(KEY_SENTINEL)],
                     jnp.int32)
    keys = jnp.pad(keys, (0, 250), constant_values=int(KEY_SENTINEL))
    got = wc_ops.wordcount_hist(keys, 8, interpret=True)
    want = wc_ref.hist_ref(keys, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got[1]) == 2 and int(got[2]) == 1 and int(got[3]) == 1


# ---------------------------------------------------------------------------
# flash_attention — prefill/train attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,causal,window,dtype", [
    (2, 256, 4, 4, 64, True, 0, jnp.float32),
    (1, 512, 8, 2, 64, True, 0, jnp.float32),      # GQA 4:1
    (2, 256, 4, 1, 128, True, 0, jnp.float32),     # MQA
    (1, 384, 4, 4, 64, False, 0, jnp.float32),     # bidirectional (encoder)
    (1, 512, 4, 4, 64, True, 128, jnp.float32),    # sliding window
    (2, 256, 4, 4, 64, True, 0, jnp.bfloat16),
    (1, 640, 4, 2, 64, True, 256, jnp.bfloat16),   # SWA + GQA + ragged S
])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.key(S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 block_q=128, block_kv=128, interpret=True)
    want = fa_ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# flash_decode — one-token query vs long KV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,t,dtype", [
    (2, 512, 8, 2, 64, 300, jnp.float32),
    (1, 1024, 4, 4, 64, 1023, jnp.float32),
    (4, 256, 8, 1, 128, 17, jnp.float32),          # MQA, short fill
    (2, 512, 8, 2, 64, 300, jnp.bfloat16),
])
def test_flash_decode_sweep(B, S, H, KV, hd, t, dtype):
    ks = jax.random.split(jax.random.key(S + t), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = fd_ops.flash_decode(q, k, v, jnp.int32(t), block_kv=128,
                              interpret=True)
    want = fd_ref.flash_decode_ref(q, k, v, jnp.int32(t))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_decode_masks_future_slots():
    """Entries at positions >= t must not contribute."""
    B, S, H, KV, hd = 1, 256, 2, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    t = 64
    out1 = fd_ops.flash_decode(q, k, v, jnp.int32(t), block_kv=64,
                               interpret=True)
    k2 = k.at[:, t:].set(999.0)
    v2 = v.at[:, t:].set(-999.0)
    out2 = fd_ops.flash_decode(q, k2, v2, jnp.int32(t), block_kv=64,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# moe_dispatch — token→expert bucket slots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,E", [(256, 8), (1024, 16), (512, 64), (333, 7)])
def test_moe_bucket_slots_sweep(T, E):
    eids = jax.random.randint(jax.random.key(T * E), (T,), 0, E)
    eids = eids.astype(jnp.int32)
    got = moe_ops.bucket_slots(eids, E, interpret=True)
    want = moe_ref.bucket_slots_ref(eids, E)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), got, want)


# ---------------------------------------------------------------------------
# ssd_scan — Mamba2 chunked state-space duality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,Ph,N,G,chunk,dtype", [
    (2, 512, 4, 64, 32, 1, 128, jnp.float32),
    (1, 256, 8, 32, 16, 1, 64, jnp.float32),
    (1, 384, 4, 64, 32, 1, 128, jnp.float32),      # ragged S vs chunk
    (2, 256, 4, 64, 16, 1, 128, jnp.bfloat16),
])
def test_ssd_scan_sweep(B, S, H, Ph, N, G, chunk, dtype):
    ks = jax.random.split(jax.random.key(S + N), 5)
    x = jax.random.normal(ks[0], (B, S, H, Ph), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32))
    Bm = jax.random.normal(ks[3], (B, S, G, N), dtype)
    C = jax.random.normal(ks[4], (B, S, G, N), dtype)
    y, st = ssd_ops.ssd(x, dt, A, Bm, C, chunk=chunk, interpret=True)
    yr, str_ = ssd_ref.ssd_ref(x, dt, A, Bm, C)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(st, np.float32),
                               np.asarray(str_, np.float32), **_tol(dtype))


def test_ssd_scan_carries_initial_state():
    """Streaming invariant: scan(x, init=s0) == scan of concatenated halves."""
    B, S, H, Ph, N = 1, 256, 2, 32, 16
    ks = jax.random.split(jax.random.key(9), 5)
    x = jax.random.normal(ks[0], (B, S, H, Ph), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32))
    Bm = jax.random.normal(ks[3], (B, S, 1, N), jnp.float32)
    C = jax.random.normal(ks[4], (B, S, 1, N), jnp.float32)
    y_full, st_full = ssd_ops.ssd(x, dt, A, Bm, C, chunk=64, interpret=True)
    h = S // 2
    y1, st1 = ssd_ops.ssd(x[:, :h], dt[:, :h], A, Bm[:, :h], C[:, :h],
                          chunk=64, interpret=True)
    y2, st2 = ssd_ops.ssd(x[:, h:], dt[:, h:], A, Bm[:, h:], C[:, h:],
                          chunk=64, init_state=st1, interpret=True)
    np.testing.assert_allclose(np.asarray(y_full[:, h:]), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st_full), np.asarray(st2),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# fused_map — the fused 1S engine step (local reduce -> owner lookup ->
# bucketize -> window fold). Contract: every output bit-identical to the
# pure-jnp composition of the unfused hot-path functions (ref.py), so the
# whole matrix asserts with assert_array_equal — no tolerances.
# ---------------------------------------------------------------------------

from repro.core.kv import KEY_SENTINEL  # noqa: E402
from repro.kernels.fused_map import ops as fm_ops, ref as fm_ref  # noqa: E402


def _fused_case(rng, S, V, P, cap, *, split=False, dupes=False,
                near_sat=False, n_pending=None):
    keys = rng.integers(0, V, S).astype(np.int32)
    if dupes:
        keys[:] = keys[0]                       # every record the same key
    keys[rng.random(S) < 0.15] = KEY_SENTINEL   # padding records
    vals = rng.integers(0, 100, S).astype(np.int32)
    if near_sat:
        from repro.core.combine import SAT_MAX
        vals = (SAT_MAX - rng.integers(0, 4, S)).astype(np.int32)
    omap = rng.integers(0, P, V).astype(np.int32)
    osplit = np.ones((V,), np.int32)
    if split:
        osplit[rng.random(V) < 0.3] = rng.integers(2, P + 1)
    pk = np.full((P, cap), KEY_SENTINEL, np.int32)
    pv = np.zeros((P, cap), np.int32)
    n_pending = cap if n_pending is None else n_pending
    pk[:, :n_pending] = rng.integers(0, V, (P, n_pending))
    pv[:, :n_pending] = rng.integers(0, 50, (P, n_pending))
    table = rng.integers(0, 1000, V).astype(np.int32)
    return tuple(jnp.asarray(a) for a in
                 (keys, vals, omap, osplit, pk, pv, table))


def _assert_fused_matches_ref(args, rep, tid, P, cap, blk):
    keys, vals, omap, osplit, pk, pv, table = args
    rep, tid = jnp.int32(rep), jnp.int32(tid)
    got = fm_ops.fused_map_step(keys, vals, rep, tid, omap, osplit,
                                pk, pv, table, n_procs=P, cap=cap,
                                block_voc=blk, interpret=True)
    want = fm_ref.fused_step_ref(keys, vals, rep, tid, omap, osplit,
                                 pk, pv, table, n_procs=P, cap=cap)
    for name, g, w in zip(("table", "bk", "bv", "counts"), got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
    return got


@pytest.mark.parametrize("S,V,P,cap,rep,blk", [
    (32, 256, 4, 8, 1, 64),
    (64, 512, 8, 16, 1, 128),
    (64, 500, 8, 16, 2, 128),    # vocab not a multiple of the tile
    (128, 64, 4, 8, 3, 64),      # vocab smaller than the tile
    (16, 2048, 2, 4, 1, 512),    # many tiles, tiny task
])
def test_fused_map_sweep(S, V, P, cap, rep, blk):
    rng = np.random.default_rng(S * 31 + V)
    args = _fused_case(rng, S, V, P, cap, split=True)
    _assert_fused_matches_ref(args, rep, 7, P, cap, blk)


def test_fused_map_capacity_one_buckets():
    """cap=1: all but one record per owner overflows into the local fold
    (ownership transfer) — nothing may be dropped."""
    rng = np.random.default_rng(0)
    S, V, P, cap = 48, 128, 4, 1
    args = _fused_case(rng, S, V, P, cap)
    table, bk, bv, counts = _assert_fused_matches_ref(args, 1, 3, P, cap,
                                                      64)
    assert int(jnp.max(counts)) <= cap
    # conservation: window delta + pushed bucket records == input records
    keys, vals, omap, osplit, pk, pv, table_in = args
    from repro.core.kv import local_reduce_repeated
    uk, uv = local_reduce_repeated(keys, vals, S, jnp.int32(1))
    total_in = (fm_ref.records_dense(uk, uv, V)
                + fm_ref.records_dense(pk, pv, V))
    total_out = (np.asarray(table) - np.asarray(table_in)
                 + np.asarray(fm_ref.records_dense(bk, bv, V)))
    np.testing.assert_array_equal(total_out, np.asarray(total_in))


def test_fused_map_all_duplicate_keys():
    """One unique key: the dup-sum collapses the task to a single record
    and one owner takes the whole push."""
    rng = np.random.default_rng(1)
    S, V, P, cap = 32, 100, 3, 4
    args = _fused_case(rng, S, V, P, cap, dupes=True)
    _, bk, _, counts = _assert_fused_matches_ref(args, 2, 5, P, cap, 64)
    live = np.asarray(bk) != int(KEY_SENTINEL)
    assert live.sum() <= 1 and int(np.asarray(counts).sum()) <= 1


def test_fused_map_overflow_saturation_near_sat_max():
    """Values at SAT_MAX: the window fold wraps mod 2^32 exactly like the
    unfused DenseWindow.put (the *saturating* accounting lives downstream
    in the Combine tree, which both paths share unchanged)."""
    rng = np.random.default_rng(2)
    S, V, P, cap = 24, 128, 4, 4
    args = _fused_case(rng, S, V, P, cap, near_sat=True)
    _assert_fused_matches_ref(args, 1, 9, P, cap, 64)


def test_fused_map_split_key_replica_routing():
    """A hot key split over k replicas must route by mixed task id —
    different tasks land on different replica ranks, and each placement
    matches lookup_owner bit-exactly."""
    from repro.core.partition import lookup_owner
    S, V, P, cap = 16, 64, 8, 4
    hot = 7
    keys = np.full((S,), hot, np.int32)
    vals = np.ones((S,), np.int32)
    omap = np.zeros((V,), np.int32)
    osplit = np.ones((V,), np.int32)
    osplit[hot] = 4                       # replicas on ranks {0, 1, 2, 3}
    pk = np.full((P, cap), KEY_SENTINEL, np.int32)
    pv = np.zeros((P, cap), np.int32)
    table = np.zeros((V,), np.int32)
    args = tuple(jnp.asarray(a) for a in
                 (keys, vals, omap, osplit, pk, pv, table))
    seen = set()
    for tid in range(8):
        _, bk, _, _ = _assert_fused_matches_ref(args, 1, tid, P, cap, 64)
        owner = int(lookup_owner(args[2], args[3], jnp.asarray([hot]),
                                 jnp.int32(tid), P)[0])
        rows = np.unique(np.nonzero(np.asarray(bk) != int(KEY_SENTINEL))[0])
        np.testing.assert_array_equal(rows, [owner])
        seen.add(owner)
    assert len(seen) > 1 and seen <= {0, 1, 2, 3}


def test_fused_map_repeat_loop_value_preserving():
    """Footnote-5 imbalance: any rep >= 1 yields the identical step."""
    rng = np.random.default_rng(3)
    S, V, P, cap = 32, 256, 4, 8
    args = _fused_case(rng, S, V, P, cap)
    outs = [_assert_fused_matches_ref(args, rep, 11, P, cap, 64)
            for rep in (1, 2, 5)]
    for later in outs[1:]:
        for g, w in zip(later, outs[0]):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.slow
@pytest.mark.parametrize("source", ["array", "zipf"])
def test_fused_job_matches_unfused_streamed(devices8, source):
    """Job-level exactness: a streamed 8-rank run with stealing on and the
    split partitioner produces record-identical results with and without
    the fused hot path, on both a dense array source and a zipf source."""
    out = devices8(f"""
        import numpy as np
        from repro.core.job import JobConfig, submit
        from repro.core.usecases import WordCount
        from repro.data.source import ZipfSource

        if "{source}" == "array":
            rng = np.random.default_rng(4)
            data = rng.integers(0, 300, 8192).astype(np.int32)
        else:
            data = ZipfSource(8192, vocab=300, a=1.8, seed=6)
        base = dict(task_size=64, push_cap=8, n_procs=8, segment=4,
                    stealing=True, partitioner="sampled+split")
        ru = submit(JobConfig(WordCount(vocab=300), **base),
                    data).result()
        rf = submit(JobConfig(WordCount(vocab=300), fused_map=True,
                              **base), data).result()
        assert ru.records == rf.records, "fused != unfused"
        assert len(rf.records) > 0
        print("OK", len(rf.records))
    """)
    assert "OK" in out


def test_fused_map_rejected_on_backend_without_support():
    from repro.core.job import JobConfig, submit
    from repro.core.usecases import WordCount
    with pytest.raises(ValueError, match="fused"):
        submit(JobConfig(WordCount(vocab=64), backend="2s",
                         fused_map=True, n_procs=1, task_size=8),
               np.zeros((64,), np.int32))
