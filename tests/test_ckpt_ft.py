"""Checkpoint/restart + fault-tolerance substrate."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.ft.elastic import fold_windows, remesh_plan, surviving_ranks
from repro.ft.straggler import ThroughputTracker, rebalance_tasks


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (4,)), jnp.int32),
                   "c": jnp.asarray(rng.normal(size=(3, 3)), jnp.bfloat16)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(3, t, extra={"cursor": 7})
    _, restored, extra = mgr.restore(jax.tree.map(np.zeros_like, t))
    assert extra["cursor"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_async_save_overlaps_and_commits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    for s in range(4):
        mgr.save_async(s, _tree(s), extra={"step": s})
    mgr.wait()
    assert mgr.latest_step() == 3
    assert len(mgr.steps()) == 3            # GC keeps 3
    _, restored, extra = mgr.restore(jax.tree.map(np.zeros_like, _tree()))
    assert extra["step"] == 3
    for a, b in zip(jax.tree.leaves(_tree(3)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_specific_step_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 5):
        mgr.save(s, _tree(s))
    _, r2, _ = mgr.restore(jax.tree.map(np.zeros_like, _tree()), step=2)
    for a, b in zip(jax.tree.leaves(_tree(2)), jax.tree.leaves(r2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a torn (uncommitted) checkpoint directory must be invisible
    torn = os.path.join(str(tmp_path), "step-9")
    os.makedirs(torn, exist_ok=True)      # crash before manifest commit
    assert mgr.latest_step() == 5


@pytest.mark.slow
def test_simulated_failure_restart_resumes_training(tmp_path):
    """Kill-and-restart: a fresh process state restored from the manifest
    continues bit-identically (same loss trajectory)."""
    import dataclasses
    from repro.config import ShapeConfig, SINGLE_POD, TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.launch.specs import make_run
    from repro.models.transformer import init_model
    from repro.train.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(get_smoke_config("olmo-1b"), dtype="float32",
                              param_dtype="float32")
    run = make_run(cfg, ShapeConfig("t", 16, 2, "train"), SINGLE_POD)
    run = dataclasses.replace(
        run, train=TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    params = init_model(cfg, jax.random.key(0))
    state = init_train_state(cfg, run.train, params)
    step = jax.jit(make_train_step(cfg, run))
    rng = np.random.default_rng(1)
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                   (2, 16)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                   (2, 16)), jnp.int32)}
               for _ in range(6)]
    mgr = CheckpointManager(str(tmp_path))
    losses_a = []
    for i, b in enumerate(batches):
        state, m = step(state, b)
        losses_a.append(float(m["loss"]))
        if i == 2:
            mgr.save(i, state, extra={"next_batch": i + 1})
    # crash after step 5 — restart from step 2's snapshot
    mgr.wait()
    _, state_r, extra = mgr.restore(jax.eval_shape(lambda: state))
    losses_b = []
    for b in batches[extra["next_batch"]:]:
        state_r, m = step(state_r, b)
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_b, losses_a[3:], rtol=1e-6)


@pytest.mark.slow
def test_engine_window_checkpoint_restart(tmp_path, devices8):
    """MapReduce window snapshot → restart produces the exact result
    (the MPI-storage-windows fault-tolerance path, Fig 5) — through the
    JobHandle lifecycle, for BOTH backends (the segmented path is part of
    the shared Backend protocol)."""
    out = devices8(f"""
        import numpy as np, jax
        from collections import Counter
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.core import JobConfig, submit
        from repro.core.usecases import WordCount

        rng = np.random.default_rng(5)
        VOCAB, N, P, task = 300, 16384, 8, 512
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        oracle = dict(Counter(tokens.tolist()))
        for backend in ("1s", "2s"):
            cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                            task_size=task, push_cap=1024, n_procs=P,
                            segment=2)
            mgr = CheckpointManager({str(tmp_path)!r} + "-" + backend)
            handle = submit(cfg, tokens)
            while handle.step():
                handle.checkpoint(mgr)      # async (overlaps next segment)
            handle.checkpoint(mgr)
            mgr.wait()
            # "crash"; a fresh handle restores the LAST snapshot
            h2 = submit(cfg, tokens).restore(mgr)
            assert h2.cursor == handle.cursor
            assert h2.result().records == oracle, backend
        print("WINDOW-CKPT-OK")
    """)
    assert "WINDOW-CKPT-OK" in out


# ---------------------------------------------------------------------------
# elastic / straggler
# ---------------------------------------------------------------------------

def test_remesh_plan_shrinks_coherently():
    for n, expect_total in [(512, 512), (496, 496), (384, 384), (100, 96)]:
        plan = remesh_plan(n)
        assert plan.n_devices <= n
        assert plan.n_devices >= n * 0.9 or plan.n_devices == expect_total
        assert plan.tp_size in (1, 2, 4, 8, 16)


def test_fold_windows_preserves_counts():
    rng = np.random.default_rng(0)
    tables = rng.integers(0, 100, size=(8, 64)).astype(np.int64)
    folded = fold_windows(tables, 4)
    assert folded.shape == (4, 64)
    np.testing.assert_array_equal(folded.sum(0), tables.sum(0))


def test_surviving_ranks():
    assert surviving_ranks(8, [2, 5]) == [0, 1, 3, 4, 6, 7]


def test_straggler_detection_and_rebalance():
    tr = ThroughputTracker(n_procs=8)
    seg = np.ones(8)
    seg[3] = 4.0                     # rank 3 is 4x slower
    for _ in range(5):
        tr.update(seg)
    flag = tr.is_straggler(threshold=0.5)
    assert flag[3] and flag.sum() == 1
    rate = 1.0 / seg
    assign = rebalance_tasks(list(range(16)), rate, 16)
    sizes = (assign >= 0).sum(axis=1)
    assert assign.shape[0] == 8 and sizes.sum() == 16
    # every task exactly once
    flat = assign[assign >= 0]
    assert sorted(flat.tolist()) == list(range(16))
    assert sizes[3] == sizes.min()   # slow rank gets fewest tasks


# ---------------------------------------------------------------------------
# unified Job API integration (single real device, P=1..2 planning only)
# ---------------------------------------------------------------------------

def test_straggler_plan_from_job_handle():
    """plan_next_segment re-plans exactly the handle's remaining tasks."""
    from repro.core import JobConfig, submit
    from repro.core.usecases import WordCount
    from repro.ft.straggler import plan_next_segment, tracker_from_result

    tokens = np.arange(4096, dtype=np.int32) % 64
    cfg = JobConfig(usecase=WordCount(vocab=64), backend="1s",
                    task_size=512, push_cap=512, n_procs=1, segment=2)
    handle = submit(cfg, tokens)
    handle.step()                            # 2 of 8 tasks done
    remaining = handle.remaining_task_ids()
    assert sorted(remaining.tolist()) == list(range(2, 8))

    res = submit(JobConfig(usecase=WordCount(vocab=64), backend="1s",
                           task_size=512, push_cap=512, n_procs=1),
                 tokens).result()
    tr = tracker_from_result(res)
    assign = plan_next_segment(handle, tr)
    flat = assign[assign >= 0]
    assert sorted(flat.tolist()) == sorted(remaining.tolist())


def test_elastic_fold_job_windows_preserves_counts():
    """Mid-job windows folded onto fewer ranks conserve every count —
    including the 1s backend's in-flight pending chunk: after the map
    phase completes, the folded tables must hold ALL N records."""
    from repro.core import JobConfig, submit
    from repro.core.usecases import WordCount
    from repro.ft.elastic import fold_job_windows

    N = 8192
    tokens = (np.arange(N, dtype=np.int32) * 7) % 50
    cfg = JobConfig(usecase=WordCount(vocab=50), backend="1s",
                    task_size=512, push_cap=512, n_procs=1, segment=4)
    handle = submit(cfg, tokens)
    handle.step()
    tables = handle.windows()
    folded = fold_job_windows(handle, 1)
    assert folded.shape == (1, 50)
    np.testing.assert_array_equal(folded.sum(0), tables.sum(0))
    # drain the map phase: nothing may be lost to the in-flight buffer
    while handle.step():
        pass
    assert fold_job_windows(handle, 1).sum() == N
