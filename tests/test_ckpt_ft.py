"""Checkpoint/restart + fault-tolerance substrate."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.ft.elastic import fold_windows, remesh_plan, surviving_ranks
from repro.ft.straggler import ThroughputTracker, rebalance_tasks


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (4,)), jnp.int32),
                   "c": jnp.asarray(rng.normal(size=(3, 3)), jnp.bfloat16)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(3, t, extra={"cursor": 7})
    _, restored, extra = mgr.restore(jax.tree.map(np.zeros_like, t))
    assert extra["cursor"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_async_save_overlaps_and_commits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    for s in range(4):
        mgr.save_async(s, _tree(s), extra={"step": s})
    mgr.wait()
    assert mgr.latest_step() == 3
    assert len(mgr.steps()) == 3            # GC keeps 3
    _, restored, extra = mgr.restore(jax.tree.map(np.zeros_like, _tree()))
    assert extra["step"] == 3
    for a, b in zip(jax.tree.leaves(_tree(3)), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_specific_step_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2, 5):
        mgr.save(s, _tree(s))
    _, r2, _ = mgr.restore(jax.tree.map(np.zeros_like, _tree()), step=2)
    for a, b in zip(jax.tree.leaves(_tree(2)), jax.tree.leaves(r2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a torn (uncommitted) checkpoint directory must be invisible
    torn = os.path.join(str(tmp_path), "step-9")
    os.makedirs(torn, exist_ok=True)      # crash before manifest commit
    assert mgr.latest_step() == 5


def test_simulated_failure_restart_resumes_training(tmp_path):
    """Kill-and-restart: a fresh process state restored from the manifest
    continues bit-identically (same loss trajectory)."""
    import dataclasses
    from repro.config import ShapeConfig, SINGLE_POD, TrainConfig
    from repro.configs.registry import get_smoke_config
    from repro.launch.specs import make_run
    from repro.models.transformer import init_model
    from repro.train.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(get_smoke_config("olmo-1b"), dtype="float32",
                              param_dtype="float32")
    run = make_run(cfg, ShapeConfig("t", 16, 2, "train"), SINGLE_POD)
    run = dataclasses.replace(
        run, train=TrainConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    params = init_model(cfg, jax.random.key(0))
    state = init_train_state(cfg, run.train, params)
    step = jax.jit(make_train_step(cfg, run))
    rng = np.random.default_rng(1)
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                   (2, 16)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                   (2, 16)), jnp.int32)}
               for _ in range(6)]
    mgr = CheckpointManager(str(tmp_path))
    losses_a = []
    for i, b in enumerate(batches):
        state, m = step(state, b)
        losses_a.append(float(m["loss"]))
        if i == 2:
            mgr.save(i, state, extra={"next_batch": i + 1})
    # crash after step 5 — restart from step 2's snapshot
    mgr.wait()
    _, state_r, extra = mgr.restore(jax.eval_shape(lambda: state))
    losses_b = []
    for b in batches[extra["next_batch"]:]:
        state_r, m = step(state_r, b)
        losses_b.append(float(m["loss"]))
    np.testing.assert_allclose(losses_b, losses_a[3:], rtol=1e-6)


def test_engine_window_checkpoint_restart(tmp_path, devices8):
    """MapReduce window snapshot → restart produces the exact result
    (the MPI-storage-windows fault-tolerance path, Fig 5)."""
    out = devices8(f"""
        import numpy as np, jax
        from collections import Counter
        from repro.ckpt.checkpoint import CheckpointManager
        from repro.core import onesided
        from repro.core.wordcount import WordCount
        from repro.core.kv import KEY_SENTINEL

        rng = np.random.default_rng(5)
        VOCAB, N, P, task = 300, 16384, 8, 512
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        oracle = dict(Counter(tokens.tolist()))
        job = WordCount(backend="1s")
        job.init(tokens, vocab=VOCAB, task_size=task, push_cap=1024,
                 n_procs=P)
        init_fn, seg_fn, fin_fn = onesided.make_segment_fns(
            job.spec, job.map_task, job.mesh)
        mgr = CheckpointManager({str(tmp_path)!r})
        carry = init_fn()
        T = job._tokens.shape[1]
        for s in range(0, T, 2):
            carry = seg_fn(carry, job._tokens[:, s:s+2],
                           job._repeats[:, s:s+2])
            mgr.save_async(s, carry, extra={{"next": s + 2}})
        mgr.wait()
        # "crash"; restore the LAST snapshot in a fresh carry
        _, carry_r, extra = mgr.restore(jax.eval_shape(lambda: carry))
        assert extra["next"] == T
        keys, vals = fin_fn(carry_r)
        keys, vals = np.asarray(keys)[0], np.asarray(vals)[0]
        valid = keys != int(KEY_SENTINEL)
        got = dict(zip(keys[valid].tolist(), vals[valid].tolist()))
        assert got == oracle
        print("WINDOW-CKPT-OK")
    """)
    assert "WINDOW-CKPT-OK" in out


# ---------------------------------------------------------------------------
# elastic / straggler
# ---------------------------------------------------------------------------

def test_remesh_plan_shrinks_coherently():
    for n, expect_total in [(512, 512), (496, 496), (384, 384), (100, 96)]:
        plan = remesh_plan(n)
        assert plan.n_devices <= n
        assert plan.n_devices >= n * 0.9 or plan.n_devices == expect_total
        assert plan.tp_size in (1, 2, 4, 8, 16)


def test_fold_windows_preserves_counts():
    rng = np.random.default_rng(0)
    tables = rng.integers(0, 100, size=(8, 64)).astype(np.int64)
    folded = fold_windows(tables, 4)
    assert folded.shape == (4, 64)
    np.testing.assert_array_equal(folded.sum(0), tables.sum(0))


def test_surviving_ranks():
    assert surviving_ranks(8, [2, 5]) == [0, 1, 3, 4, 6, 7]


def test_straggler_detection_and_rebalance():
    tr = ThroughputTracker(n_procs=8)
    seg = np.ones(8)
    seg[3] = 4.0                     # rank 3 is 4x slower
    for _ in range(5):
        tr.update(seg)
    flag = tr.is_straggler(threshold=0.5)
    assert flag[3] and flag.sum() == 1
    rate = 1.0 / seg
    assign = rebalance_tasks(list(range(16)), rate, 16)
    sizes = (assign >= 0).sum(axis=1)
    assert assign.shape[0] == 8 and sizes.sum() == 16
    # every task exactly once
    flat = assign[assign >= 0]
    assert sorted(flat.tolist()) == list(range(16))
    assert sizes[3] == sizes.min()   # slow rank gets fewest tasks
