"""Data pipeline + optimizer substrate tests."""
import numpy as np
import pytest
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.config import TrainConfig
from repro.data.corpus import imbalance_repeats, synth_corpus, zipf_tokens
from repro.data.pipeline import DoubleBufferedLoader, lm_batches
from repro.data.tokenizer import (HashTokenizer, Vocab, encode_with_vocab,
                                  words_of)
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               global_norm, lr_schedule)
from repro.optim.compress import compress_int8, decompress_int8


# ---------------------------------------------------------------------------
# tokenizer / corpus
# ---------------------------------------------------------------------------

def test_words_of_splits_bytes():
    assert words_of(b"the  quick\nbrown\tfox ") == \
        [b"the", b"quick", b"brown", b"fox"]


def test_vocab_roundtrip_and_rank_order():
    counts = {b"a": 10, b"bb": 5, b"ccc": 7, b"d": 1}
    v = Vocab.from_counts(counts, max_size=3)
    assert v.size == 3                         # 2 words + <unk>
    assert v.word_of(v.id_of(b"a")) == b"a"
    assert v.id_of(b"a") != 0 and v.id_of(b"ccc") != 0   # top-2 kept
    assert v.id_of(b"d") == 0                  # rare word -> <unk>
    assert v.word_of(0) == b"<unk>"


def test_encode_with_vocab_and_hash_tokenizer():
    data = b"to be or not to be"
    counts = {w: 1 for w in words_of(data)}
    v = Vocab.from_counts(counts, max_size=10)
    ids = encode_with_vocab(data, v)
    assert ids.shape == (6,)
    assert ids[1] == ids[5]                   # "be" == "be"
    ht = HashTokenizer(1024)
    ids2 = ht.encode(data)
    assert ids2.shape == (6,) and ids2[0] == ids2[4]
    assert (ids2 >= 0).all() and (ids2 < 1024).all()


def test_zipf_corpus_is_skewed():
    toks = zipf_tokens(200_000, 5000, seed=1)
    counts = np.bincount(toks, minlength=5000)
    top = np.sort(counts)[::-1]
    assert top[0] > 20 * top[100]             # heavy head — PUMA-like


def test_imbalance_repeats_modes():
    b = imbalance_repeats(8, 10, mode="balanced")
    assert (b == 1).all()
    u = imbalance_repeats(8, 10, mode="unbalanced", hot_factor=8,
                          hot_fraction=0.125)
    assert (u[0] == 8).all() and (u[1:] == 1).all()
    r = imbalance_repeats(8, 10, mode="random", hot_factor=4, seed=0)
    assert r.min() >= 1 and r.max() <= 4


def test_lm_batches_and_double_buffer():
    toks = synth_corpus(10_000, 512, seed=0)
    it = lm_batches(toks, batch=4, seq=32, seed=0)
    loader = DoubleBufferedLoader(it)
    seen = 0
    for batch in loader:
        assert batch["tokens"].shape == (4, 32)
        assert batch["labels"].shape == (4, 32)
        # labels are next-token shifted
        np.testing.assert_array_equal(np.asarray(batch["tokens"][:, 1:]),
                                      np.asarray(batch["labels"][:, :-1]))
        seen += 1
        if seen >= 5:
            break
    assert seen == 5


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _ref_adamw(p, g, m, v, t, cfg: TrainConfig, lr):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    p = p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
    return p, m, v


def test_adamw_matches_reference_update():
    cfg = TrainConfig(lr=1e-2, warmup_steps=0, total_steps=100,
                      grad_clip=0.0)
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
    state = adamw_init(p, cfg)
    pr = np.asarray(p["w"]); m = np.zeros_like(pr); v = np.zeros_like(pr)
    cur = p
    for t in range(1, 4):
        g = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
        cur, state, _ = adamw_update(cur, g, state, cfg)
        lr = float(lr_schedule(cfg, t))        # schedule sees the new step
        pr, m, v = _ref_adamw(pr, np.asarray(g["w"]), m, v, t, cfg, lr)
        np.testing.assert_allclose(np.asarray(cur["w"]), pr, atol=1e-5,
                                   rtol=1e-5)


def test_lr_schedule_warmup_cosine():
    cfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(lr_schedule(cfg, 0)) < 0.2
    np.testing.assert_allclose(float(lr_schedule(cfg, 10)), 1.0, rtol=1e-3)
    assert float(lr_schedule(cfg, 109)) < 0.12   # cosine floor 10%


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), np.sqrt(90 + 160), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_int8_compression_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10),
                    jnp.float32)
    q, scale = compress_int8(x)
    assert q.dtype == jnp.int8
    back = decompress_int8(q, scale)
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    assert err <= float(scale) * 0.51 + 1e-6   # half a quantization step
