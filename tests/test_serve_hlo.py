"""Serving engine + HLO stats parser tests."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.launch.hlo_stats import collective_bytes
from repro.models.transformer import forward, init_model
from repro.serve.engine import ServeEngine, prefill_to_decode_cache


@pytest.mark.parametrize("arch", ["olmo-1b", "h2o-danube-1.8b",
                                  "jamba-v0.1-52b", "whisper-tiny"])
def test_serve_generate_matches_teacher_forcing(arch):
    """Greedy generation must reproduce argmax of a teacher-forced full
    forward over (prompt + generated) — validates the prefill→decode cache
    handoff (incl. SWA ring and SSM state carry)."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              param_dtype="float32")
    params = init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    B, S_p, n_new = 2, 8, 6
    prompts = rng.integers(0, cfg.vocab_size, (B, S_p)).astype(np.int32)
    fe = None
    if cfg.n_enc_layers:
        fe = rng.normal(size=(B, S_p, cfg.d_model)).astype(np.float32)
    eng = ServeEngine(cfg, params, max_len=S_p + n_new + 2)
    out = eng.generate(prompts, n_new, frontend_embeds=fe, greedy=True)
    assert out.shape == (B, n_new)

    # teacher-forced check, token by token
    seq = np.concatenate([prompts, out], axis=1)
    batch = {"tokens": jnp.asarray(seq)}
    if fe is not None:
        batch["frontend_embeds"] = jnp.asarray(fe)
    logits, _ = forward(cfg, params, batch)
    logits = np.asarray(logits, np.float32)
    for j in range(n_new):
        pos = S_p + j - 1
        want = logits[:, pos].argmax(-1)
        np.testing.assert_array_equal(out[:, j], want)


def test_vlm_generate_with_image_prefix():
    cfg = dataclasses.replace(get_smoke_config("internvl2-26b"),
                              dtype="float32", param_dtype="float32")
    params = init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(2)
    B, S_p, S_img, n_new = 1, 6, 16, 4
    prompts = rng.integers(0, cfg.vocab_size, (B, S_p)).astype(np.int32)
    fe = rng.normal(size=(B, S_img, cfg.d_model)).astype(np.float32)
    eng = ServeEngine(cfg, params, max_len=S_img + S_p + n_new + 2)
    out = eng.generate(prompts, n_new, frontend_embeds=fe, greedy=True)
    assert out.shape == (B, n_new)
    seq = np.concatenate([prompts, out], axis=1)
    logits, _ = forward(cfg, params, {"tokens": jnp.asarray(seq),
                                      "frontend_embeds": jnp.asarray(fe)})
    logits = np.asarray(logits, np.float32)
    for j in range(n_new):
        pos = S_img + S_p + j - 1
        np.testing.assert_array_equal(out[:, j], logits[:, pos].argmax(-1))


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

SAMPLE = """
HloModule jit_step
%r = f32[32,4096]{1,0} all-reduce(%x), replica_groups=[16,16]<=[16,16]T(1,0)
%fusion = f32[8]{0} fusion(%r, %all-reduce.2), kind=kLoop
%ag = bf16[32,4096,3144]{2,1,0} all-gather(%y), replica_groups=[128,2]<=[16,16]T(1,0), dimensions={0}
%rs = f32[16,128]{1,0} reduce-scatter(%z), replica_groups=[16,16]<=[256], dimensions={0}
%cp = s32[16,4096,1]{2,1,0} collective-permute(%w), source_target_pairs={{0,0},{1,1}}
%a2a = bf16[8,64]{1,0} all-to-all(%u), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
%ags = (f32[128]{0}, f32[512]{0}) all-gather-start(%v), replica_groups=[4,4]<=[16]
%agd = f32[512]{0} all-gather-done(%ags)
"""


def test_collective_bytes_wire_math():
    got = collective_bytes(SAMPLE)
    # all-reduce: 32*4096*4 B result, g=16 → 2*(15/16)*524288
    np.testing.assert_allclose(got["all-reduce"],
                               2 * 15 / 16 * 32 * 4096 * 4)
    # all-gather: result 32*4096*3144*2, g=2 → (1/2)*result
    np.testing.assert_allclose(got["all-gather"],
                               0.5 * 32 * 4096 * 3144 * 2 + 3 / 4 * 512 * 4)
    # reduce-scatter: result 16*128*4, g=16 → result*15
    np.testing.assert_allclose(got["reduce-scatter"], 16 * 128 * 4 * 15)
    # permute: raw result bytes
    np.testing.assert_allclose(got["collective-permute"], 16 * 4096 * 4)
    # all-to-all: g=8 → (7/8)*result
    np.testing.assert_allclose(got["all-to-all"], 7 / 8 * 8 * 64 * 2)
    assert got["n_all-gather"] == 2           # start counted, done skipped
    assert got["n_all-reduce"] == 1           # fusion operand mention skipped
    assert got["total"] == sum(got[k] for k in (
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_extrapolation_affine():
    from repro.launch.dryrun import _extrapolate
    c11 = {"flops": 10.0}
    c21 = {"flops": 16.0}     # dL = 6
    c12 = {"flops": 17.0}     # dA = 7
    out = _extrapolate(c11, c21, c12, NB=4, A=3, keys=("flops",))
    # base=10, per-acc c=7 with 1 block; per-extra-block 6
    # total = 10 + 2*7 + 3*3*6 = 78
    np.testing.assert_allclose(out["flops"], 78.0)
