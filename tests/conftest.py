"""Shared test helpers.

NOTE: no XLA_FLAGS here on purpose — unit/smoke tests run on the 1 real CPU
device. Multi-device integration tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run ``code`` in a subprocess with n placeholder CPU devices.

    The snippet should print its assertions' evidence; raises on non-zero
    exit with captured output in the message.
    """
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_devices}")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def devices8():
    def run(code: str, n_devices: int = 8, **kw) -> str:
        return run_devices(code, n_devices, **kw)
    return run
