"""Cross-job co-scheduling (repro/core/workdomain.py + the fleet-wide
cursor in repro/core/steal.py).

Pins the tentpole contract of the WorkDomain: the fleet cursor claims
every (job, task) pair exactly once across job boundaries; a
single-member fleet reduces bit-identically to the solo steal schedule;
every co-scheduled member's records are bit-identical to its solo run
(including across a mid-co-schedule fleet checkpoint/restore); and the
scheduler charges tenants the work their jobs actually *executed* in
mixed slices, not what a slice was nominally assigned.
"""
import numpy as np
import pytest

from repro.core import JobConfig, JobScheduler, submit
from repro.core.scheduler import DONE
from repro.core.steal import composite_slots, fleet_merge, steal_schedule
from repro.core.usecases import Histogram, WordCount, wordcount_oracle
from repro.core.workdomain import WorkDomain, can_coschedule

VOCAB, TASK = 200, 512
STRIDE = 64                     # composite id stride for host-level tests


def random_grid(rng, P, max_t=8):
    """Random member assignment grid (same shape family as
    test_steal.random_grid): unique local ids < STRIDE, right-padded."""
    T = int(rng.integers(1, max_t + 1))
    counts = rng.integers(0, T + 1, size=P)
    if counts.sum() == 0:
        counts[int(rng.integers(0, P))] = 1
    ids = -np.ones((P, T), np.int32)
    pool = rng.permutation(STRIDE)[: int(counts.sum())]
    k = 0
    for r in range(P):
        ids[r, : counts[r]] = pool[k: k + counts[r]]
        k += counts[r]
    reps = rng.integers(1, 9, size=(P, T)).astype(np.int32)
    return ids, reps


def wc_cfg(**kw):
    base = dict(usecase=WordCount(vocab=VOCAB), backend="1s",
                task_size=TASK, push_cap=256, n_procs=1, segment=1)
    base.update(kw)
    return JobConfig(**base)


# ---------------------------------------------------------------------------
# fleet-wide cursor: exactly-once across job boundaries, solo reduction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [2, 4, 8])
def test_fleet_exactly_once_across_jobs(P):
    """Property: over random K-member grids and random initial progress,
    the fleet cursor executes every (job, task) pair exactly once — the
    solo exactly-once argument survives the composite encoding — and
    the per-slot executed-work split accounts each member's repeats
    exactly (the host twin of ``carry.job_work``)."""
    rng = np.random.default_rng(P)
    for trial in range(15):
        K = int(rng.integers(2, 5))
        members = [random_grid(rng, P) for _ in range(K)]
        ids, reps = fleet_merge([m[0] for m in members],
                                [m[1] for m in members], stride=STRIDE)
        work0 = rng.integers(0, 40, size=P).astype(np.int32)
        sched = steal_schedule(ids, reps, work0=work0,
                               coslots=K, costride=STRIDE)
        executed = sched.exec_ids[sched.exec_ids >= 0]
        expect = [j * STRIDE + t for j, (g, _) in enumerate(members)
                  for t in g[g >= 0].tolist()]
        assert sorted(executed.tolist()) == sorted(expect), (
            f"P={P} trial={trial}: fleet cursor lost/duplicated a task")
        for j, (g, r) in enumerate(members):
            assert sched.slot_work[j] == int(r[g >= 0].sum()), (
                f"P={P} trial={trial}: slot {j} mis-accounted")
        assert int(sched.slot_work.sum()) == int(
            (sched.work - work0).sum())


def test_single_member_fleet_reduces_to_solo():
    """A 1-member fleet is the solo schedule bit-for-bit — merging is an
    encoding, not a different scheduler."""
    rng = np.random.default_rng(42)
    for _ in range(10):
        ids, reps = random_grid(rng, 4)
        solo = steal_schedule(ids, reps)
        fids, freps = fleet_merge([ids], [reps], stride=STRIDE)
        fleet = steal_schedule(fids, freps, coslots=1, costride=STRIDE)
        np.testing.assert_array_equal(
            solo.exec_ids[solo.exec_ids >= 0],
            fleet.exec_ids[fleet.exec_ids >= 0])
        np.testing.assert_array_equal(solo.work, fleet.work)
        np.testing.assert_array_equal(solo.stolen, fleet.stolen)


def test_priority_lanes_come_first():
    """A higher-priority member's columns sit at the head of every
    rank's deque — claimed (and stolen) before any lower lane."""
    lo = np.arange(8, dtype=np.int32).reshape(2, 4)
    hi = np.arange(6, dtype=np.int32).reshape(2, 3)
    ones = [np.ones_like(lo), np.ones_like(hi)]
    ids, _ = fleet_merge([lo, hi], ones, stride=STRIDE,
                         priorities=[0, 7])
    slots = composite_slots(ids, STRIDE)
    for r in range(2):
        row = slots[r][slots[r] >= 0]
        first_lo = np.argmax(row == 0)
        assert (row[:first_lo] == 1).all(), f"rank {r}: {row}"


def test_fleet_merge_rejects_oversized_ids():
    ids = np.array([[0, STRIDE]], np.int32)     # id == stride: overflow
    with pytest.raises(AssertionError, match="stride"):
        fleet_merge([ids], [np.ones_like(ids)], stride=STRIDE)


# ---------------------------------------------------------------------------
# eligibility gates: fused / '2s' / sampling cleanly reject
# ---------------------------------------------------------------------------

def test_composite_spec_rejects_fused_map():
    from repro.core.registry import JobSpec
    with pytest.raises(ValueError, match="fused_map.*coslots"):
        JobSpec(vocab=VOCAB, task_size=TASK, push_cap=256, n_procs=1,
                segment=1, fused_map=True, coslots=2, costride=STRIDE)


def test_twosided_rejects_composite_spec():
    from repro.core.registry import JobSpec, get_backend
    spec = JobSpec(vocab=VOCAB, task_size=TASK, push_cap=256, n_procs=1,
                   segment=1, coslots=2, costride=STRIDE)
    with pytest.raises(ValueError, match="'2s'.*coslots"):
        get_backend("2s").make_segment_fns(
            spec, lambda t, i, r: (t, t), None)


def test_can_coschedule_gates(tokens):
    h = submit(wc_cfg(), tokens)
    assert can_coschedule(h)
    oneshot = submit(wc_cfg(segment=0), tokens)
    assert not can_coschedule(oneshot)
    two_s = submit(wc_cfg(backend="2s"), tokens)
    assert not can_coschedule(two_s)
    sampled = submit(wc_cfg(partitioner="sampled"), tokens)
    assert not can_coschedule(sampled)
    for x in (h, oneshot, two_s, sampled):
        x.feed.close()


def test_workdomain_needs_two_members(tokens):
    h = submit(wc_cfg(), tokens)
    with pytest.raises(ValueError, match="at least two"):
        WorkDomain([h])
    h.feed.close()


# ---------------------------------------------------------------------------
# scheduler integration: record identity, executed-work fair share,
# mid-co-schedule fleet checkpoint/restore
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return rng.integers(0, VOCAB, size=13 * TASK).astype(np.int32)


@pytest.fixture(scope="module")
def tokens_b():
    rng = np.random.default_rng(1)
    return rng.integers(0, VOCAB, size=7 * TASK).astype(np.int32)


def test_coscheduled_jobs_record_identical_to_solo(tokens, tokens_b):
    ref_a = submit(wc_cfg(), tokens).result()
    ref_b = submit(wc_cfg(), tokens_b).result()
    sched = JobScheduler(coschedule=True)
    ha = sched.submit(wc_cfg(), tokens, tenant="t", name="a")
    hb = sched.submit(wc_cfg(), tokens_b, tenant="t", name="b")
    sched.run_until_complete()
    assert len(sched._domains) == 1 and sched._domains[0].done
    for h, ref in ((ha, ref_a), (hb, ref_b)):
        got = h.result()
        assert got.records == ref.records
        assert got.output == ref.output
    # executed work charged per member: one task-rep per task here
    assert sched._by_name["a"].work_done == 13
    assert sched._by_name["b"].work_done == 7
    assert sched.tenants["t"].work == 20


def test_short_member_finalizes_before_domain_drains(tokens, tokens_b):
    """Operation-level co-scheduling must not hold a short job's result
    hostage to a long co-tenant: member b (7 tasks) adopts its result
    while the domain is still executing member a (13 tasks)."""
    sched = JobScheduler(coschedule=True)
    sched.submit(wc_cfg(), tokens, tenant="t", name="a")
    sched.submit(wc_cfg(), tokens_b, tenant="t", name="b")
    states = []
    for _ in range(64):
        sched.run_until_complete(max_slices=1)
        states.append(tuple(j.state for j in sched.jobs))
        if all(j.state == DONE for j in sched.jobs):
            break
    assert states[-1] == (DONE, DONE), states
    assert ("live", DONE) in states, states


def test_fairshare_charges_executed_not_assigned(tokens, tokens_b, tokens_c):
    """Satellite regression: tenant A's two co-schedulable jobs execute
    20 task-reps total; tenant B's solo histogram job executes 20 too.
    Fair share must end with the tenants' charged service equal (within
    10%) — charging assigned slices instead of executed work would skew
    A by ~2x (each domain slice advances both members)."""
    sched = JobScheduler(policy="fair", coschedule=True)
    sched.submit(wc_cfg(), tokens, tenant="A", name="a1")
    sched.submit(wc_cfg(), tokens_b, tenant="A", name="a2")
    sched.submit(JobConfig(usecase=Histogram(vocab=VOCAB, n_bins=16),
                           backend="1s", task_size=TASK, push_cap=256,
                           n_procs=1, segment=1),
                 tokens_c, tenant="B", name="b1")
    sched.run_until_complete()
    assert len(sched._domains) == 1          # histogram sliced solo
    wa, wb = sched.tenants["A"].work, sched.tenants["B"].work
    assert wa == 20 and wb == 20, (wa, wb)
    assert abs(wa - wb) <= 0.1 * max(wa, wb)


@pytest.fixture(scope="module")
def tokens_c():
    rng = np.random.default_rng(2)
    return rng.integers(0, VOCAB, size=20 * TASK).astype(np.int32)


def test_mid_coschedule_checkpoint_restore(tokens, tokens_b, tmp_path):
    """Fleet snapshot taken while the shared cursor is mid-domain:
    restore into a fresh scheduler (same submissions) and finish —
    records identical to the uninterrupted solo runs, accounting
    resumes, and the domain re-forms from the manifest."""
    ref_a = submit(wc_cfg(), tokens).result()
    ref_b = submit(wc_cfg(), tokens_b).result()

    s1 = JobScheduler(coschedule=True)
    s1.submit(wc_cfg(), tokens, tenant="t", name="a")
    s1.submit(wc_cfg(), tokens_b, tenant="t", name="b")
    s1.run_until_complete(max_slices=1)
    assert s1._domains and not s1._domains[0].done
    s1.checkpoint(str(tmp_path))

    s2 = JobScheduler(coschedule=True)
    ha = s2.submit(wc_cfg(), tokens, tenant="t", name="a")
    hb = s2.submit(wc_cfg(), tokens_b, tenant="t", name="b")
    s2.restore(str(tmp_path))
    assert len(s2._domains) == 1             # re-formed from manifest
    s2.run_until_complete()
    assert ha.result().records == ref_a.records
    assert hb.result().records == ref_b.records
    assert s2.tenants["t"].work == 20


def test_evicting_live_domain_member_raises(tokens, tokens_b):
    sched = JobScheduler(coschedule=True)
    sched.submit(wc_cfg(), tokens, tenant="t", name="a")
    sched.submit(wc_cfg(), tokens_b, tenant="t", name="b")
    sched.run_until_complete(max_slices=1)
    assert not sched._domains[0].done
    with pytest.raises(RuntimeError, match="co-scheduled"):
        sched.evict("a")
    sched.close()


# ---------------------------------------------------------------------------
# multi-rank: cross-job steals happen, device == host replay, exactness
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multirank_crossjob_stealing_exact(devices8):
    devices8("""
        import numpy as np
        from repro.core.job import JobConfig, submit
        from repro.core.steal import fleet_merge, steal_schedule
        from repro.core.usecases import WordCount
        from repro.core.workdomain import WorkDomain
        from repro.distributed.mesh import local_mesh

        P, S, V = 4, 64, 512
        rng = np.random.default_rng(0)
        sizes = (13, 7)
        data = [rng.integers(0, V, size=n * S).astype(np.int32)
                for n in sizes]
        reps = [np.where(rng.random((P, -(-n // P))) < 0.3, 5, 1)
                .astype(np.int32) for n in sizes]
        cfg = JobConfig(usecase=WordCount(vocab=V), backend="1s",
                        task_size=S, push_cap=128, n_procs=P, segment=1,
                        stealing=True)
        mesh = local_mesh((P,), ("procs",))

        solo = [submit(cfg, d, mesh=mesh, repeats=r).result()
                for d, r in zip(data, reps)]

        h0 = submit(cfg, data[0], mesh=mesh, repeats=reps[0])
        h1 = submit(cfg, data[1], mesh=mesh, repeats=reps[1])
        dom = WorkDomain([h0, h1], names=["a", "b"], mesh=mesh)
        while dom.step(1):
            dom.collect_finished()
        dom.collect_finished()
        assert dom.done
        carry = dom.handle._carry
        stolen = np.asarray(carry.stolen)[0]
        assert stolen.sum() > 0, "no cross-rank steals in skewed fleet"

        # every member bit-identical to its solo run
        for h, ref, name in zip([h0, h1], solo, "ab"):
            got = h.result()
            assert got.records == ref.records, name
            assert got.output == ref.output, name

        # host replay, chained segment-by-segment exactly as the device
        # stepped (work0 carries the progress row across segments),
        # reproduces both carry rows bit-for-bit
        ids = dom.handle.feed.task_ids_grid
        rg = dom.handle.feed.repeats_grid
        seg = dom.handle.feed.segment
        slot_work = np.zeros((dom.K,), np.int64)
        work = np.zeros((P,), np.int32)
        for c0 in range(0, ids.shape[1], seg):
            sch = steal_schedule(ids[:, c0:c0 + seg], rg[:, c0:c0 + seg],
                                 work0=work, coslots=dom.K,
                                 costride=dom.stride)
            work = sch.work
            slot_work += sch.slot_work
        np.testing.assert_array_equal(
            slot_work, np.asarray(carry.job_work)[0])
        np.testing.assert_array_equal(work, np.asarray(carry.work)[0])
        print("CROSSJOB-OK", stolen.tolist(), slot_work.tolist())
    """)
