"""Unit tests for the unified Job API (single real device, n_procs=1).

Covers the backend registry (resolution, registration, clear errors),
the submit()/JobHandle lifecycle (oneshot vs segmented equivalence,
step/cursor semantics, structured JobResult), and oracle equality for
every built-in use-case on both built-in backends. The 8-device variants
live in tests/test_engine.py (marked slow).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (Backend, Histogram, InvertedIndex, JobConfig,
                        UnknownBackendError, WordCount, available_backends,
                        get_backend, histogram_oracle, inverted_index_oracle,
                        register_backend, submit, wordcount_oracle)

VOCAB, N, TASK = 200, 8192, 512


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return rng.integers(0, VOCAB, size=N).astype(np.int32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_backends_resolve():
    assert {"1s", "2s"} <= set(available_backends())
    for name in ("1s", "2s"):
        b = get_backend(name)
        assert isinstance(b, Backend)
        assert b.name == name
        assert get_backend(name) is b          # singleton (jit caches)


def test_unknown_backend_clear_error():
    with pytest.raises(UnknownBackendError, match=r"nope.*1s.*2s"):
        get_backend("nope")


def test_register_backend_decorator():
    @register_backend("test-dummy")
    class Dummy:
        def run_job(self, spec, map_fn, mesh, tokens, task_ids, repeats):
            raise NotImplementedError

        def make_segment_fns(self, spec, map_fn, mesh):
            raise NotImplementedError

    try:
        assert get_backend("test-dummy").name == "test-dummy"
        assert "test-dummy" in available_backends()
    finally:
        from repro.core import registry
        registry._REGISTRY.pop("test-dummy", None)
        registry._INSTANCES.pop("test-dummy", None)


def test_submit_rejects_unknown_backend(tokens):
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="3s",
                    n_procs=1)
    with pytest.raises(UnknownBackendError):
        submit(cfg, tokens)


# ---------------------------------------------------------------------------
# JobHandle lifecycle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["1s", "2s"])
def test_oneshot_result_structured(tokens, backend):
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                    task_size=TASK, push_cap=256, n_procs=1)
    res = submit(cfg, tokens).result()
    assert res.records == wordcount_oracle(tokens, VOCAB)
    assert res.output == res.records           # WordCount has no finalize
    assert res.backend == backend
    assert res.n_tasks == N // TASK
    assert res.tasks_per_rank.sum() == res.n_tasks
    assert res.work_per_rank.sum() == res.n_tasks  # all repeats == 1
    assert res.imbalance == 1.0
    assert res.wall_time > 0


@pytest.mark.parametrize("backend", ["1s", "2s"])
def test_segmented_equals_oneshot(tokens, backend):
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                    task_size=TASK, push_cap=256, n_procs=1)
    oneshot = submit(cfg, tokens).result()
    handle = submit(dataclasses.replace(cfg, segment=3), tokens)
    steps = 0
    while handle.step():
        steps += 1
    assert steps == (N // TASK + 2) // 3 - 1   # last step returns False
    res = handle.result()
    assert res.records == oneshot.records
    assert (res.keys == oneshot.keys).all()


def test_step_requires_segmented(tokens):
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                    task_size=TASK, push_cap=256, n_procs=1)
    with pytest.raises(RuntimeError, match="segment"):
        submit(cfg, tokens).step()


def test_result_is_cached(tokens):
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                    task_size=TASK, push_cap=256, n_procs=1)
    h = submit(cfg, tokens)
    assert not h.done
    r1 = h.result()
    assert h.done
    assert h.result() is r1
    assert not h.step()                         # done job refuses to step


# ---------------------------------------------------------------------------
# use-case oracle equality (both backends, oneshot + segmented)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["1s", "2s"])
@pytest.mark.parametrize("segment", [0, 4])
def test_histogram_oracle(tokens, backend, segment):
    uc = Histogram(vocab=VOCAB, n_bins=16)
    cfg = JobConfig(usecase=uc, backend=backend, task_size=TASK,
                    push_cap=TASK, n_procs=1, segment=segment)
    res = submit(cfg, tokens).result()
    np.testing.assert_array_equal(res.output,
                                  histogram_oracle(tokens, VOCAB, 16))


@pytest.mark.parametrize("backend", ["1s", "2s"])
@pytest.mark.parametrize("segment", [0, 4])
def test_inverted_index_oracle(tokens, backend, segment):
    queries = (3, 17, 42, 199)
    uc = InvertedIndex(queries=queries, n_docs=4, tasks_per_doc=4)
    cfg = JobConfig(usecase=uc, backend=backend, task_size=TASK,
                    push_cap=TASK, n_procs=1, segment=segment)
    res = submit(cfg, tokens).result()
    assert res.output == inverted_index_oracle(tokens, queries, TASK, 4, 4)


@pytest.mark.parametrize("backend", ["1s", "2s"])
def test_combine_capacity_consistent_across_modes(tokens, backend):
    """A non-default Combine window must produce identical records in
    oneshot and segmented mode (it used to be honored only by the 1s
    oneshot path). VOCAB=200 keys all occur, so 256 is the smallest
    power-of-two capacity that does NOT overflow — see the overflow
    tests below for the undersized case, which now raises."""
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                    task_size=TASK, push_cap=256, n_procs=1,
                    combine_capacity=256)
    oneshot = submit(cfg, tokens).result()
    seg = submit(dataclasses.replace(cfg, segment=4), tokens).result()
    assert oneshot.records == seg.records
    assert oneshot.combine_overflow == 0
    assert oneshot.records == wordcount_oracle(tokens, VOCAB)


@pytest.mark.parametrize("backend", ["1s", "2s"])
def test_combine_overflow_raises_not_silent(tokens, backend):
    """THE headline bugfix: an undersized combine_capacity used to
    *silently drop* every key past the capacity — result() returned
    wrong counts with no signal. It must now raise, carrying the
    overflow count and the (wrong) partial result for inspection."""
    from repro.core import CombineOverflowError
    oracle = wordcount_oracle(tokens, VOCAB)
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                    task_size=TASK, push_cap=256, n_procs=1,
                    combine_capacity=128)
    h = submit(cfg, tokens)
    with pytest.raises(CombineOverflowError, match="combine_capacity"):
        h.result()
    # the attached partial result is the pre-fix behavior: provably wrong
    try:
        h.result()                          # raises again — never silent
    except CombineOverflowError as e:
        assert e.result.combine_overflow > 0
        assert e.result.records != oracle   # pre-fix counts WERE wrong
        assert sum(e.result.records.values()) < sum(oracle.values())
        # exactly the dropped tail is accounted for
        assert (len(oracle) - len(e.result.records)
                == e.result.combine_overflow)
    assert h.feed._closed                   # stream was still torn down


def test_result_closes_feed_on_engine_error(tokens):
    """A raising segment/finish fn must not leak the feed's prefetch
    thread: result() closes the feed on every exit path."""
    @dataclasses.dataclass(frozen=True)
    class Broken:
        vocab: int

        @property
        def window(self):
            return self.vocab

        def map_emit(self, toks, task_id):
            raise ValueError("boom at trace time")

    cfg = JobConfig(usecase=Broken(vocab=VOCAB), backend="1s",
                    task_size=TASK, push_cap=256, n_procs=1)
    h = submit(cfg, tokens)
    with pytest.raises(ValueError, match="boom"):
        h.result()
    assert h.feed._closed                   # used to stay open forever


def test_jobhandle_context_manager(tokens):
    """``with submit(...) as h`` releases the feed even when the body
    abandons the job mid-stream (no result() ever called)."""
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                    task_size=TASK, push_cap=256, n_procs=1, segment=2)
    with submit(cfg, tokens) as h:
        h.step()
        assert not h.feed._closed
    assert h.feed._closed
    # and the normal full-lifecycle use still works inside the block
    with submit(cfg, tokens) as h2:
        assert h2.result().records == wordcount_oracle(tokens, VOCAB)
    assert h2.feed._closed
    h2.close()                              # idempotent


def test_custom_usecase_with_local_reduce_combiner(tokens):
    """A user-defined use-case exercising the optional combiner hook."""
    import jax.numpy as jnp
    from repro.core.kv import KEY_SENTINEL, local_reduce

    @dataclasses.dataclass(frozen=True)
    class EvenCount:
        vocab: int

        @property
        def window(self):
            return self.vocab

        def map_emit(self, toks, task_id):
            valid = (toks != KEY_SENTINEL) & (toks % 2 == 0)
            keys = jnp.where(valid, toks, KEY_SENTINEL)
            return keys, jnp.where(valid, 1, 0).astype(jnp.int32)

        def local_reduce(self, keys, vals):
            return local_reduce(keys, vals, keys.shape[0])[:2]

    cfg = JobConfig(usecase=EvenCount(vocab=VOCAB), backend="1s",
                    task_size=TASK, push_cap=256, n_procs=1)
    res = submit(cfg, tokens).result()
    evens = tokens[tokens % 2 == 0]
    assert res.records == wordcount_oracle(evens, VOCAB)


# ---------------------------------------------------------------------------
# deprecated shim is gone (was kept one release, removed in PR 9)
# ---------------------------------------------------------------------------

def test_deprecated_shim_removed():
    """The class-based MapReduceJob shim and its lazy __getattr__ hook
    were removed after their one-release migration window: the old names
    must fail loudly (AttributeError / ImportError), not half-work."""
    import importlib.util
    import repro.core
    with pytest.raises(AttributeError, match="MapReduceJob"):
        repro.core.MapReduceJob
    assert importlib.util.find_spec("repro.core.api") is None
    assert importlib.util.find_spec("repro.core.wordcount") is None
    assert "MapReduceJob" not in dir(repro.core)


def test_migrated_wordcount_replaces_shim(tokens):
    """The submit() one-liner the shim's migration table pointed at —
    the exact replacement for the removed subclass-style WordCount."""
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                    task_size=TASK, push_cap=256, n_procs=1)
    res = submit(cfg, tokens).result()
    assert res.records == wordcount_oracle(tokens, VOCAB)
