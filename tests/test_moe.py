"""MoE dispatch: the paper's technique as an in-model feature.

Invariants:
  * "1s" (decoupled pipelined) and "2s" (bulk) dispatch compute the SAME
    function — only the schedule differs (paper: same bytes, overlapped);
  * both match a dense (no-dispatch) oracle that runs every expert on every
    token and mixes with the router gates (when capacity admits all tokens);
  * routing respects top_k; aux loss is the switch load-balancing loss;
  * the sharded (8-device) dispatch matches the unpartitioned reference.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke_config
from repro.models import moe as moe_mod


def _cfg(**kw):
    base = get_smoke_config("llama4-maverick-400b-a17b")
    kw.setdefault("dtype", "float32")
    kw.setdefault("param_dtype", "float32")
    kw.setdefault("capacity_factor", 8.0)     # no drops for oracle equality
    return dataclasses.replace(base, **kw)


def _dense_oracle(cfg, p, x):
    """Every expert on every token, gate-mixed — exact when nothing drops."""
    T, D = x.shape
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", x, p["we_gate"]))
    h = jnp.einsum("td,edf->tef", x, p["we_in"])
    out_all = jnp.einsum("tef,efd->ted", g * h, p["we_out"])
    y = jnp.zeros_like(x)
    for j in range(cfg.top_k):
        y += jnp.take_along_axis(
            out_all, ids[:, j][:, None, None], 1)[:, 0] * gates[:, j][:, None]
    return y


@pytest.mark.parametrize("mode,topk", [("1s", 1), ("2s", 1),
                                       ("1s", 2), ("2s", 2)])
def test_dispatch_matches_dense_oracle(mode, topk):
    cfg = _cfg(dispatch_mode=mode, top_k=topk, dispatch_groups=2)
    p = moe_mod.init_moe(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y, aux = moe_mod.moe_forward(cfg, p, x)
    want = _dense_oracle(cfg, p, x.reshape(-1, cfg.d_model))
    if cfg.n_shared_experts:
        xs = x.reshape(-1, cfg.d_model)
        want = want + (jax.nn.silu(xs @ p["ws_gate"]) * (xs @ p["ws_in"])
                       ) @ p["ws_out"]
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               np.asarray(want), atol=1e-4, rtol=1e-4)
    assert np.isfinite(float(aux))


def test_1s_equals_2s_exactly():
    """The decoupled schedule must be a pure re-ordering: same result."""
    for topk in (1, 2):
        cfg1 = _cfg(dispatch_mode="1s", top_k=topk, dispatch_groups=4)
        cfg2 = dataclasses.replace(cfg1, dispatch_mode="2s")
        p = moe_mod.init_moe(cfg1, jax.random.key(2))
        x = jax.random.normal(jax.random.key(3), (1, 32, cfg1.d_model),
                              jnp.float32)
        y1, a1 = moe_mod.moe_forward(cfg1, p, x)
        y2, a2 = moe_mod.moe_forward(cfg2, p, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_aux_loss_balanced_is_one():
    """Perfectly uniform routing → switch aux loss == 1 (its minimum)."""
    cfg = _cfg(top_k=1)
    E = cfg.n_experts
    T = 64 * E
    probs = jnp.full((T, E), 1.0 / E)
    ids = jnp.tile(jnp.arange(E, dtype=jnp.int32), T // E)[:, None]
    aux = moe_mod._aux_loss(cfg, probs, ids)
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-5)


def test_capacity_drops_keep_residual_semantics():
    """With capacity_factor → 0 almost everything drops; output ≈ 0 (dropped
    tokens contribute nothing — their residual passes through upstream)."""
    cfg = _cfg(dispatch_mode="2s", top_k=1, capacity_factor=0.01,
               n_shared_experts=0)
    p = moe_mod.init_moe(cfg, jax.random.key(4))
    x = jax.random.normal(jax.random.key(5), (1, 64, cfg.d_model),
                          jnp.float32)
    y, _ = moe_mod.moe_forward(cfg, p, x)
    dense = _dense_oracle(cfg, p, x.reshape(-1, cfg.d_model))
    assert float(jnp.abs(y).sum()) < float(jnp.abs(dense).sum())


def test_sharded_dispatch_matches_reference(devices8):
    out = devices8("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.registry import get_smoke_config
        from repro.models import moe as moe_mod
        from repro.distributed.mesh import local_mesh

        base = get_smoke_config("llama4-maverick-400b-a17b")
        for mode in ("1s", "2s"):
            cfg = dataclasses.replace(
                base, dtype="float32", param_dtype="float32",
                dispatch_mode=mode, top_k=2, capacity_factor=8.0,
                dispatch_groups=2)
            p = moe_mod.init_moe(cfg, jax.random.key(0))
            # mesh (data=2, model=4): experts 8 -> 2 per shard; seq 32 -> 8
            mesh = local_mesh((2, 4), ("data", "model"))
            x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model),
                                  jnp.float32)
            y_ref, aux_ref = moe_mod.moe_forward(cfg, p, x)
            y_sh, aux_sh = moe_mod.moe_forward(cfg, p, x, mesh=mesh,
                                               dp_entry="data")
            np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                                       atol=1e-4, rtol=1e-4)
            np.testing.assert_allclose(float(aux_sh), float(aux_ref),
                                       rtol=1e-5)
        # decode path: S=1 token, replicated dispatch
        cfg = dataclasses.replace(base, dtype="float32",
                                  param_dtype="float32", top_k=2,
                                  capacity_factor=8.0)
        p = moe_mod.init_moe(cfg, jax.random.key(2))
        mesh = local_mesh((2, 4), ("data", "model"))
        x1 = jax.random.normal(jax.random.key(3), (2, 1, cfg.d_model),
                               jnp.float32)
        y_ref, _ = moe_mod.moe_forward(cfg, p, x1)
        y_sh, _ = moe_mod.moe_forward(cfg, p, x1, mesh=mesh,
                                      dp_entry="data")
        np.testing.assert_allclose(np.asarray(y_sh), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        print("MOE-SHARDED-OK")
    """)
    assert "MOE-SHARDED-OK" in out
