"""Streaming DataSource API: sources, the SegmentFeed prefetcher, and
streamed-equals-resident exactness through the Job API.

The load-bearing properties pinned here:

  * every DataSource is offset-pure (same bytes whatever the read
    segmentation/order), so the prefetcher may run ahead and restore may
    seek;
  * a streamed job's ``JobResult.records`` is oracle-identical to the
    fully-resident run on BOTH backends — including across a mid-stream
    ``checkpoint()``/``restore()`` and a straggler re-plan;
  * peak host residency of a streamed job is O(segment), not O(dataset)
    (the mmap acceptance criterion);
  * jitted programs are reused across ``submit()`` calls (no per-job
    recompile), and restoring a snapshot into the wrong backend fails
    loudly instead of installing an incompatible carry.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import JobConfig, submit, wordcount_oracle
from repro.core.usecases import WordCount
from repro.data.source import (ArraySource, ConcatSource, MmapTokenSource,
                               ZipfSource, as_source, read_all)

VOCAB, N, TASK = 180, 8192, 512


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(7)
    return rng.integers(0, VOCAB, size=N).astype(np.int32)


@pytest.fixture()
def token_file(tokens, tmp_path):
    path = os.path.join(str(tmp_path), "tokens.bin")
    tokens.tofile(path)
    return path


def _cfg(backend="1s", segment=0, n=1):
    return JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                     task_size=TASK, push_cap=256, n_procs=n,
                     segment=segment)


# ---------------------------------------------------------------------------
# sources: the offset-purity contract
# ---------------------------------------------------------------------------

def _source_matrix(tokens, tmp_path):
    path = os.path.join(str(tmp_path), "m.bin")
    tokens.tofile(path)
    return [
        ArraySource(tokens),
        MmapTokenSource(path),
        ConcatSource([ArraySource(tokens[:3000]),
                      ArraySource(tokens[3000:3001]),
                      ArraySource(tokens[3001:])]),
    ]


def test_sources_len_and_read_all(tokens, tmp_path):
    for src in _source_matrix(tokens, tmp_path):
        assert src.len_elements() == N
        np.testing.assert_array_equal(read_all(src, block=700), tokens)


@pytest.mark.parametrize("offset,size", [(0, 10), (4090, 100), (N - 5, 99),
                                         (N, 4), (0, N)])
def test_sources_read_windows(tokens, tmp_path, offset, size):
    expect = tokens[offset: offset + size]
    for src in _source_matrix(tokens, tmp_path):
        got = src.read(offset, size)
        np.testing.assert_array_equal(got, expect)
        assert got.dtype == np.int32


def test_zipf_source_offset_deterministic():
    src = ZipfSource(10_000, vocab=VOCAB, seed=11, block=512)
    whole = read_all(src)
    assert len(whole) == 10_000
    assert whole.min() >= 0 and whole.max() < VOCAB
    # read order / segmentation never changes the bytes
    rng = np.random.default_rng(0)
    for _ in range(20):
        o = int(rng.integers(0, 10_000))
        s = int(rng.integers(1, 2000))
        np.testing.assert_array_equal(src.read(o, s), whole[o: o + s])
    assert not np.array_equal(whole,
                              read_all(ZipfSource(10_000, VOCAB, seed=12,
                                                  block=512)))


def test_as_source_auto_wraps(tokens):
    assert isinstance(as_source(tokens), ArraySource)
    assert isinstance(as_source(tokens.tolist()), ArraySource)
    src = ZipfSource(100, vocab=VOCAB)          # any DataSource passes through
    assert as_source(src) is src


# ---------------------------------------------------------------------------
# streamed == resident exactness (property-style over sources × backends)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["1s", "2s"])
@pytest.mark.parametrize("kind", ["array", "mmap", "zipf"])
def test_streamed_equals_resident(tokens, tmp_path, backend, kind):
    if kind == "array":
        src = ArraySource(tokens)
    elif kind == "mmap":
        path = os.path.join(str(tmp_path), f"{backend}.bin")
        tokens.tofile(path)
        src = MmapTokenSource(path)
    else:
        src = ZipfSource(N, vocab=VOCAB, seed=3)
    resident = read_all(src)
    oracle = wordcount_oracle(resident, VOCAB)
    # oneshot (one big streamed segment) and segmented must both match
    # the resident-array run exactly
    assert submit(_cfg(backend), src).result().records == oracle
    res = submit(_cfg(backend, segment=3), src).result()
    assert res.records == oracle
    assert submit(_cfg(backend), resident).result().records == oracle


@pytest.mark.parametrize("backend", ["1s", "2s"])
def test_streamed_ckpt_restore_mid_stream(tokens, token_file, tmp_path,
                                          backend):
    from repro.ckpt.checkpoint import CheckpointManager
    oracle = wordcount_oracle(tokens, VOCAB)
    cfg = _cfg(backend, segment=2)
    mgr = CheckpointManager(os.path.join(str(tmp_path), "ck"))
    h = submit(cfg, MmapTokenSource(token_file))
    h.step()
    h.step()
    h.checkpoint(mgr)
    mgr.wait()
    # "crash": a fresh handle on a fresh source seeks — never replays
    src2 = MmapTokenSource(token_file)
    h2 = submit(cfg, src2).restore(mgr)
    assert h2.cursor == 4
    assert h2.result().records == oracle
    consumed = (16 - 4) * TASK * 4          # bytes for remaining tasks only
    assert h2.feed.stats.bytes_read == consumed


@pytest.mark.parametrize("backend", ["1s", "2s"])
def test_streamed_straggler_replan_exact(tokens, token_file, backend):
    """A mid-stream throughput-proportional re-plan re-routes exactly the
    unread tasks; records stay oracle-exact."""
    from repro.ft.straggler import ThroughputTracker, replan_handle
    oracle = wordcount_oracle(tokens, VOCAB)
    h = submit(_cfg(backend, segment=2), MmapTokenSource(token_file))
    h.step()
    before = sorted(h.remaining_task_ids().tolist())
    tr = ThroughputTracker(n_procs=1)
    assign = replan_handle(h, tr)
    assert sorted(assign[assign >= 0].tolist()) == before
    assert h.result().records == oracle


def test_replan_rejects_wrong_task_set(tokens):
    h = submit(_cfg("1s", segment=2), tokens)
    h.step()
    bad = np.array([[0, 1, 2]], np.int32)       # 0,1 already consumed
    with pytest.raises(AssertionError, match="unread"):
        h.replan(bad)


# ---------------------------------------------------------------------------
# memory bound: peak host residency is O(segment), not O(dataset)
# ---------------------------------------------------------------------------

def test_mmap_job_never_fully_resident(tmp_path):
    """The mmap acceptance criterion: a streamed job over a token file
    holds O(segment) host bytes in the feed, never O(dataset)."""
    big_n = 262_144                              # 1 MiB of tokens
    rng = np.random.default_rng(0)
    big = rng.integers(0, VOCAB, size=big_n).astype(np.int32)
    path = os.path.join(str(tmp_path), "big.bin")
    big.tofile(path)
    src = MmapTokenSource(path)
    seg = 2
    res = submit(_cfg("1s", segment=seg), src).result()
    assert res.records == wordcount_oracle(big, VOCAB)
    h = submit(_cfg("1s", segment=seg), src)    # fresh feed for the stats
    while h.step():
        pass
    stats = h.feed.stats
    dataset_bytes = big_n * 4
    segment_bytes = seg * TASK * 4               # one (P=1, seg, S) block
    # at most the consumed segment + the prefetched one live at once
    assert stats.max_live_bytes <= 2 * segment_bytes
    assert stats.max_live_bytes < dataset_bytes / 50
    assert stats.bytes_read >= dataset_bytes     # everything was streamed
    assert stats.prefetch_hits >= stats.segments_built - 2


# ---------------------------------------------------------------------------
# jit-program reuse across submits (no per-job recompile)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["1s", "2s"])
def test_program_reuse_across_submits(tokens, backend):
    """Two submits of an equal JobConfig must share one compiled
    segmented program: ``as_map_fn`` is memoized per (hashable) use-case,
    so the backend's ``("seg", spec, map_fn, mesh)`` memo key hits."""
    cfg = _cfg(backend, segment=4)
    h1 = submit(cfg, tokens)
    h2 = submit(dataclasses.replace(cfg), tokens)  # distinct equal config
    assert h1 is not h2
    assert h1._map_fn is h2._map_fn                # use-case memo hit
    h1._ensure_segmented()
    h2._ensure_segmented()
    assert h1._seg_fns is h2._seg_fns              # backend memo hit
    n_before = len(h1.backend._programs)
    submit(cfg, tokens).result()
    assert len(h1.backend._programs) == n_before   # result() adds none
