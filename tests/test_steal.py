"""Work stealing (core/steal.py): the claim function's exactly-once
property, schedule quality, and end-to-end exactness through the Job API.

Load-bearing properties pinned here:

  * the pure claim function pops every real task slot exactly once for
    *random cursor states* (random grids, padding, repeats and progress
    rows; P in {2, 4, 8}) — the no-dedup exactly-once argument;
  * balanced workloads never pay a single steal (the margin hysteresis);
  * skewed workloads get their work balanced (the fig9 mechanism);
  * a streamed stealing job's records equal the resident run and the
    unsteered 2s output — including across a mid-steal
    checkpoint/restore round-trip (slow, 4-device subprocess).
"""
import os

import numpy as np
import pytest

from repro.core import JobConfig, submit, wordcount_oracle
from repro.core.steal import claim_step, steal_schedule
from repro.core.usecases import WordCount
from repro.data.source import MmapTokenSource, ZipfSource, read_all

VOCAB, N, TASK = 180, 8192, 512


def random_grid(rng, P):
    """Random assignment grid: random width, unique global ids, random
    right-padding per rank, random repeats."""
    T = int(rng.integers(1, 9))
    counts = rng.integers(0, T + 1, size=P)
    if counts.sum() == 0:
        counts[int(rng.integers(0, P))] = 1
    ids = -np.ones((P, T), np.int32)
    pool = rng.permutation(int(counts.sum()))
    k = 0
    for r in range(P):
        ids[r, : counts[r]] = pool[k: k + counts[r]]
        k += counts[r]
    reps = rng.integers(1, 9, size=(P, T)).astype(np.int32)
    return ids, reps


# ---------------------------------------------------------------------------
# the claim function: exactly-once, determinism, hysteresis
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("P", [2, 4, 8])
def test_every_task_claimed_exactly_once(P):
    """Property: over random grids and random initial progress rows, the
    replayed claim executes every real task exactly once — no loss, no
    duplicate, regardless of how skewed the cursor state gets."""
    rng = np.random.default_rng(P)
    for trial in range(25):
        ids, reps = random_grid(rng, P)
        work0 = rng.integers(0, 40, size=P).astype(np.int32)
        sched = steal_schedule(ids, reps, work0=work0)
        executed = sched.exec_ids[sched.exec_ids >= 0]
        expect = ids[ids >= 0]
        assert sorted(executed.tolist()) == sorted(expect.tolist()), (
            f"P={P} trial={trial}: claims lost or duplicated a task")
        # the progress row accounts exactly the executed repeats
        total = {int(i): int(r) for i, r in
                 zip(ids.ravel(), reps.ravel()) if i >= 0}
        assert int((sched.work - work0).sum()) == sum(total.values())


@pytest.mark.parametrize("P", [2, 4, 8])
def test_claim_step_respects_cursor_ranges(P):
    """One round over random cursors: every claim addresses a slot
    inside some rank's [head, tail) range, claims are distinct slots,
    and the new cursors pop exactly the claimed slots."""
    rng = np.random.default_rng(100 + P)
    for _ in range(50):
        tail0 = rng.integers(0, 10, size=P)
        head0 = np.array([rng.integers(0, t + 1) for t in tail0])
        work = rng.integers(0, 30, size=P)
        sr, sc, head, tail = (np.asarray(x) for x in claim_step(
            head0.astype(np.int32), tail0.astype(np.int32),
            work.astype(np.int32)))
        claimed = [(int(r), int(c)) for r, c in zip(sr, sc) if r >= 0]
        assert len(set(claimed)) == len(claimed)        # distinct slots
        for r, c in claimed:
            assert head0[r] <= c < tail0[r]
        # cursors shrink by exactly the number of claims per rank
        popped = np.bincount([r for r, _ in claimed], minlength=P)
        np.testing.assert_array_equal(
            (head - head0) + (tail0 - tail), popped)
        # nobody idles while any deque still has tasks
        n_idle = int((sr < 0).sum())
        remaining = int((tail - head).sum())
        assert n_idle == 0 or remaining == 0


def test_claim_deterministic_across_calls():
    head = np.zeros(4, np.int32)
    tail = np.array([3, 5, 2, 4], np.int32)
    work = np.array([9, 0, 4, 27], np.int32)
    a = [np.asarray(x) for x in claim_step(head, tail, work)]
    b = [np.asarray(x) for x in claim_step(head, tail, work)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_balanced_workload_never_steals():
    ids = np.arange(32, dtype=np.int32).reshape(4, 8)
    reps = np.ones((4, 8), np.int32)
    sched = steal_schedule(ids, reps)
    assert sched.n_stolen == 0
    # everyone just walked their own list in order
    np.testing.assert_array_equal(sched.exec_ids, ids)


def test_skewed_workload_balances_and_packs():
    """The fig9 mechanism: a hot rank's tasks migrate to ranks that ran
    ahead, so per-rank work evens out AND the lockstep makespan
    (sum of per-step maxima) drops."""
    P, T = 4, 8
    ids = np.arange(P * T, dtype=np.int32).reshape(P, T)
    reps = np.ones((P, T), np.int32)
    reps[0] = 8                               # rank 0 is hot
    sched = steal_schedule(ids, reps)
    assert sched.n_stolen > 0
    assert sched.work.max() / sched.work.mean() < 1.15
    makespan = sched.exec_reps.max(axis=0).sum()
    assert makespan < reps.max(axis=0).sum() * 0.6


# ---------------------------------------------------------------------------
# Job API: exactness with stealing on (single device, P=1 fast path)
# ---------------------------------------------------------------------------

def _cfg(segment=0, stealing=True, backend="1s"):
    return JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                     task_size=TASK, push_cap=256, n_procs=1,
                     segment=segment, stealing=stealing)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(21)
    return rng.integers(0, VOCAB, size=N).astype(np.int32)


@pytest.mark.parametrize("kind", ["array", "mmap", "zipf"])
def test_streamed_equals_resident_with_stealing(tokens, tmp_path, kind):
    if kind == "array":
        src = tokens
    elif kind == "mmap":
        path = os.path.join(str(tmp_path), "steal.bin")
        tokens.tofile(path)
        src = MmapTokenSource(path)
    else:
        src = ZipfSource(N, vocab=VOCAB, seed=4)
    resident = read_all(src) if kind != "array" else tokens
    oracle = wordcount_oracle(resident, VOCAB)
    assert submit(_cfg(), src).result().records == oracle
    res = submit(_cfg(segment=3), src).result()
    assert res.records == oracle
    assert res.n_steals == 0                  # P=1: nothing to steal from


def test_stealing_checkpoint_restore_round_trip(tokens, tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    oracle = wordcount_oracle(tokens, VOCAB)
    cfg = _cfg(segment=2)
    mgr = CheckpointManager(os.path.join(str(tmp_path), "ck"))
    h = submit(cfg, tokens)
    h.step()
    h.step()
    h.checkpoint(mgr)
    mgr.wait()
    _, extra = mgr.peek()
    assert extra["stealing"] is True
    h2 = submit(cfg, tokens).restore(mgr)
    assert h2.cursor == 4
    assert h2.result().records == oracle


def test_restore_rejects_stealing_mismatch(tokens, tmp_path):
    """A snapshot's claim-state accounting is only meaningful in the
    mode that produced it — restoring across a stealing mismatch must
    fail loudly (like the backend guard), not corrupt the stats."""
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(os.path.join(str(tmp_path), "ck"))
    h = submit(_cfg(segment=2, stealing=False), tokens)
    h.step()
    h.checkpoint(mgr)
    mgr.wait()
    with pytest.raises(ValueError, match="stealing"):
        submit(_cfg(segment=2, stealing=True), tokens).restore(mgr)


def test_stealing_rejected_on_backends_without_support(tokens):
    with pytest.raises(ValueError, match="stealing"):
        submit(_cfg(backend="2s"), tokens)


def test_outer_rebalance_is_the_coarse_loop(tokens):
    """Host re-planning over a stealing handle only fires on persistent
    drift; fine-grained skew is left to the in-scan claims."""
    from repro.ft.straggler import ThroughputTracker, outer_rebalance
    h = submit(_cfg(segment=2), tokens)
    h.step()
    tr = ThroughputTracker(n_procs=1)
    # balanced tracker + stealing handle: boundary left untouched
    assert outer_rebalance(h, tr) is None
    # drift past the threshold triggers the coarse re-plan of exactly
    # the unread tasks
    before = sorted(h.remaining_task_ids().tolist())
    grid = outer_rebalance(h, tr, drift_threshold=0.5)
    assert grid is not None
    assert sorted(grid[grid >= 0].tolist()) == before
    assert h.result().records == wordcount_oracle(tokens, VOCAB)


def test_jobresult_has_steal_stats(tokens):
    res = submit(_cfg(), tokens).result()
    assert res.steals_per_rank.shape == (1,)
    assert res.n_steals == 0
    assert res.work_per_rank.sum() == res.n_tasks   # all repeats == 1


# ---------------------------------------------------------------------------
# multi-rank: device schedule == host replay, exact vs unsteered 2s,
# mid-steal checkpoint (8-device subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multirank_stealing_exact_and_matches_replay(devices8, tmp_path):
    out = devices8(f"""
        import numpy as np
        from repro.core import JobConfig, submit
        from repro.core.planner import plan_input, shard_task_ids
        from repro.core.steal import steal_schedule
        from repro.core.usecases import WordCount
        from repro.ckpt.checkpoint import CheckpointManager

        VOCAB, N, TASK, P = 300, 16384, 512, 4
        rng = np.random.default_rng(5)
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        plan = plan_input(N, TASK, P)
        reps = np.ones((P, plan.tasks_per_proc), np.int32)
        reps[0] = 8                                  # hot rank
        base = JobConfig(usecase=WordCount(vocab=VOCAB), backend="2s",
                         task_size=TASK, push_cap=512, n_procs=P)
        r2 = submit(base, tokens, repeats=reps).result()
        st_cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                           task_size=TASK, push_cap=512, n_procs=P,
                           stealing=True)
        rs = submit(st_cfg, tokens, repeats=reps).result()
        # oracle-exact: identical to the unsteered 2s output
        assert rs.records == r2.records
        assert rs.n_steals > 0
        # the device scan realizes the host-replayed schedule bit-for-bit
        sched = steal_schedule(shard_task_ids(plan), reps)
        assert np.array_equal(sched.work, rs.work_per_rank)
        assert np.array_equal(sched.stolen, rs.steals_per_rank)

        # mid-steal checkpoint: snapshot while claim state is live,
        # restore into a fresh handle, finish — still exact, and the
        # final progress row matches the uninterrupted run
        seg_cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                            task_size=TASK, push_cap=512, n_procs=P,
                            segment=2, stealing=True)
        full = submit(seg_cfg, tokens, repeats=reps)
        while full.step():
            pass
        ref = full.result()
        assert ref.records == r2.records
        mgr = CheckpointManager({str(tmp_path)!r})
        h = submit(seg_cfg, tokens, repeats=reps)
        h.step()
        h.checkpoint(mgr)
        mgr.wait()
        assert np.asarray(h.carry.work).any()        # claim state is live
        h2 = submit(seg_cfg, tokens, repeats=reps).restore(mgr)
        res = h2.result()
        assert res.records == r2.records
        assert np.array_equal(res.work_per_rank, ref.work_per_rank)
        assert np.array_equal(res.steals_per_rank, ref.steals_per_rank)
        print("STEAL-OK", int(rs.n_steals), rs.work_per_rank.tolist())
    """, n_devices=4)
    assert "STEAL-OK" in out
