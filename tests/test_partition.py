"""Skew-aware reduce partitioning (core/partition.py) + its Job API
threading.

The load-bearing properties pinned here:

  * the hash partitioner's dense map is bit-identical to the paper's
    ``hash(key) % P`` rule, so "hash" stays the exact seed behavior;
  * the sampled greedy packing provably flattens owner loads vs hash on
    a skewed histogram, and hot-key splitting assigns k > 1 owners whose
    replicas the device-side lookup spreads by task id;
  * **exactness matrix**: for every partitioner, streamed == resident
    and sampled == hash record-identical outputs on array/mmap/zipf
    sources, on both backends — partitioning is a placement decision,
    never a semantics decision (Combine's dup-sum merges split
    partials);
  * a mid-stream checkpoint/restore with a non-default partitioner
    resumes exactly (the owner map rides the carry snapshot), and
    restoring into a handle with a *different* partitioner fails
    loudly, like the backend / stealing guards.

The multi-rank variant (owner maps actually re-routing the push
shuffle, splits active) lives in the slow 8-device subprocess test at
the bottom.
"""
import os

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (HashPartitioner, JobConfig, Partitioner,
                        SampledPartitioner, submit, wordcount_oracle)
from repro.core.kv import KEY_SENTINEL, owner_of
from repro.core.partition import (available_partitioners, hash_owner_map,
                                  lookup_owner, owner_loads,
                                  resolve_partitioner,
                                  sample_key_histogram)
from repro.core.usecases import WordCount
from repro.data.source import MmapTokenSource, ZipfSource, read_all

VOCAB, N, TASK = 180, 8192, 512


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(21)
    return rng.integers(0, VOCAB, size=N).astype(np.int32)


def _cfg(partitioner, backend="1s", segment=0):
    return JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                     task_size=TASK, push_cap=256, n_procs=1,
                     segment=segment, partitioner=partitioner)


# ---------------------------------------------------------------------------
# the maps themselves (host-side, no engine)
# ---------------------------------------------------------------------------

def test_hash_map_bit_identical_to_modulo_rule():
    for P in (1, 2, 7, 8):
        omap = hash_owner_map(4096, P)
        ref = np.asarray(owner_of(jnp.arange(4096, dtype=jnp.int32), P))
        np.testing.assert_array_equal(omap, ref)
    omap, osplit = HashPartitioner().build(np.zeros(64), 4)
    np.testing.assert_array_equal(omap, hash_owner_map(64, 4))
    assert (osplit == 1).all()


def test_resolve_partitioner_names_instances_and_errors():
    assert available_partitioners() == ["hash", "sampled", "sampled+split"]
    assert resolve_partitioner("hash").name == "hash"
    assert resolve_partitioner("sampled").name == "sampled"
    assert resolve_partitioner("sampled+split").split
    custom = SampledPartitioner(sample_tasks=4, split=True,
                                split_threshold=0.1)
    assert resolve_partitioner(custom) is custom
    assert isinstance(custom, Partitioner)
    with pytest.raises(ValueError, match="unknown partitioner.*nope"):
        resolve_partitioner("nope")
    with pytest.raises(TypeError, match="not a Partitioner"):
        resolve_partitioner(42)


def test_sampled_build_flattens_skewed_loads():
    """Greedy LPT on a Zipf-ish histogram must beat hash placement by a
    wide margin (that is the whole point of the subsystem)."""
    P, vocab = 8, 512
    rng = np.random.default_rng(5)
    hist = np.zeros(vocab)
    ranks = rng.permutation(vocab)[:200]
    # skewed presence, but no single key above the per-rank target —
    # the regime greedy LPT can fully flatten without splitting
    hist[ranks] = 100.0 / (1 + np.arange(200)) ** 0.7
    omap, osplit = SampledPartitioner().build(hist, P)
    assert omap.shape == (vocab,) and osplit.shape == (vocab,)
    assert ((omap >= 0) & (omap < P)).all()
    assert (osplit == 1).all()                          # no splitting here
    # unobserved keys keep the hash owner (the map stays total)
    unseen = np.setdiff1d(np.arange(vocab), ranks)
    np.testing.assert_array_equal(omap[unseen],
                                  hash_owner_map(vocab, P)[unseen])
    load_hash = owner_loads(hist, hash_owner_map(vocab, P),
                            np.ones(vocab, np.int32), P)
    load_samp = owner_loads(hist, omap, osplit, P)
    assert np.isclose(load_hash.sum(), load_samp.sum())  # records conserved
    imb_hash = load_hash.max() / load_hash.mean()
    imb_samp = load_samp.max() / load_samp.mean()
    assert imb_samp < imb_hash
    assert imb_samp < 1.05                              # near-perfect pack


def test_split_breaks_single_hot_key_bound():
    """One dominant key caps what any no-split packing can achieve;
    splitting must beat that bound by dividing the key across owners."""
    P, vocab = 8, 64
    hist = np.ones(vocab)
    hist[3] = 1000.0                                     # one hot key
    no_split = SampledPartitioner()
    omap0, osplit0 = no_split.build(hist, P)
    load0 = owner_loads(hist, omap0, osplit0, P)
    assert load0.max() >= 1000.0                        # pinned to one owner
    sp = SampledPartitioner(split=True)
    omap1, osplit1 = sp.build(hist, P)
    assert osplit1[3] > 1                               # hot key is split
    assert (osplit1[np.arange(vocab) != 3] == 1).all()
    load1 = owner_loads(hist, omap1, osplit1, P)
    assert np.isclose(load0.sum(), load1.sum())
    assert load1.max() < load0.max() / 2                # bound broken
    assert load1.max() / load1.mean() < 1.5


def test_lookup_owner_spreads_split_keys_by_task():
    P, vocab = 8, 32
    omap = np.zeros(vocab, np.int32)
    omap[5] = 3
    osplit = np.ones(vocab, np.int32)
    osplit[5] = 4                                        # replicas 3,4,5,6
    keys = jnp.asarray([5, 7, int(KEY_SENTINEL), 5], jnp.int32)
    seen = set()
    for tid in range(32):
        owners = np.asarray(lookup_owner(
            jnp.asarray(omap), jnp.asarray(osplit), keys,
            jnp.int32(tid), P))
        assert owners[1] == omap[7]                     # non-split: the map
        assert owners[2] == P                           # sentinel: ghost
        assert owners[0] == owners[3]                   # same task agrees
        assert 3 <= owners[0] <= 6                      # inside the replicas
        seen.add(int(owners[0]))
    assert len(seen) == 4                               # all replicas used


def test_sample_key_histogram_counts_task_presence(tokens):
    """hist[key] = number of sampled tasks containing the key (each task
    pushes at most one record per key), never raw frequency."""
    from repro.core.planner import plan_input, read_tasks
    from repro.data.source import ArraySource
    plan = plan_input(N, TASK, 1)
    src = ArraySource(tokens)
    hist = sample_key_histogram(
        lambda ids: read_tasks(src, plan, ids),
        plan, WordCount(vocab=VOCAB), n_sample=plan.n_tasks)
    expect = np.zeros(VOCAB, np.int64)
    for t in range(plan.n_tasks):
        np.add.at(expect, np.unique(tokens[t * TASK:(t + 1) * TASK]), 1)
    np.testing.assert_array_equal(hist, expect)
    assert hist.max() <= plan.n_tasks


# ---------------------------------------------------------------------------
# exactness matrix: sampled == hash == oracle over sources × backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["1s", "2s"])
@pytest.mark.parametrize("kind", ["array", "mmap", "zipf"])
def test_partitioner_exactness_matrix(tokens, tmp_path, backend, kind):
    if kind == "array":
        src = tokens
    elif kind == "mmap":
        path = os.path.join(str(tmp_path), f"{backend}.bin")
        tokens.tofile(path)
        src = MmapTokenSource(path)
    else:
        src = ZipfSource(N, vocab=VOCAB, seed=9)
    oracle = wordcount_oracle(
        read_all(src) if kind != "array" else tokens, VOCAB)
    h0 = submit(_cfg("hash", backend), src)
    base = h0.result()
    assert base.records == oracle
    assert base.partitioner == "hash"
    assert h0.feed.stats.sample_tasks_read == 0      # hash: no pre-pass
    for part in ("sampled", "sampled+split",
                 SampledPartitioner(sample_tasks=5, split=True,
                                    split_threshold=0.05)):
        h = submit(_cfg(part, backend, segment=3), src)
        res = h.result()
        assert res.records == oracle, part              # record-identical
        assert res.partitioner == resolve_partitioner(part).name
        assert h.feed.stats.sample_tasks_read > 0       # pre-pass accounted


def test_sampled_stats_and_custom_threshold(tokens):
    """The sampling pre-pass reads through the feed (bytes + task count
    land in FeedStats); an aggressive split threshold forces splits even
    at P=1 config scale... except P=1 can't split — assert the guard."""
    h = submit(_cfg(SampledPartitioner(sample_tasks=6)), tokens)
    res = h.result()
    assert res.records == wordcount_oracle(tokens, VOCAB)
    assert h.feed.stats.sample_tasks_read == 6
    assert res.n_split_keys == 0                        # P=1: nothing to split


# ---------------------------------------------------------------------------
# checkpoint / restore with a non-default partitioner
# ---------------------------------------------------------------------------

def test_ckpt_restore_mid_stream_with_sampled_partitioner(tokens,
                                                          tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    oracle = wordcount_oracle(tokens, VOCAB)
    path = os.path.join(str(tmp_path), "t.bin")
    tokens.tofile(path)
    cfg = _cfg("sampled+split", segment=2)
    mgr = CheckpointManager(os.path.join(str(tmp_path), "ck"))
    h = submit(cfg, MmapTokenSource(path))
    h.step()
    h.step()
    h.checkpoint(mgr)
    mgr.wait()
    # fresh process analogue: restore must resume with the *snapshot's*
    # owner map (carry data), not a freshly re-sampled one
    h2 = submit(cfg, MmapTokenSource(path)).restore(mgr)
    assert h2.cursor == 4
    np.testing.assert_array_equal(np.asarray(h2.carry.owner_map),
                                  np.asarray(h.carry.owner_map))
    assert h2.result().records == oracle


def test_restore_rejects_partitioner_mismatch(tokens, tmp_path):
    from repro.ckpt.checkpoint import CheckpointManager
    mgr = CheckpointManager(os.path.join(str(tmp_path), "ck"))
    h = submit(_cfg("sampled", segment=2), tokens)
    h.step()
    h.checkpoint(mgr)
    mgr.wait()
    h2 = submit(_cfg("hash", segment=2), tokens)
    with pytest.raises(ValueError, match="partitioner='sampled'"):
        h2.restore(mgr)


def test_submit_rejects_unknown_partitioner(tokens):
    with pytest.raises(ValueError, match="unknown partitioner"):
        submit(_cfg("zipf-magic"), tokens)


def test_window_override_sizes_owner_map_from_spec(tokens, tmp_path):
    """A JobConfig(window=...) override widens the engine window past
    usecase.window; the sampled owner map must match the ENGINE's shape
    (else the first step silently retraces and a checkpoint restore
    crashes on a carry shape mismatch)."""
    from repro.ckpt.checkpoint import CheckpointManager
    import dataclasses as dc
    cfg = dc.replace(_cfg("sampled", segment=2), window=256)  # > VOCAB=180
    h = submit(cfg, tokens)
    h.step()
    assert np.asarray(h.carry.owner_map).shape == (1, 256)
    mgr = CheckpointManager(os.path.join(str(tmp_path), "ck"))
    h.checkpoint(mgr)
    mgr.wait()
    h2 = submit(cfg, tokens).restore(mgr)     # same-shape carry: no crash
    assert h2.result().records == wordcount_oracle(tokens, VOCAB)


def test_one_compiled_engine_serves_every_partitioner(tokens):
    """The owner map is carry data, not program structure: submits that
    differ ONLY in partitioner must share one compiled segmented program
    (JobSpec.partitioner is a provenance tag excluded from the memo
    key)."""
    h1 = submit(_cfg("hash", segment=4), tokens)
    h2 = submit(_cfg("sampled", segment=4), tokens)
    h3 = submit(_cfg("sampled+split", segment=4), tokens)
    for h in (h1, h2, h3):
        h._ensure_segmented()
    assert h1._seg_fns is h2._seg_fns is h3._seg_fns
    assert h1.spec == h2.spec                 # eq ignores the tag...
    assert h2.spec.partitioner == "sampled"   # ...but the tag is intact


# ---------------------------------------------------------------------------
# multi-rank: the owner map actually re-routes the shuffle (slow, 8 dev)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multirank_partitioners_exact_and_balanced(devices8):
    out = devices8("""
        import numpy as np
        from repro.core import (JobConfig, SampledPartitioner, submit,
                                wordcount_oracle)
        from repro.core.partition import hash_owner_map
        from repro.core.usecases import WordCount
        from repro.data.source import ZipfSource, read_all

        P, N, VOCAB, TASK = 8, 131072, 512, 1024
        src = ZipfSource(N, vocab=VOCAB, a=1.6, seed=4)
        oracle = wordcount_oracle(read_all(src), VOCAB)
        results = {}
        for part in ("hash", "sampled",
                     SampledPartitioner(split=True, split_threshold=0.05)):
            for stealing in (False, True):
                cfg = JobConfig(usecase=WordCount(vocab=VOCAB),
                                backend="1s", task_size=TASK,
                                push_cap=128, n_procs=P, segment=16,
                                partitioner=part, stealing=stealing)
                res = submit(cfg, src).result()
                assert res.records == oracle, (part, stealing)
                results[(str(part), stealing)] = res
            cfg2 = JobConfig(usecase=WordCount(vocab=VOCAB), backend="2s",
                             task_size=TASK, push_cap=128, n_procs=P,
                             partitioner=part)
            assert submit(cfg2, src).result().records == oracle, part

        # the sampled map must differ from hash (it re-routed the push
        # shuffle) and the split variant must have split something on a
        # Zipf-1.6 corpus at this vocab/P
        h = submit(JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                             task_size=TASK, push_cap=128, n_procs=P,
                             segment=16,
                             partitioner=SampledPartitioner(
                                 split=True, split_threshold=0.05)), src)
        h._ensure_engine()
        h._ensure_owner_map()
        omap = np.asarray(h.carry.owner_map)[0]
        osplit = np.asarray(h.carry.owner_split)[0]
        h.close()
        assert (omap != hash_owner_map(VOCAB, P)).any()
        assert (osplit > 1).any(), "no hot key split at zipf a=1.6"
        print("PARTITION-MATRIX-OK nsplit=%d" % int((osplit > 1).sum()))
    """)
    assert "PARTITION-MATRIX-OK" in out
