"""Integration tests for the MapReduce engines on an 8-device mesh.

Each test spawns one subprocess with 8 placeholder CPU devices (the main
pytest process keeps the single real device, per the dry-run isolation
rule) and verifies exact results vs a host oracle.
"""
import pytest


def test_wordcount_both_backends_exact(devices8):
    out = devices8("""
        import numpy as np
        from collections import Counter
        from repro.core.wordcount import WordCount
        rng = np.random.default_rng(0)
        for VOCAB, N, task, cap in [(1000, 65536, 2048, 1024),
                                    (127, 8192, 512, 64),
                                    (4096, 50000, 1250, 256)]:
            tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
            oracle = dict(Counter(tokens.tolist()))
            for backend in ("1s", "2s"):
                job = WordCount(backend=backend)
                job.init(tokens, vocab=VOCAB, task_size=task, push_cap=cap,
                         n_procs=8)
                job.run()
                assert job.result_dict() == oracle, (VOCAB, N, backend)
        print("EXACT")
    """)
    assert "EXACT" in out


def test_wordcount_unbalanced_workload_exact(devices8):
    """The paper's imbalance model (footnote 5): a task is *computed*
    ``repeat`` times while its input is read once — so the result must stay
    exactly the balanced result, for both engines."""
    out = devices8("""
        import numpy as np
        from collections import Counter
        from repro.core.wordcount import WordCount
        from repro.data.corpus import imbalance_repeats
        rng = np.random.default_rng(1)
        VOCAB, N, P = 500, 32768, 8
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        task = 512
        T = N // task // P
        reps = imbalance_repeats(P, T, mode="unbalanced", hot_factor=4,
                                 hot_fraction=0.25)
        assert reps.max() == 4 and reps.min() == 1
        oracle = dict(Counter(tokens.tolist()))
        for backend in ("1s", "2s"):
            job = WordCount(backend=backend)
            job.init(tokens, vocab=VOCAB, task_size=task, push_cap=2048,
                     n_procs=P, repeats=reps)
            job.run()
            assert job.result_dict() == oracle, backend
        print("EXACT-UNBALANCED")
    """)
    assert "EXACT-UNBALANCED" in out


def test_backends_agree_and_sorted(devices8):
    out = devices8("""
        import numpy as np
        from repro.core.wordcount import WordCount
        from repro.core.kv import KEY_SENTINEL
        rng = np.random.default_rng(7)
        tokens = rng.integers(0, 300, size=16384).astype(np.int32)
        res = {}
        for backend in ("1s", "2s"):
            job = WordCount(backend=backend)
            job.init(tokens, vocab=300, task_size=1024, push_cap=512,
                     n_procs=8)
            keys, vals = job.run()
            valid = keys != int(KEY_SENTINEL)
            assert (np.diff(keys[valid]) > 0).all()   # Combine returns sorted
            res[backend] = (keys[valid].tolist(), vals[valid].tolist())
        assert res["1s"] == res["2s"]
        print("AGREE")
    """)
    assert "AGREE" in out


def test_push_cap_overflow_ownership_transfer(devices8):
    """With a tiny push_cap most records overflow → stay owner-local and be
    folded during Combine (paper footnote 2). Result must stay exact."""
    out = devices8("""
        import numpy as np
        from collections import Counter
        from repro.core.wordcount import WordCount
        rng = np.random.default_rng(2)
        # skewed keys: heavy hitters overflow the per-owner bucket cap
        tokens = rng.zipf(1.2, size=32768).astype(np.int32) % 100
        tokens = tokens.astype(np.int32)
        oracle = dict(Counter(tokens.tolist()))
        for backend in ("1s", "2s"):
            job = WordCount(backend=backend)
            job.init(tokens, vocab=100, task_size=1024, push_cap=4,
                     n_procs=8)
            job.run()
            assert job.result_dict() == oracle, backend
        print("OVERFLOW-EXACT")
    """)
    assert "OVERFLOW-EXACT" in out


def test_segmented_engine_matches_monolithic(devices8):
    """run_segments (the checkpointable path) == run_job, segment by
    segment, including a simulated restart from a mid-job snapshot."""
    out = devices8("""
        import numpy as np, jax
        from collections import Counter
        from repro.core import onesided
        from repro.core.api import JobSpec
        from repro.core.wordcount import WordCount
        from repro.core.kv import KEY_SENTINEL
        from repro.distributed.mesh import local_mesh

        rng = np.random.default_rng(5)
        VOCAB, N, P, task = 400, 32768, 8, 512
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        oracle = dict(Counter(tokens.tolist()))

        job = WordCount(backend="1s")
        job.init(tokens, vocab=VOCAB, task_size=task, push_cap=1024,
                 n_procs=P)
        spec, mesh = job.spec, job.mesh
        toks, reps = job._tokens, job._repeats
        T = toks.shape[1]
        init_fn, seg_fn, fin_fn = onesided.make_segment_fns(
            spec, job.map_task, mesh)
        carry = init_fn()
        seg = 2
        snapshots = []
        for s in range(0, T, seg):
            tok_s = toks[:, s:s + seg]
            rep_s = reps[:, s:s + seg]
            carry = seg_fn(carry, tok_s, rep_s)
            snapshots.append(jax.tree.map(np.asarray, carry))
        keys, vals = fin_fn(carry)
        keys, vals = np.asarray(keys)[0], np.asarray(vals)[0]
        valid = keys != int(KEY_SENTINEL)
        got = dict(zip(keys[valid].tolist(), vals[valid].tolist()))
        assert got == oracle, "segmented != oracle"

        # restart: resume from snapshot after segment 1 and replay the rest
        carry2 = jax.tree.map(lambda a: a, snapshots[0])   # restored copy
        for s in range(seg, T, seg):
            carry2 = seg_fn(carry2, toks[:, s:s+seg], reps[:, s:s+seg])
        k2, v2 = fin_fn(carry2)
        k2, v2 = np.asarray(k2)[0], np.asarray(v2)[0]
        assert (k2 == keys).all() and (v2 == vals).all(), "restart mismatch"
        print("SEGMENTED-EXACT")
    """, timeout=560)
    assert "SEGMENTED-EXACT" in out


def test_tree_combine_multiproc_sorted_merge(devices8):
    out = devices8("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.core.combine import tree_combine
        from repro.core.kv import KEY_SENTINEL
        from repro.distributed.mesh import local_mesh
        mesh = local_mesh((8,), ("procs",))
        rng = np.random.default_rng(11)
        # per-proc sorted unique keys; capacity W covers the merged union
        K, W = 32, 256
        keys = np.full((8, W), int(KEY_SENTINEL), np.int32)
        vals = np.zeros((8, W), np.int32)
        oracle = {}
        for p in range(8):
            ks = np.sort(rng.choice(200, size=rng.integers(5, K),
                                    replace=False)).astype(np.int32)
            keys[p, :len(ks)] = ks
            vals[p, :len(ks)] = p + 1
            for k in ks:
                oracle[int(k)] = oracle.get(int(k), 0) + p + 1

        def body(k, v):
            kk, vv = tree_combine(k[0], v[0], "procs", 8)
            return kk[None], vv[None]

        fn = jax.jit(jax.shard_map(body, mesh=mesh,
                                   in_specs=(P("procs"), P("procs")),
                                   out_specs=(P("procs"), P("procs"))))
        ok, ov = fn(keys, vals)
        ok, ov = np.asarray(ok)[0], np.asarray(ov)[0]
        valid = ok != int(KEY_SENTINEL)
        got = dict(zip(ok[valid].tolist(), ov[valid].tolist()))
        assert got == oracle
        assert (np.diff(ok[valid]) > 0).all()
        print("COMBINE-OK")
    """)
    assert "COMBINE-OK" in out
