"""Integration tests for the MapReduce engines on an 8-device mesh.

Each test spawns one subprocess with 8 placeholder CPU devices (the main
pytest process keeps the single real device, per the dry-run isolation
rule) and verifies exact results vs a host oracle, through the unified
``submit()/JobHandle`` API.
"""
import pytest

pytestmark = pytest.mark.slow


def test_wordcount_both_backends_exact(devices8):
    out = devices8("""
        import numpy as np
        from collections import Counter
        from repro.core import JobConfig, submit
        from repro.core.usecases import WordCount
        rng = np.random.default_rng(0)
        for VOCAB, N, task, cap in [(1000, 65536, 2048, 1024),
                                    (127, 8192, 512, 64),
                                    (4096, 50000, 1250, 256)]:
            tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
            oracle = dict(Counter(tokens.tolist()))
            for backend in ("1s", "2s"):
                cfg = JobConfig(usecase=WordCount(vocab=VOCAB),
                                backend=backend, task_size=task,
                                push_cap=cap, n_procs=8)
                res = submit(cfg, tokens).result()
                assert res.records == oracle, (VOCAB, N, backend)
                assert res.n_tasks == (N + task - 1) // task
                assert res.tasks_per_rank.sum() == res.n_tasks
        print("EXACT")
    """)
    assert "EXACT" in out


def test_wordcount_unbalanced_workload_exact(devices8):
    """The paper's imbalance model (footnote 5): a task is *computed*
    ``repeat`` times while its input is read once — so the result must stay
    exactly the balanced result, for both engines. The JobResult must also
    expose the imbalance it ran under."""
    out = devices8("""
        import numpy as np
        from collections import Counter
        from repro.core import JobConfig, submit
        from repro.core.usecases import WordCount
        from repro.data.corpus import imbalance_repeats
        rng = np.random.default_rng(1)
        VOCAB, N, P = 500, 32768, 8
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        task = 512
        T = N // task // P
        reps = imbalance_repeats(P, T, mode="unbalanced", hot_factor=4,
                                 hot_fraction=0.25)
        assert reps.max() == 4 and reps.min() == 1
        oracle = dict(Counter(tokens.tolist()))
        for backend in ("1s", "2s"):
            cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                            task_size=task, push_cap=2048, n_procs=P)
            res = submit(cfg, tokens, repeats=reps).result()
            assert res.records == oracle, backend
            assert res.imbalance > 1.0
        print("EXACT-UNBALANCED")
    """)
    assert "EXACT-UNBALANCED" in out


def test_backends_agree_and_sorted(devices8):
    out = devices8("""
        import numpy as np
        from repro.core import JobConfig, submit
        from repro.core.usecases import WordCount
        from repro.core.kv import KEY_SENTINEL
        rng = np.random.default_rng(7)
        tokens = rng.integers(0, 300, size=16384).astype(np.int32)
        res = {}
        for backend in ("1s", "2s"):
            cfg = JobConfig(usecase=WordCount(vocab=300), backend=backend,
                            task_size=1024, push_cap=512, n_procs=8)
            r = submit(cfg, tokens).result()
            valid = r.keys != int(KEY_SENTINEL)
            assert (np.diff(r.keys[valid]) > 0).all()  # Combine sorts
            res[backend] = (r.keys[valid].tolist(), r.values[valid].tolist())
        assert res["1s"] == res["2s"]
        print("AGREE")
    """)
    assert "AGREE" in out


def test_push_cap_overflow_ownership_transfer(devices8):
    """With a tiny push_cap most records overflow → stay owner-local and be
    folded during Combine (paper footnote 2). Result must stay exact."""
    out = devices8("""
        import numpy as np
        from collections import Counter
        from repro.core import JobConfig, submit
        from repro.core.usecases import WordCount
        rng = np.random.default_rng(2)
        # skewed keys: heavy hitters overflow the per-owner bucket cap
        tokens = rng.zipf(1.2, size=32768).astype(np.int32) % 100
        tokens = tokens.astype(np.int32)
        oracle = dict(Counter(tokens.tolist()))
        for backend in ("1s", "2s"):
            cfg = JobConfig(usecase=WordCount(vocab=100), backend=backend,
                            task_size=1024, push_cap=4, n_procs=8)
            res = submit(cfg, tokens).result()
            assert res.records == oracle, backend
        print("OVERFLOW-EXACT")
    """)
    assert "OVERFLOW-EXACT" in out


def test_segmented_matches_oneshot_both_backends(devices8):
    """The segmented lifecycle (step()-driven, checkpointable) must equal
    the oneshot result for EVERY backend — the segmented path is part of
    the shared Backend protocol, not a onesided side-door. Includes a
    simulated restart from a mid-job in-memory snapshot."""
    out = devices8("""
        import dataclasses
        import numpy as np, jax
        from collections import Counter
        from repro.core import JobConfig, submit
        from repro.core.usecases import WordCount

        rng = np.random.default_rng(5)
        VOCAB, N, P, task = 400, 32768, 8, 512
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        oracle = dict(Counter(tokens.tolist()))

        for backend in ("1s", "2s"):
            cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                            task_size=task, push_cap=1024, n_procs=P,
                            segment=2)
            handle = submit(cfg, tokens)
            snapshots = []
            while True:
                more = handle.step()
                snapshots.append((handle.cursor,
                                  jax.tree.map(np.asarray, handle.carry)))
                if not more:
                    break
            res = handle.result()
            assert res.records == oracle, (backend, "segmented != oracle")

            oneshot = submit(dataclasses.replace(cfg, segment=0),
                             tokens).result()
            assert oneshot.records == res.records, backend

            # restart: resume from the first snapshot and replay the rest
            cur0, carry0 = snapshots[0]
            h2 = submit(cfg, tokens).load(carry0, cur0)
            r2 = h2.result()
            assert (r2.keys == res.keys).all(), (backend, "restart keys")
            assert (r2.values == res.values).all(), (backend, "restart vals")
        print("SEGMENTED-EXACT")
    """, timeout=560)
    assert "SEGMENTED-EXACT" in out


def test_new_usecases_both_backends_8dev(devices8):
    """Histogram and InvertedIndex are oracle-exact on the 8-device mesh
    for both backends (scenario diversity through one API)."""
    out = devices8("""
        import numpy as np
        from repro.core import (JobConfig, submit, Histogram, InvertedIndex,
                                histogram_oracle, inverted_index_oracle)
        rng = np.random.default_rng(3)
        VOCAB, N, P, task = 1024, 32768, 8, 512
        tokens = rng.integers(0, VOCAB, size=N).astype(np.int32)
        n_tasks = N // task
        for backend in ("1s", "2s"):
            h = submit(JobConfig(usecase=Histogram(vocab=VOCAB, n_bins=32),
                                 backend=backend, task_size=task,
                                 push_cap=task, n_procs=P), tokens).result()
            assert (h.output == histogram_oracle(tokens, VOCAB, 32)).all()

            q = (5, 99, 512)
            tpd = n_tasks // 4
            uc = InvertedIndex(queries=q, n_docs=4, tasks_per_doc=tpd)
            r = submit(JobConfig(usecase=uc, backend=backend,
                                 task_size=task, push_cap=task,
                                 n_procs=P), tokens).result()
            assert r.output == inverted_index_oracle(
                tokens, q, task, tpd, 4), backend
        print("USECASES-EXACT")
    """)
    assert "USECASES-EXACT" in out


def test_tree_combine_multiproc_sorted_merge(devices8):
    out = devices8("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.core.combine import tree_combine
        from repro.core.kv import KEY_SENTINEL
        from repro.distributed.collectives import shard_map
        from repro.distributed.mesh import local_mesh
        mesh = local_mesh((8,), ("procs",))
        rng = np.random.default_rng(11)
        # per-proc sorted unique keys; capacity W covers the merged union
        K, W = 32, 256
        keys = np.full((8, W), int(KEY_SENTINEL), np.int32)
        vals = np.zeros((8, W), np.int32)
        oracle = {}
        for p in range(8):
            ks = np.sort(rng.choice(200, size=rng.integers(5, K),
                                    replace=False)).astype(np.int32)
            keys[p, :len(ks)] = ks
            vals[p, :len(ks)] = p + 1
            for k in ks:
                oracle[int(k)] = oracle.get(int(k), 0) + p + 1

        def body(k, v):
            kk, vv, of = tree_combine(k[0], v[0], "procs", 8)
            return kk[None], vv[None], of[None]

        fn = jax.jit(shard_map(body, mesh=mesh,
                               in_specs=(P("procs"), P("procs")),
                               out_specs=(P("procs"), P("procs"),
                                          P("procs"))))
        ok, ov, of = fn(keys, vals)
        ok, ov = np.asarray(ok)[0], np.asarray(ov)[0]
        valid = ok != int(KEY_SENTINEL)
        got = dict(zip(ok[valid].tolist(), ov[valid].tolist()))
        assert got == oracle
        assert (np.diff(ok[valid]) > 0).all()
        # W covers the union: the overflow counter must stay 0 (and be
        # identical on every rank — it is psum-replicated)
        assert (np.asarray(of) == 0).all()
        print("COMBINE-OK")
    """)
    assert "COMBINE-OK" in out


def test_tree_combine_overflow_detected_at_merge_levels(devices8):
    """Satellite bugfix: two full W-wide runs whose key union exceeds W
    used to be truncated to W at each level with the loss vanishing at
    the next — the overflow must now surface, counted globally. Both the
    raw tree (disjoint per-rank runs => every merge overflows) and the
    Job API path (per-rank windows fit combine_capacity, the union does
    not => overflow arises ONLY inside the tree) are pinned."""
    out = devices8("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.combine import tree_combine
        from repro.core.kv import KEY_SENTINEL
        from repro.distributed.collectives import shard_map
        from repro.distributed.mesh import local_mesh

        mesh = local_mesh((8,), ("procs",))
        W = 16
        # 8 disjoint full runs: rank p owns keys [p*W, (p+1)*W)
        keys = (np.arange(8 * W, dtype=np.int32).reshape(8, W))
        vals = np.ones((8, W), np.int32)

        def body(k, v):
            kk, vv, of = tree_combine(k[0], v[0], "procs", 8)
            return kk[None], vv[None], of[None]

        fn = jax.jit(shard_map(body, mesh=mesh,
                               in_specs=(P("procs"), P("procs")),
                               out_specs=(P("procs"), P("procs"),
                                          P("procs"))))
        ok, ov, of = fn(keys, vals)
        of = np.asarray(of)
        # merges: 4+2+1 = 7, each unions 2W unique keys into W -> W lost
        assert (of == 7 * W).all(), of          # replicated global count
        ok0 = np.asarray(ok)[0]
        assert (ok0 == np.arange(W)).all()      # smallest W keys survive

        # Job API: per-rank windows fit W, only the tree overflows
        from repro.core import (CombineOverflowError, JobConfig, submit,
                                wordcount_oracle)
        from repro.core.usecases import WordCount
        VOCAB = 256
        toks = np.tile(np.arange(VOCAB, dtype=np.int32), 32)  # all keys hot
        oracle = wordcount_oracle(toks, VOCAB)
        cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                        task_size=512, push_cap=512, n_procs=8,
                        combine_capacity=64)
        h = submit(cfg, toks)
        try:
            h.result()
            raise SystemExit("no overflow raised")
        except CombineOverflowError as e:
            assert e.result.combine_overflow > 0
            assert e.result.records != oracle   # pre-fix silent wrongness
            assert len(e.result.records) <= 64
        print("TREE-OVERFLOW-OK")
    """)
    assert "TREE-OVERFLOW-OK" in out


def test_tree_combine_overflow_saturates_past_int31(devices8):
    """Regression at >2^31 synthetic counts: 8 ranks each seeding 2^30
    lost records sum to 2^33 — the old int32 psum wrapped that to
    exactly 0, i.e. a catastrophic loss reported as \"exact\". The
    saturating accumulation must instead pin the total near INT32_MAX,
    identically on every rank."""
    out = devices8("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.combine import SAT_MAX, tree_combine
        from repro.core.kv import KEY_SENTINEL
        from repro.distributed.collectives import shard_map
        from repro.distributed.mesh import local_mesh
        mesh = local_mesh((8,), ("procs",))
        W = 16
        keys = np.full((8, W), int(KEY_SENTINEL), np.int32)
        vals = np.zeros((8, W), np.int32)

        def body(k, v):
            kk, vv, of = tree_combine(k[0], v[0], "procs", 8,
                                      overflow=jnp.int32(2 ** 30))
            return kk[None], vv[None], of[None]

        fn = jax.jit(shard_map(body, mesh=mesh,
                               in_specs=(P("procs"), P("procs")),
                               out_specs=(P("procs"), P("procs"),
                                          P("procs"))))
        _, _, of = fn(keys, vals)
        of = np.asarray(of)
        # every rank agrees (psum-replicated) ...
        assert (of == of[0]).all(), of
        # ... and the 2^33 true loss saturates (per-rank contributions
        # clamp to SAT_MAX // 8) instead of wrapping to 0
        assert of[0] == 8 * (SAT_MAX // 8), of
        print("SAT-OK", int(of[0]))
    """)
    assert "SAT-OK" in out


def test_sat_add_i32_saturates_instead_of_wrapping():
    import jax.numpy as jnp
    from repro.core.combine import SAT_MAX, sat_add_i32
    a = jnp.int32(SAT_MAX - 5)
    assert int(sat_add_i32(a, jnp.int32(10))) == SAT_MAX
    assert int(sat_add_i32(jnp.int32(3), jnp.int32(4))) == 7
    assert int(sat_add_i32(jnp.int32(0), a)) == SAT_MAX - 5
    # elementwise too (the psum contributions are arrays)
    got = sat_add_i32(jnp.asarray([SAT_MAX, 1], jnp.int32),
                      jnp.asarray([1, 1], jnp.int32))
    assert got.tolist() == [SAT_MAX, 2]
