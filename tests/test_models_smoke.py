"""Per-arch smoke tests: reduced config of the same family, one forward +
one train step + one decode step on CPU, asserting shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.config import ShapeConfig, SINGLE_POD, TrainConfig
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.launch.specs import make_run
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_model, loss_fn, prefill)
from repro.train.train_step import init_train_state, make_train_step

B, S = 2, 64


def _batch(cfg, train=True):
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if train:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.frontend == "vision_stub":
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(B, 16, cfg.d_model)), jnp.dtype(cfg.dtype))
    elif cfg.n_enc_layers:
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = init_model(cfg, jax.random.key(0))
    batch = _batch(cfg, train=False)
    logits, aux = forward(cfg, params, batch)
    S_out = S + (16 if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    shape = ShapeConfig("smoke", S, B, "train")
    run = make_run(cfg, shape, SINGLE_POD)
    run = dataclasses.replace(run, train=TrainConfig(
        lr=1e-3, warmup_steps=2, total_steps=10))
    params = init_model(cfg, jax.random.key(0))
    state = init_train_state(cfg, run.train, params)
    step = jax.jit(make_train_step(cfg, run))
    batch = _batch(cfg)
    state1, m1 = step(state, batch)
    state2, m2 = step(state1, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    # params actually move
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)).sum()),
            state.params, state1.params))
    assert delta > 0
    # a second step on the same batch should usually not explode
    assert float(m2["loss"]) < float(m1["loss"]) * 2 + 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_model(cfg, jax.random.key(1))
    S_max = 96
    enc_len = S if cfg.n_enc_layers else 0
    cache = init_cache(cfg, B, S_max, enc_len=enc_len)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, new_cache = decode_step(cfg, params, cache, tok, jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache tree structure is preserved (scan-carry compatible)
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ["stablelm-12b", "deepseek-v2-lite-16b",
                                  "mamba2-780m", "h2o-danube-1.8b",
                                  "jamba-v0.1-52b"])
def test_decode_matches_prefill_logits(arch):
    """Teacher-forcing equivalence: decoding token t with a cache built from
    positions < t reproduces the full-sequence forward logits at t.

    Run in float32 — the equivalence is algorithmic; bf16 residual noise
    compounds across layers and would only test the dtype."""
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              param_dtype="float32")
    params = init_model(cfg, jax.random.key(2))
    rng = np.random.default_rng(3)
    T = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, T)), jnp.int32)
    full_logits, _ = forward(cfg, params, {"tokens": toks})

    S_max = 32
    cache = init_cache(cfg, 1, S_max)
    outs = []
    for t in range(T):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1],
                                    jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        atol=2e-4, rtol=2e-3)


def test_param_count_matches_init():
    """Analytic param_count (used for MODEL_FLOPS / napkin math) must agree
    with the real initialized tree on every smoke config."""
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        params = init_model(cfg, jax.random.key(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.02, \
            (arch, actual, analytic)


def test_full_configs_match_assignment():
    """The full configs carry the assigned dimensions verbatim."""
    spec = {
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
    }
    for arch, (L, d, H, KV, ff, V) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d, arch
        assert cfg.vocab_size == V, arch
        if H:
            assert cfg.n_heads == H and cfg.n_kv_heads == KV, arch
        if ff:
            assert cfg.d_ff == ff, arch
    # MoE side conditions
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.n_experts == 128 and l4.top_k == 1
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.n_experts == 64 and ds.top_k == 6 and ds.kv_lora_rank == 512
    jm = get_config("jamba-v0.1-52b")
    assert jm.n_experts == 16 and jm.top_k == 2
    mb = get_config("mamba2-780m")
    assert mb.ssm_state == 128
