"""Training substrate: grad-accum equivalence, loss descent, remat
invariance, sharded FSDP train step.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.config import ShapeConfig, SINGLE_POD, TrainConfig
from repro.configs.registry import get_smoke_config
from repro.launch.specs import make_run
from repro.models.transformer import init_model, loss_fn
from repro.train.train_step import init_train_state, make_train_step


def _setup(arch="olmo-1b", B=4, S=32, **tkw):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32",
                              param_dtype="float32")
    shape = ShapeConfig("t", S, B, "train")
    run = make_run(cfg, shape, SINGLE_POD)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=50, **tkw)
    run = dataclasses.replace(run, train=tcfg)
    params = init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    return cfg, run, params, batch


def test_grad_accum_matches_single_batch():
    """A=4 microbatched accumulation == A=1 full batch (same update)."""
    cfg, run1, params, batch = _setup(B=8)
    run4 = dataclasses.replace(run1, microbatch=2)
    assert run4.grad_accum_steps == 4 and run1.grad_accum_steps == 1
    s0 = init_train_state(cfg, run1.train, params)
    st1, m1 = jax.jit(make_train_step(cfg, run1))(s0, batch)
    st4, m4 = jax.jit(make_train_step(cfg, run4))(s0, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st4.params)):
        # accumulation order differs between the scan and the full batch;
        # float32 reduction noise also shifts with the host device count,
        # so the tolerance leaves headroom over the 1-device case
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-4)


def test_loss_decreases_overfit():
    cfg, run, params, batch = _setup(B=4, S=32)
    state = init_train_state(cfg, run.train, params)
    step = jax.jit(make_train_step(cfg, run))
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]
    assert all(np.isfinite(losses))


def test_remat_policies_same_loss_and_grads():
    cfg, run, params, batch = _setup()
    vals = {}
    for pol in ("none", "dots", "full"):
        (loss, _), grads = jax.value_and_grad(
            lambda p, pol=pol: loss_fn(cfg, p, batch, remat=pol),
            has_aux=True)(params)
        vals[pol] = (float(loss), grads)
    for pol in ("dots", "full"):
        np.testing.assert_allclose(vals[pol][0], vals["none"][0], rtol=1e-6)
        for a, b in zip(jax.tree.leaves(vals[pol][1]),
                        jax.tree.leaves(vals["none"][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-4)


def test_compressed_grad_sync_error_feedback():
    """int8 + error feedback: a constant gradient stream must converge to
    the exact mean direction (residual absorbs quantization bias)."""
    from repro.optim import compress as cp
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(64, 64)), jnp.float32)}
    res = cp.init_residuals(g)
    acc = jnp.zeros_like(g["w"])
    N = 50
    for _ in range(N):
        gq, res = cp.ef_compress(g, res)
        acc = acc + gq["w"]
    np.testing.assert_allclose(np.asarray(acc / N), np.asarray(g["w"]),
                               atol=2e-3)


def test_unbalanced_batch_train_step_finite():
    """Sequence-packed labels with mask (imbalanced tokens per row)."""
    cfg, run, params, batch = _setup(B=4, S=32)
    mask = np.ones((4, 32), np.float32)
    mask[1, 8:] = 0.0
    mask[3, 2:] = 0.0
    batch["loss_mask"] = jnp.asarray(mask)
    loss, m = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


def test_sharded_fsdp_train_step(devices8):
    """2-step train on a (2,4) mesh with FSDP+TP sharding rules: runs,
    finite, and parameters stay sharded per the specs."""
    out = devices8("""
        import dataclasses
        import numpy as np, jax, jax.numpy as jnp
        from repro.config import ShapeConfig, MeshConfig, TrainConfig
        from repro.configs.registry import get_smoke_config
        from repro.distributed.mesh import local_mesh
        from repro.distributed import sharding as shd
        from repro.launch import specs as sp
        from repro.models.transformer import init_model
        from repro.train.train_step import init_train_state, make_train_step

        cfg = dataclasses.replace(get_smoke_config("olmo-1b"),
                                  dtype="float32", param_dtype="float32")
        mesh_cfg = MeshConfig((2, 4), ("data", "model"))
        mesh = local_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("t", 32, 4, "train")
        run = sp.make_run(cfg, shape, mesh_cfg)
        run = dataclasses.replace(run, train=TrainConfig(lr=1e-3,
                                  warmup_steps=2, total_steps=10))
        params = init_model(cfg, jax.random.key(0))
        state = init_train_state(cfg, run.train, params)
        state_sh = sp.state_shardings(cfg, mesh, mesh_cfg,
                                      jax.eval_shape(lambda: state))
        state = jax.device_put(state, state_sh)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (4, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                    (4, 32)), jnp.int32)}
        batch_sh = sp.batch_shardings(cfg, shape, mesh, mesh_cfg,
                                      jax.eval_shape(lambda: batch))
        batch = jax.device_put(batch, batch_sh)
        dp = sp.dp_entry_for(shape, mesh_cfg)
        step = jax.jit(make_train_step(cfg, run, mesh=mesh, dp_entry=dp),
                       in_shardings=(state_sh, batch_sh))
        l0 = None
        for i in range(3):
            state, m = step(state, batch)
            assert np.isfinite(float(m["loss"]))
            l0 = l0 or float(m["loss"])
        # sharding preserved on outputs
        emb = state.params["embed_tokens"]
        assert emb.sharding.spec == state_sh.params["embed_tokens"].spec
        print("FSDP-STEP-OK", l0, float(m["loss"]))
    """)
    assert "FSDP-STEP-OK" in out
