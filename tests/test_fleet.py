"""Elastic fleet subsystem (repro/fleet) + its ft/ckpt satellites.

Fast, single-device half: the deterministic fault machinery
(plan/injector/source), the host fold arithmetic — including the
int32-saturation regression near INT32_MAX — re-bucketization, the
FleetCheckpoint failure diagnostics, and the supervisor's heal and
terminal-failure paths at P=1.

Slow, 8-device subprocess half: a K=4 fleet at P=8 survives a mid-run
rank kill and resumes at P=6 with every job record-identical to its
unfailed solo run, and the full elastic matrix — use-case x {1s,
1s+steal} x {hash, sampled+split} — folds 8 -> 6 and 8 -> 4 exactly.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.ckpt import CheckpointManager, FleetStateError, FleetCheckpoint
from repro.core import JobConfig, submit
from repro.core.partition import fold_owner_map, hash_owner_map
from repro.core.usecases import WordCount
from repro.fleet import (FaultEvent, FaultInjector, FaultPlan,
                         FaultingSource, FleetSupervisor, InjectedIOError,
                         RemeshChecksumError, elastic_restore)
from repro.ft.elastic import (I32_MAX, fold_windows, rebucketize_tasks,
                              remesh_fleet)

VOCAB = 64


def wc_cfg(**kw):
    base = dict(usecase=WordCount(vocab=VOCAB), backend="1s",
                task_size=16, push_cap=64, n_procs=1, segment=2)
    base.update(kw)
    return JobConfig(**base)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(7)
    return rng.integers(0, VOCAB, size=1024).astype(np.int32)


# ---------------------------------------------------------------------------
# fold_windows: int32 saturation regression (satellite #1)
# ---------------------------------------------------------------------------

def test_fold_windows_saturates_instead_of_wrapping():
    # two near-full int32 count windows folding onto one rank used to
    # wrap negative; they must pin at INT32_MAX (sat_add_i32 semantics)
    tables = np.array([[I32_MAX - 5, 10], [7, 20]], np.int32)
    out = fold_windows(tables, 1)
    assert out.dtype == np.int32
    assert out[0, 0] == I32_MAX          # (I32_MAX - 5) + 7 saturates
    assert out[0, 1] == 30               # small sums stay exact


def test_fold_windows_saturation_matches_pairwise_sat_add():
    # int64-accumulate-then-clip == pairwise saturating adds for
    # non-negative counts — the documented equivalence with the
    # device's sat_add_i32, checked here over a random fold
    rng = np.random.default_rng(0)
    tables = rng.integers(0, I32_MAX, size=(8, 16)).astype(np.int32)
    folded = fold_windows(tables, 3)

    def sat_add(a, b):
        s = (a.astype(np.int64) + b.astype(np.int64))
        return np.minimum(s, I32_MAX).astype(np.int32)

    for d in range(3):
        acc = np.zeros((16,), np.int32)
        for r in range(d, 8, 3):
            acc = sat_add(acc, tables[r])
        np.testing.assert_array_equal(folded[d], acc)


def test_fold_windows_wide_dtypes_fold_plain():
    # int64 windows (and floats) are legitimately wide — they must NOT
    # be clipped into int32 range; the sum-preserving fold still holds
    tables = np.full((4, 3), np.int64(I32_MAX) * 4, np.int64)
    out = fold_windows(tables, 2)
    assert out.dtype == np.int64
    np.testing.assert_array_equal(out.sum(axis=0), tables.sum(axis=0))


# ---------------------------------------------------------------------------
# rebucketize / owner-map fold / mesh arithmetic
# ---------------------------------------------------------------------------

def test_rebucketize_covers_remaining_and_keeps_repeats():
    ids = np.array([[0, 2, 4, -1], [1, 3, 5, 6]], np.int32)
    reps = np.array([[1, 2, 3, 1], [4, 5, 6, 7]], np.int32)
    grid, greps = rebucketize_tasks(ids, reps, cursor=1, n_new=3)
    assert grid.shape == greps.shape == (3, 2)
    got = {int(t): int(r) for t, r in
           zip(grid.ravel(), greps.ravel()) if t >= 0}
    # consumed column 0 (tasks 0, 1) gone; padding -1 dropped
    assert got == {2: 2, 4: 3, 3: 5, 5: 6, 6: 7}


def test_rebucketize_exhausted_assignment_is_empty():
    ids = np.array([[0, 1], [2, 3]], np.int32)
    grid, greps = rebucketize_tasks(ids, np.ones_like(ids), 2, 4)
    assert grid.shape == (4, 0) and greps.shape == (4, 0)


def test_fold_owner_map_targets_surviving_ranks():
    omap = np.arange(8, dtype=np.int32)          # owners 0..7 (P_old=8)
    osplit = np.array([1, 2, 9, 1, 1, 1, 8, 3], np.int32)
    om, osp = fold_owner_map(omap, osplit, 3)
    assert om.max() < 3 and om.min() >= 0
    np.testing.assert_array_equal(om, omap % 3)
    assert osp.max() <= 3 and osp.min() >= 1     # split width clipped


def test_remesh_fleet_shapes_and_validation():
    cfg = remesh_fleet(6)
    assert cfg.shape == (6,) and cfg.axes == ("procs",)
    with pytest.raises(ValueError, match="no mesh"):
        remesh_fleet(0)


# ---------------------------------------------------------------------------
# deterministic fault machinery
# ---------------------------------------------------------------------------

def test_fault_plan_generate_is_seed_deterministic():
    kw = dict(n_ticks=200, n_procs=8, jobs=("a", "b"), p_kill=0.05)
    a = FaultPlan.generate(3, **kw)
    b = FaultPlan.generate(3, **kw)
    c = FaultPlan.generate(4, **kw)
    assert a.events == b.events          # same seed -> same campaign
    assert a.events != c.events
    assert any(e.kind == "kill" for e in a.events)
    assert sum(e.kind == "kill" for e in a.events) <= 1   # max_kill


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(0, "meteor")


def test_injector_delivers_each_event_once_even_late():
    plan = FaultPlan((FaultEvent(0, "slow", ranks=(0,)),
                      FaultEvent(2, "kill", ranks=(1,)),
                      FaultEvent(5, "join", ranks=(1,))))
    inj = FaultInjector(plan)
    assert [e.kind for e in inj.poll(0)] == ["slow"]
    assert inj.poll(1) == []
    # a supervisor stuck recovering until tick 7 still gets both
    assert [e.kind for e in inj.poll(7)] == ["kill", "join"]
    assert inj.poll(7) == [] and inj.pending == ()


def test_faulting_source_trips_then_reads_pure(tokens):
    from repro.data.source import ArraySource
    src = FaultingSource(ArraySource(tokens), name="t")
    clean = np.array(src.read(16, 8))
    src.trip(2)
    for _ in range(2):
        with pytest.raises(InjectedIOError, match="source 't'"):
            src.read(16, 8)
    assert src.faults_fired == 2
    np.testing.assert_array_equal(src.read(16, 8), clean)  # purity
    assert src.len_elements() == len(tokens)


# ---------------------------------------------------------------------------
# FleetCheckpoint diagnostics (satellite #2)
# ---------------------------------------------------------------------------

def test_load_state_missing_manifest_names_dir_and_snapshots(tmp_path):
    fleet = FleetCheckpoint(str(tmp_path))
    fleet.manager("alpha").save(0, {"x": np.zeros((2,), np.int32)})
    fleet.manager("beta").save(0, {"x": np.zeros((2,), np.int32)})
    assert not fleet.has_state()
    with pytest.raises(FleetStateError) as ei:
        fleet.load_state()
    msg = str(ei.value)
    assert str(tmp_path) in msg
    assert "job-alpha" in msg and "job-beta" in msg
    assert "manager" in msg              # points at the per-job escape


def test_load_state_corrupt_manifest_is_diagnosed(tmp_path):
    fleet = FleetCheckpoint(str(tmp_path))
    fleet.save_state({"jobs": []})
    assert fleet.has_state()
    with open(os.path.join(str(tmp_path), FleetCheckpoint.STATE),
              "w") as f:
        f.write("{torn")
    with pytest.raises(FleetStateError, match="unreadable"):
        fleet.load_state()


def test_save_state_fsyncs_before_rename(tmp_path, monkeypatch):
    synced = []
    real = os.fsync
    monkeypatch.setattr(os, "fsync", lambda fd: synced.append(fd)
                        or real(fd))
    fleet = FleetCheckpoint(str(tmp_path))
    fleet.save_state({"jobs": [1]})
    assert synced, "save_state must fsync before the atomic rename"
    assert fleet.load_state() == {"jobs": [1]}


# ---------------------------------------------------------------------------
# elastic_restore, single device (same-P path + guards + checksum gate)
# ---------------------------------------------------------------------------

def test_elastic_restore_same_p_delegates_to_seek(tokens, tmp_path):
    solo = submit(wc_cfg(), tokens).result()
    mgr = CheckpointManager(str(tmp_path))
    h = submit(wc_cfg(), tokens)
    h.step(2)
    h.checkpoint(mgr).result()
    h.close()
    h2 = elastic_restore(submit(wc_cfg(), tokens), mgr)
    assert h2.result().records == solo.records


def test_elastic_restore_rejects_backend_mismatch(tokens, tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    h = submit(wc_cfg(), tokens)
    h.step(1)
    h.checkpoint(mgr).result()
    h.close()
    h2 = submit(wc_cfg(backend="2s"), tokens)
    with pytest.raises(ValueError, match="backend '1s'"):
        elastic_restore(h2, mgr)
    h2.close()


def test_remesh_checksum_gate_refuses_corrupt_fold(tokens, tmp_path,
                                                   monkeypatch):
    # force the host twin to disagree: the device fold must be rejected,
    # not silently resumed from
    import repro.fleet.remesh as remesh_mod
    mgr = CheckpointManager(str(tmp_path))
    h = submit(wc_cfg(), tokens)
    h.step(1)
    h.checkpoint(mgr).result()
    h.close()
    # same-P delegates (no fold), so fake a cross-P restore by lying
    # about the handle's P via a 1 -> 1 fold: patch P detection instead
    monkeypatch.setattr(remesh_mod, "fold_windows",
                        lambda t, n: np.asarray(t) + 1)
    monkeypatch.setattr(
        CheckpointManager, "restore",
        _shrinkless_restore(CheckpointManager.restore), raising=True)
    h2 = submit(wc_cfg(), tokens)
    with pytest.raises(RemeshChecksumError, match="refusing"):
        elastic_restore(h2, mgr)
    h2.close()


def _shrinkless_restore(real):
    """Wrap CheckpointManager.restore to report P_old = P_new + 1 by
    padding a zero rank row — drives elastic_restore down the cross-P
    fold path on a single device (the zero row changes no sums)."""
    from repro.core.kv import KEY_SENTINEL

    def patched(self, tree_like, step=None, shardings=None):
        step, tree, extra = real(self, tree_like, step=step,
                                 shardings=shardings)
        pad = {
            "table": lambda a: np.concatenate(
                [a, np.zeros_like(a[:1])], axis=0),
            "pending_k": lambda a: np.concatenate(
                [a, np.full_like(a[:1], int(KEY_SENTINEL))], axis=0),
            "pending_v": lambda a: np.concatenate(
                [a, np.zeros_like(a[:1])], axis=0),
            "owner_map": lambda a: np.concatenate(
                [a, a[:1]], axis=0),
            "owner_split": lambda a: np.concatenate(
                [a, a[:1]], axis=0),
        }
        tree = tree._replace(**{k: f(np.asarray(getattr(tree, k)))
                                for k, f in pad.items()})
        return step, tree, extra
    return patched


# ---------------------------------------------------------------------------
# supervisor at P=1: heal + terminal failure isolation
# ---------------------------------------------------------------------------

def test_supervisor_heals_injected_feed_fault(tokens, tmp_path):
    solo = submit(wc_cfg(), tokens).result()
    plan = FaultPlan((FaultEvent(0, "feed_error", job="wc",
                                 duration=1),))
    with FleetSupervisor(n_procs=1, ckpt_dir=str(tmp_path), plan=plan,
                         ckpt_every=2, slices_per_tick=2) as sup:
        sup.submit(wc_cfg(), tokens, name="wc")
        res = sup.run(max_ticks=100)
    assert not sup.failed
    assert res["wc"].records == solo.records
    kinds = [t["kind"] for t in sup.timeline]
    assert "feed_error" in kinds and "healed" in kinds
    assert sup.entries["wc"].source.faults_fired == 1


@dataclasses.dataclass(frozen=True)
class Boom:
    """Raises at trace time — a genuinely broken tenant (must NOT heal)."""
    vocab: int

    @property
    def window(self):
        return self.vocab

    def map_emit(self, toks, task_id):
        raise ValueError("boom at trace time")


def test_supervisor_isolates_real_failures(tokens, tmp_path):
    with FleetSupervisor(n_procs=1, ckpt_dir=str(tmp_path),
                         ckpt_every=0, slices_per_tick=2) as sup:
        sup.submit(wc_cfg(), tokens, name="good")
        sup.submit(wc_cfg(usecase=Boom(vocab=VOCAB)), tokens, name="bad")
        res = sup.run(max_ticks=100)
    assert "good" in res                       # sibling unharmed
    assert "bad" in sup.failed                 # terminal, not retried
    assert "boom" in str(sup.failed["bad"])
    assert sup.done


def test_supervisor_restart_discipline_skips_snapshots(tokens, tmp_path):
    """restore_on_remesh=False is fig13's control arm: checkpoints are
    still taken, but a re-mesh restarts every job from scratch — and
    from-scratch on the new mesh is still exact (ownership transfer)."""
    solo = submit(wc_cfg(), tokens).result()
    plan = FaultPlan((FaultEvent(2, "kill", ranks=(0,)),))
    with FleetSupervisor(n_procs=1, ckpt_dir=str(tmp_path), plan=plan,
                         ckpt_every=1, slices_per_tick=1,
                         restore_on_remesh=False) as sup:
        sup.submit(wc_cfg(), tokens, name="wc")
        res = sup.run(max_ticks=200)
    assert not sup.failed
    assert res["wc"].records == solo.records
    [rec] = sup.recoveries
    assert (rec.jobs_restored, rec.jobs_scratch) == (0, 1)


def test_supervisor_rejects_duplicate_names(tokens, tmp_path):
    with FleetSupervisor(n_procs=1, ckpt_dir=str(tmp_path)) as sup:
        sup.submit(wc_cfg(), tokens, name="x")
        with pytest.raises(ValueError, match="duplicate"):
            sup.submit(wc_cfg(), tokens, name="x")


# ---------------------------------------------------------------------------
# 8-device subprocess integration (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fleet_of_four_survives_kill_to_p6(devices8):
    out = devices8("""
        import numpy as np, tempfile
        from repro.core.job import JobConfig, submit
        from repro.core.usecases import WordCount, Histogram
        from repro.fleet import FaultEvent, FaultPlan, FleetSupervisor

        rng = np.random.default_rng(1)
        data = {f"j{i}": rng.integers(0, 128, size=4096 + 1024 * i)
                .astype(np.int32) for i in range(4)}
        cases = {"j0": WordCount(vocab=128), "j1": WordCount(vocab=128),
                 "j2": Histogram(vocab=128, n_bins=32),
                 "j3": WordCount(vocab=128)}
        def cfg(uc):
            return JobConfig(usecase=uc, backend="1s", task_size=16,
                             push_cap=128, segment=2, n_procs=8)
        solo = {n: submit(cfg(cases[n]), data[n]).result()
                for n in data}
        plan = FaultPlan((FaultEvent(3, "kill", ranks=(1, 5)),))
        with tempfile.TemporaryDirectory() as d:
            sup = FleetSupervisor(n_procs=8, ckpt_dir=d, plan=plan,
                                  ckpt_every=1, slices_per_tick=4)
            for n in data:
                sup.submit(cfg(cases[n]), data[n], name=n)
            res = sup.run(max_ticks=500)
            sup.close()
        assert not sup.failed, sup.failed
        assert set(res) == set(data)
        for n in data:
            assert res[n].records == solo[n].records, n
        [r] = sup.recoveries
        assert (r.kind, r.p_old, r.p_new) == ("kill", 8, 6)
        assert r.jobs_restored == 4 and r.jobs_scratch == 0
        assert sup.n_procs == 6
        print("OK restored", r.jobs_restored, "in", round(r.seconds, 2))
    """)
    assert "OK restored 4" in out


@pytest.mark.slow
def test_elastic_matrix_records_identical(devices8):
    # use-case x {1s, 1s+steal} x {hash, sampled+split}, folded to both
    # P=6 and P=4 — every combination record-identical to its solo run
    out = devices8("""
        import numpy as np, tempfile
        from repro.ckpt import CheckpointManager
        from repro.core.job import JobConfig, submit
        from repro.core.usecases import (Histogram, InvertedIndex,
                                         WordCount)
        from repro.fleet import elastic_restore

        rng = np.random.default_rng(5)
        tokens = rng.integers(0, 96, size=4096).astype(np.int32)
        cases = [("wc", WordCount(vocab=96)),
                 ("hist", Histogram(vocab=96, n_bins=16)),
                 ("inv", InvertedIndex(queries=(3, 5, 7), n_docs=8,
                                       tasks_per_doc=4))]
        checked = 0
        for cname, uc in cases:
            for stealing in (False, True):
                for part in ("hash", "sampled+split"):
                    def cfg(P):
                        return JobConfig(
                            usecase=uc, backend="1s", task_size=16,
                            push_cap=128, segment=2, n_procs=P,
                            stealing=stealing, partitioner=part)
                    solo = submit(cfg(8), tokens).result()
                    with tempfile.TemporaryDirectory() as d:
                        mgr = CheckpointManager(d)
                        h = submit(cfg(8), tokens)
                        h.step(5)              # mid-run snapshot
                        h.checkpoint(mgr).result()
                        h.close()
                        for P_new in (6, 4):
                            h2 = elastic_restore(
                                submit(cfg(P_new), tokens), mgr)
                            r = h2.result()
                            tag = (cname, stealing, part, P_new)
                            assert r.records == solo.records, tag
                            checked += 1
        print("MATRIX OK", checked)
    """)
    assert "MATRIX OK 24" in out
