"""Dry-run builder integration on a small production-like mesh.

The 512-device sweep runs out-of-process (results/dryrun); here the same
builders lower + compile smoke-sized cells on a (2,2) mesh in a subprocess
— exercising input_specs, sharding assembly, train/prefill/decode program
construction and the §Perf variants end to end inside the test suite.
"""


def test_builders_compile_all_kinds(devices8):
    out = devices8("""
        import dataclasses
        import jax
        from repro.config import SHAPES, MeshConfig, ShapeConfig
        from repro.configs.registry import get_smoke_config
        from repro.distributed.mesh import local_mesh
        from repro.launch import dryrun as dr
        from repro.launch.hlo_stats import collective_bytes

        mesh = local_mesh((2, 2), ("data", "model"))
        mesh_cfg = MeshConfig((2, 2), ("data", "model"))

        for arch, kinds in [("olmo-1b", ("train", "prefill", "decode")),
                            ("llama4-maverick-400b-a17b", ("train",
                                                           "decode")),
                            ("mamba2-780m", ("decode",))]:
            cfg = get_smoke_config(arch)
            for kind in kinds:
                shape = ShapeConfig("t", 64, 4, kind)
                fn, args, in_sh, _ = dr.build_cell(cfg, shape, mesh,
                                                   mesh_cfg)
                compiled = jax.jit(fn, in_shardings=in_sh).lower(
                    *args).compile()
                txt = compiled.as_text()
                cb = collective_bytes(txt)
                ca = compiled.cost_analysis()
                if isinstance(ca, (list, tuple)):   # jax 0.4.x
                    ca = ca[0]
                assert ca.get("flops", 0) > 0
                print(arch, kind, "ok", int(cb.get("total", 0)))

        # §Perf variants lower too (flat_dp train; serve decode)
        cfg = get_smoke_config("olmo-1b")
        shape = ShapeConfig("t", 64, 4, "train")
        fn, args, in_sh, _ = dr.build_train(cfg, shape, mesh, mesh_cfg,
                                            microbatch=4, remat="dots",
                                            sharding="flat_dp")
        jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        print("flat_dp ok")
        cfg = dataclasses.replace(
            get_smoke_config("llama4-maverick-400b-a17b"),
            expert_tp_axis="data")
        shape = ShapeConfig("t", 64, 4, "decode")
        fn, args, in_sh, _ = dr.build_decode(cfg, shape, mesh, mesh_cfg,
                                             sharding="serve")
        jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
        print("serve_ep ok")
        print("BUILDERS-OK")
    """, n_devices=4, timeout=560)
    assert "BUILDERS-OK" in out
