"""bench-guard (benchmarks/check_regression.py): schema + tolerance
gates over the benchmark smoke artifacts, against synthetic fixtures."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))                     # repo root: benchmarks/
from benchmarks.check_regression import (CHECKS, check,  # noqa: E402
                                         group_names, main)

FIG8 = {
    "per_task_size": {"1024": {"resident_s": 1.0, "streamed_s": 1.0}},
    "worst_overlap_win_pct": -2.0,
    "streamed_within_10pct": True,
}
FIG9 = {
    "model": {"rows": [{"s": 1.6, "t_2s": 2.0, "t_steal": 1.0}]},
    "real": {"per_skew": {"0.0": {}}},
    "steal_overhead_pct_worst": 6.0,
    "criteria": {"steal_beats_2s_at_max_skew": True, "oracle_exact": True},
}
FIG10 = {
    "model": {"rows": [{"a": 2.2, "per_part": {}}]},
    "real": {"per_skew": {"2.2": {}}},
    "partitioner_overhead_pct_worst": 3.0,
    "criteria": {"sampled_beats_hash_at_max_skew": True,
                 "split_beats_hash_at_max_skew": True,
                 "win_split_vs_hash_reduce_pct": 70.0,
                 "oracle_exact": True},
}
FIG11 = {
    "per_k": {"16": {"policies": {}}},
    "criteria": {"max_K": 16,
                 "fairshare_p95_win_pct": 41.0,
                 "fair_vs_fifo_makespan_pct": -14.0,
                 "jain_fair": 0.48,
                 "fair_jain_beats_fifo": True,
                 "priority_favors_high": True,
                 "all_jobs_exact": True},
}
FIG12 = {
    "vocabs": [16384, 262144], "task_size": 256, "push_cap": 64,
    "n_procs": 4, "triad_gbps": 19.0,
    "model": {"rows": [{"vocab": 262144}]},
    "real": {"P": 4, "n_tokens": 32768, "per_vocab": {"262144": {}}},
    "criteria": {"fused_model_beats_unfused_measured_at_max": True,
                 "fused_bytes_win_pct_at_max": 49.8,
                 "achieved_bw_frac_fused_at_max": 0.32,
                 "measured_ratio_fused_vs_unfused_at_max": 1.1,
                 "records_equal": True,
                 "oracle_exact": True},
}
FIG13 = {
    "P": 8, "P_new": 6, "K": 4, "kill_tick": 12,
    "clean": {"wall_s": 4.0, "ticks": 24, "exact": True, "final_p": 8,
              "recoveries": []},
    "recover": {"wall_s": 4.8, "ticks": 26, "exact": True, "final_p": 6,
                "recoveries": [{"tick": 12, "p_old": 8, "p_new": 6,
                                "seconds": 0.4, "restored": 4,
                                "scratch": 0}]},
    "restart": {"wall_s": 7.5, "ticks": 40, "exact": True, "final_p": 6,
                "recoveries": [{"tick": 12, "p_old": 8, "p_new": 6,
                                "seconds": 0.1, "restored": 0,
                                "scratch": 4}]},
    "criteria": {"records_equal": True,
                 "all_jobs_elastic_restored": True,
                 "mttr_s": 0.4,
                 "recovery_overhead_pct": 20.0,
                 "restart_overhead_pct": 87.5,
                 "recovery_win_vs_restart_pct": 36.0,
                 "recovery_beats_restart": True},
}
FIG14 = {
    "model": {"16": {"fair": {}, "fair+cosched": {}}},
    "real": {"P": 8, "per_k": {"4": {"fleets": {}}}},
    "criteria": {"max_K": 16,
                 "cosched_makespan_win_pct": 56.0,
                 "cosched_beats_fair_makespan": True,
                 "cosched_p95_win_pct": 62.0,
                 "jain_fair": 0.55,
                 "jain_cosched": 0.62,
                 "cosched_beats_fair_jain": True,
                 "all_jobs_exact": True,
                 "crossjob_steals_real": 94,
                 "crossjob_stealing_active": True,
                 "one_domain_per_fleet": True},
}
FIG15 = {
    "skews": [0.0, 1.6], "code_rates": [1, 2, 3],
    "real": {"P": 6, "per_skew": {"1.6": {}}},
    "bytes": {"per_step_blocks": {"1": 5, "2": 3, "3": 2},
              "shuffle_ratio_at_max_skew": {"2": 0.6, "3": 0.4}},
    "criteria": {"shuffle_ratio_r2_at_max_skew": 0.6,
                 "shuffle_ratio_r3_at_max_skew": 0.4,
                 "bytes_win_r2_pct": 40.0,
                 "bytes_win_r3_pct": 60.0,
                 "r2_le_065_at_max_skew": True,
                 "records_equal": True,
                 "oracle_exact": True},
}


@pytest.fixture()
def dirs(tmp_path):
    results = tmp_path / "results"
    baseline = tmp_path / "baseline"
    results.mkdir()
    baseline.mkdir()

    def write(fig8=FIG8, fig9=FIG9, fig10=FIG10, fig11=FIG11,
              fig12=FIG12, fig13=FIG13, fig14=FIG14, fig15=FIG15,
              fresh_fig8=None, fresh_fig9=None, fresh_fig10=None,
              fresh_fig11=None, fresh_fig12=None, fresh_fig13=None,
              fresh_fig14=None, fresh_fig15=None):
        (baseline / "BENCH_io_overlap.json").write_text(json.dumps(fig8))
        (baseline / "BENCH_imbalance.json").write_text(json.dumps(fig9))
        (baseline / "BENCH_keyskew.json").write_text(json.dumps(fig10))
        (baseline / "BENCH_multitenant.json").write_text(json.dumps(fig11))
        (baseline / "BENCH_roofline.json").write_text(json.dumps(fig12))
        (baseline / "BENCH_elastic.json").write_text(json.dumps(fig13))
        (baseline / "BENCH_crossjob.json").write_text(json.dumps(fig14))
        (baseline / "BENCH_coded.json").write_text(json.dumps(fig15))
        (results / "fig8_io_overlap.json").write_text(
            json.dumps(fresh_fig8 if fresh_fig8 is not None else fig8))
        (results / "fig9_imbalance.json").write_text(
            json.dumps(fresh_fig9 if fresh_fig9 is not None else fig9))
        (results / "fig10_keyskew.json").write_text(
            json.dumps(fresh_fig10 if fresh_fig10 is not None else fig10))
        (results / "fig11_multitenant.json").write_text(
            json.dumps(fresh_fig11 if fresh_fig11 is not None else fig11))
        (results / "fig12_roofline.json").write_text(
            json.dumps(fresh_fig12 if fresh_fig12 is not None else fig12))
        (results / "fig13_elastic.json").write_text(
            json.dumps(fresh_fig13 if fresh_fig13 is not None else fig13))
        (results / "fig14_crossjob.json").write_text(
            json.dumps(fresh_fig14 if fresh_fig14 is not None else fig14))
        (results / "fig15_coded.json").write_text(
            json.dumps(fresh_fig15 if fresh_fig15 is not None else fig15))

    return str(results), str(baseline), write


def test_clean_artifacts_pass(dirs):
    results, baseline, write = dirs
    write()
    assert check("fig8", results, baseline) == []
    assert check("fig9", results, baseline) == []
    assert check("fig10", results, baseline) == []
    assert check("fig11", results, baseline) == []
    assert check("fig12", results, baseline) == []
    assert check("fig13", results, baseline) == []
    assert check("fig14", results, baseline) == []
    assert check("fig15", results, baseline) == []
    assert main(["fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
                 "fig14", "fig15", "--results", results, "--baseline",
                 baseline]) == 0


def test_missing_fresh_artifact_fails(dirs, tmp_path):
    results, baseline, write = dirs
    write()
    empty = tmp_path / "empty"
    empty.mkdir()
    errs = check("fig8", str(empty), baseline)
    assert errs and "missing" in errs[0]


def test_missing_required_key_fails(dirs):
    results, baseline, write = dirs
    bad = copy.deepcopy(FIG9)
    del bad["criteria"]["steal_beats_2s_at_max_skew"]
    write(fresh_fig9=bad)
    errs = check("fig9", results, baseline)
    assert any("steal_beats_2s_at_max_skew" in e for e in errs)
    assert main(["fig9", "--results", results, "--baseline", baseline]) == 1


def test_tolerance_breach_fails_and_within_passes(dirs):
    results, baseline, write = dirs
    # fig8: win may drop at most 25pp below baseline (-2.0)
    ok = dict(FIG8, worst_overlap_win_pct=-20.0)
    bad = dict(FIG8, worst_overlap_win_pct=-40.0)
    write(fresh_fig8=ok)
    assert check("fig8", results, baseline) == []
    write(fresh_fig8=bad)
    errs = check("fig8", results, baseline)
    assert any("regressed" in e for e in errs)
    # fig9: steal overhead may rise at most 30pp above baseline (6.0)
    worse = copy.deepcopy(FIG9)
    worse["steal_overhead_pct_worst"] = 50.0
    write(fresh_fig9=worse)
    errs = check("fig9", results, baseline)
    assert any("steal_overhead_pct_worst" in e for e in errs)


def test_require_true_criteria_enforced(dirs):
    results, baseline, write = dirs
    lost = copy.deepcopy(FIG9)
    lost["criteria"]["steal_beats_2s_at_max_skew"] = False
    write(fresh_fig9=lost)
    errs = check("fig9", results, baseline)
    assert any("expected true" in e for e in errs)


def test_fig10_gates(dirs):
    """The key-skew guard: win may shrink at most 40pp below baseline
    (70), exactness and both beats-hash criteria are hard-required."""
    results, baseline, write = dirs
    ok = copy.deepcopy(FIG10)
    ok["criteria"]["win_split_vs_hash_reduce_pct"] = 45.0   # within 40pp
    write(fresh_fig10=ok)
    assert check("fig10", results, baseline) == []
    bad = copy.deepcopy(FIG10)
    bad["criteria"]["win_split_vs_hash_reduce_pct"] = 20.0  # breach
    write(fresh_fig10=bad)
    assert any("win_split_vs_hash_reduce_pct" in e
               for e in check("fig10", results, baseline))
    inexact = copy.deepcopy(FIG10)
    inexact["criteria"]["oracle_exact"] = False
    write(fresh_fig10=inexact)
    assert any("oracle_exact" in e and "expected true" in e
               for e in check("fig10", results, baseline))


def test_fig11_gates(dirs):
    """The multi-tenant guard: fair-share p95 win may shrink at most
    35pp below baseline (41), fair-fleet makespan may rise at most 25pp
    above it, per-job exactness + jain ordering are hard-required."""
    results, baseline, write = dirs
    ok = copy.deepcopy(FIG11)
    ok["criteria"]["fairshare_p95_win_pct"] = 10.0     # within 35pp of 41
    ok["criteria"]["fair_vs_fifo_makespan_pct"] = 5.0  # within 25pp
    write(fresh_fig11=ok)
    assert check("fig11", results, baseline) == []
    # p95 win collapsing to ~FIFO trips the min gate
    bad = copy.deepcopy(FIG11)
    bad["criteria"]["fairshare_p95_win_pct"] = 2.0
    write(fresh_fig11=bad)
    assert any("fairshare_p95_win_pct" in e
               for e in check("fig11", results, baseline))
    # slicing overhead ballooning the makespan trips the max gate
    slow = copy.deepcopy(FIG11)
    slow["criteria"]["fair_vs_fifo_makespan_pct"] = 30.0
    write(fresh_fig11=slow)
    assert any("fair_vs_fifo_makespan_pct" in e
               for e in check("fig11", results, baseline))
    # a diverging job is a hard failure
    inexact = copy.deepcopy(FIG11)
    inexact["criteria"]["all_jobs_exact"] = False
    write(fresh_fig11=inexact)
    assert any("all_jobs_exact" in e and "expected true" in e
               for e in check("fig11", results, baseline))


def test_fig12_gates(dirs):
    """The roofline guard: the fused bytes-moved win may shrink at most
    15pp below baseline (49.8); model-beats-measured and real-run
    exactness are hard-required."""
    results, baseline, write = dirs
    ok = copy.deepcopy(FIG12)
    ok["criteria"]["fused_bytes_win_pct_at_max"] = 40.0   # within 15pp
    write(fresh_fig12=ok)
    assert check("fig12", results, baseline) == []
    shrunk = copy.deepcopy(FIG12)
    shrunk["criteria"]["fused_bytes_win_pct_at_max"] = 20.0  # breach
    write(fresh_fig12=shrunk)
    assert any("fused_bytes_win_pct_at_max" in e
               for e in check("fig12", results, baseline))
    # a model claiming a win that measured wall contradicts is a hard
    # failure — the whole point of gating model against measurement
    contradicted = copy.deepcopy(FIG12)
    contradicted["criteria"][
        "fused_model_beats_unfused_measured_at_max"] = False
    write(fresh_fig12=contradicted)
    assert any("fused_model_beats_unfused_measured_at_max" in e
               and "expected true" in e
               for e in check("fig12", results, baseline))
    # the kernel diverging from the unfused engine on a real run is the
    # one unforgivable regression
    inexact = copy.deepcopy(FIG12)
    inexact["criteria"]["records_equal"] = False
    write(fresh_fig12=inexact)
    assert any("records_equal" in e and "expected true" in e
               for e in check("fig12", results, baseline))


def test_fig12_bandwidth_floor_is_absolute(dirs):
    """The achieved-bandwidth floor is baseline-independent: a fresh
    kernel moving its bytes under 2% of triad bandwidth fails even if
    the committed baseline were equally slow (the superlinear-tiling
    regression guard)."""
    results, baseline, write = dirs
    slow_base = copy.deepcopy(FIG12)
    slow_base["criteria"]["achieved_bw_frac_fused_at_max"] = 0.005
    slow = copy.deepcopy(FIG12)
    slow["criteria"]["achieved_bw_frac_fused_at_max"] = 0.01
    write(fig12=slow_base, fresh_fig12=slow)
    errs = check("fig12", results, baseline)
    assert any("achieved_bw_frac_fused_at_max" in e and "floor" in e
               for e in errs)


def test_group_expansion_matches_registry(dirs):
    """--group resolves through run.py's REGISTRY: every guarded bench
    lands in exactly one group, and the union covers CHECKS — so CI
    consumes one list and a new figure needs no workflow edit."""
    results, baseline, write = dirs
    bench, chaos = group_names("bench"), group_names("chaos")
    assert "fig12" in bench and "fig13" in chaos
    assert not set(bench) & set(chaos)
    assert set(bench) | set(chaos) == set(group_names("all")) == set(CHECKS)
    write()
    assert main(["--group", "all",
                 "--results", results, "--baseline", baseline]) == 0


def test_fig13_gates(dirs):
    """The elastic guard: recovery overhead over clean may rise at most
    75pp above baseline (20); exactness, restore-without-resubmission,
    and recovery-beats-restart are hard-required."""
    results, baseline, write = dirs
    ok = copy.deepcopy(FIG13)
    ok["criteria"]["recovery_overhead_pct"] = 80.0   # within 75pp of 20
    write(fresh_fig13=ok)
    assert check("fig13", results, baseline) == []
    bloated = copy.deepcopy(FIG13)
    bloated["criteria"]["recovery_overhead_pct"] = 120.0  # breach
    write(fresh_fig13=bloated)
    assert any("recovery_overhead_pct" in e
               for e in check("fig13", results, baseline))
    # a kill that forces even one from-scratch restart is a hard failure
    scratched = copy.deepcopy(FIG13)
    scratched["criteria"]["all_jobs_elastic_restored"] = False
    write(fresh_fig13=scratched)
    assert any("all_jobs_elastic_restored" in e and "expected true" in e
               for e in check("fig13", results, baseline))
    # recovery slower than restart-from-scratch defeats the subsystem
    pointless = copy.deepcopy(FIG13)
    pointless["criteria"]["recovery_beats_restart"] = False
    write(fresh_fig13=pointless)
    assert any("recovery_beats_restart" in e
               for e in check("fig13", results, baseline))


def test_fig14_gates(dirs):
    """The cross-job guard: the co-scheduled makespan win may shrink at
    most 30pp below baseline (56); beating fair on makespan AND Jain,
    per-job exactness, and live cross-rank steals are hard-required,
    with an absolute 0.30 Jain floor on the co-scheduled fleet."""
    results, baseline, write = dirs
    ok = copy.deepcopy(FIG14)
    ok["criteria"]["cosched_makespan_win_pct"] = 30.0  # within 30pp of 56
    write(fresh_fig14=ok)
    assert check("fig14", results, baseline) == []
    shrunk = copy.deepcopy(FIG14)
    shrunk["criteria"]["cosched_makespan_win_pct"] = 10.0  # breach
    write(fresh_fig14=shrunk)
    assert any("cosched_makespan_win_pct" in e
               for e in check("fig14", results, baseline))
    # a domain that wins makespan by starving its small members fails
    # the fairness leg outright
    unfair = copy.deepcopy(FIG14)
    unfair["criteria"]["cosched_beats_fair_jain"] = False
    write(fresh_fig14=unfair)
    assert any("cosched_beats_fair_jain" in e and "expected true" in e
               for e in check("fig14", results, baseline))
    # ... and the Jain floor is absolute, baseline notwithstanding
    starved_base = copy.deepcopy(FIG14)
    starved_base["criteria"]["jain_cosched"] = 0.10
    starved = copy.deepcopy(FIG14)
    starved["criteria"]["jain_cosched"] = 0.15
    write(fig14=starved_base, fresh_fig14=starved)
    assert any("jain_cosched" in e and "floor" in e
               for e in check("fig14", results, baseline))
    # a co-scheduled job diverging from its solo records is the one
    # unforgivable regression
    inexact = copy.deepcopy(FIG14)
    inexact["criteria"]["all_jobs_exact"] = False
    write(fresh_fig14=inexact)
    assert any("all_jobs_exact" in e and "expected true" in e
               for e in check("fig14", results, baseline))
    # a "win" with zero cross-rank steals is a bookkeeping artifact
    idle = copy.deepcopy(FIG14)
    idle["criteria"]["crossjob_stealing_active"] = False
    write(fresh_fig14=idle)
    assert any("crossjob_stealing_active" in e
               for e in check("fig14", results, baseline))


def test_fig15_gates(dirs):
    """The coded-shuffle guard: the r=2 bytes win may shrink at most
    10pp below baseline (40); the 0.65x acceptance ratio, record
    identity with r=1, and oracle exactness are hard-required."""
    results, baseline, write = dirs
    ok = copy.deepcopy(FIG15)
    ok["criteria"]["bytes_win_r2_pct"] = 32.0    # within 10pp of 40
    write(fresh_fig15=ok)
    assert check("fig15", results, baseline) == []
    shrunk = copy.deepcopy(FIG15)
    shrunk["criteria"]["bytes_win_r2_pct"] = 25.0   # breach
    write(fresh_fig15=shrunk)
    assert any("bytes_win_r2_pct" in e
               for e in check("fig15", results, baseline))
    # the acceptance headline is hard-required: r=2 must keep shuffle
    # bytes at or under 0.65x the r=1 reference
    over = copy.deepcopy(FIG15)
    over["criteria"]["r2_le_065_at_max_skew"] = False
    write(fresh_fig15=over)
    assert any("r2_le_065_at_max_skew" in e and "expected true" in e
               for e in check("fig15", results, baseline))
    # a coded run diverging from the r=1 records (or the host oracle)
    # is the one unforgivable regression
    inexact = copy.deepcopy(FIG15)
    inexact["criteria"]["records_equal"] = False
    write(fresh_fig15=inexact)
    assert any("records_equal" in e and "expected true" in e
               for e in check("fig15", results, baseline))


def test_fig15_bytes_floor_is_absolute(dirs):
    """The bytes-win floor is baseline-independent: a silently-
    degenerate r=1 fallback (coded path not engaging, 0% win) fails
    even against a baseline that recorded the same degeneracy."""
    results, baseline, write = dirs
    flat_base = copy.deepcopy(FIG15)
    flat_base["criteria"]["bytes_win_r2_pct"] = 0.0
    flat = copy.deepcopy(FIG15)
    flat["criteria"]["bytes_win_r2_pct"] = 0.0
    write(fig15=flat_base, fresh_fig15=flat)
    errs = check("fig15", results, baseline)
    assert any("bytes_win_r2_pct" in e and "floor" in e for e in errs)


def test_fig11_fairness_floor_is_absolute(dirs):
    """The jain floor is baseline-independent: even a baseline that
    (hypothetically) recorded terrible fairness cannot excuse a fresh
    run below 0.30."""
    results, baseline, write = dirs
    low_base = copy.deepcopy(FIG11)
    low_base["criteria"]["jain_fair"] = 0.10
    unfair = copy.deepcopy(FIG11)
    unfair["criteria"]["jain_fair"] = 0.15
    write(fig11=low_base, fresh_fig11=unfair)
    errs = check("fig11", results, baseline)
    assert any("jain_fair" in e and "floor" in e for e in errs)
    # and a missing floor metric is reported, not skipped
    gone = copy.deepcopy(FIG11)
    del gone["criteria"]["jain_fair"]
    write(fresh_fig11=gone)
    errs = check("fig11", results, baseline)
    assert any("jain_fair" in e for e in errs)
