"""Deterministic fault injection for the elastic fleet supervisor.

Chaos that cannot be replayed cannot be debugged: every fault here is a
frozen :class:`FaultEvent` on a virtual-time *tick* axis (the
supervisor's scheduling rounds, not wall seconds), and a whole campaign
is a :class:`FaultPlan` — either written out literally in a test or
derived from a seed via :meth:`FaultPlan.generate`, which uses a
counter-keyed ``np.random.default_rng`` so the same seed always yields
the same events in the same order. The :class:`FaultInjector` is the
tiny delivery mechanism: ``poll(tick)`` hands each due event to the
supervisor exactly once.

Fault kinds and what they model:

  * ``kill``       — ranks die; device state on them is lost. The
                     supervisor re-meshes the fleet onto the survivors
                     (:mod:`repro.fleet.supervisor`).
  * ``join``       — ranks return; the same re-mesh path runs in
                     reverse (grow).
  * ``slow``       — a rank degrades by ``factor`` for ``duration``
                     ticks; results are unaffected, wall time is (the
                     straggler scenario the paper's decoupling targets).
  * ``feed_error`` — a job's input stream starts raising
                     :class:`InjectedIOError`; the wrapped
                     :class:`FaultingSource` delivers it through the
                     prefetch thread exactly like a real storage fault,
                     and the scheduler's failure isolation turns it into
                     a FAILED job the supervisor heals.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

KINDS = ("kill", "slow", "feed_error", "join")


class InjectedIOError(OSError):
    """The marker error a tripped :class:`FaultingSource` raises; the
    supervisor only heals jobs whose failure is this injected kind (a
    real bug in a use-case must stay FAILED, not retry forever)."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``ranks`` are mesh positions for
    ``kill``/``slow`` (a count for ``join`` would be ambiguous — it
    names the ranks being added, so only ``len(ranks)`` matters there);
    ``job`` targets ``feed_error``; ``factor`` is the slow rank's
    per-tick stall in seconds; ``duration`` is ticks (``slow``) or
    failing reads (``feed_error``)."""
    tick: int
    kind: str
    ranks: tuple[int, ...] = ()
    job: str | None = None
    factor: float = 0.0
    duration: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")


@dataclass(frozen=True)
class FaultPlan:
    """An immutable, replayable chaos campaign (events sorted by tick)."""
    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: (e.tick, e.kind))))

    @staticmethod
    def generate(seed: int, *, n_ticks: int, n_procs: int,
                 jobs: tuple[str, ...] = (), p_kill: float = 0.02,
                 p_slow: float = 0.05, p_feed: float = 0.05,
                 max_kill: int = 1) -> FaultPlan:
        """Seed-deterministic campaign: each tick independently draws
        each fault kind. Kills never take the fleet below 1 rank, and
        at most one kill event is emitted per campaign by default
        (``max_kill``) — recovery measurement wants a clean MTTR signal,
        soak tests can raise it."""
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        alive = n_procs
        kills = 0
        for t in range(n_ticks):
            if (kills < max_kill and alive > 1
                    and rng.random() < p_kill):
                n = int(rng.integers(1, min(2, alive - 1) + 1))
                ranks = tuple(sorted(
                    rng.choice(alive, size=n, replace=False).tolist()))
                events.append(FaultEvent(t, "kill", ranks=ranks))
                alive -= n
                kills += 1
            if rng.random() < p_slow:
                events.append(FaultEvent(
                    t, "slow", ranks=(int(rng.integers(alive)),),
                    factor=float(rng.uniform(0.001, 0.01)),
                    duration=int(rng.integers(1, 4))))
            if jobs and rng.random() < p_feed:
                events.append(FaultEvent(
                    t, "feed_error",
                    job=str(jobs[int(rng.integers(len(jobs)))]),
                    duration=int(rng.integers(1, 3))))
        return FaultPlan(tuple(events))


class FaultInjector:
    """Delivers a plan's events to the supervisor, each exactly once.

    ``poll(tick)`` returns every not-yet-delivered event with
    ``event.tick <= tick`` — late delivery (e.g. the supervisor spent
    several ticks recovering) never drops a fault, it just lands at the
    next opportunity, which is also what a real failure does."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._delivered = 0

    @property
    def pending(self) -> tuple[FaultEvent, ...]:
        return self.plan.events[self._delivered:]

    def poll(self, tick: int) -> list[FaultEvent]:
        due = [e for e in self.pending if e.tick <= tick]
        self._delivered += len(due)
        return due


@dataclass
class FaultingSource:
    """A DataSource wrapper whose reads can be tripped to raise
    :class:`InjectedIOError` — the feed-fault delivery vehicle.

    ``trip(n)`` arms the next ``n`` reads; the failure surfaces wherever
    the read actually happens (usually the SegmentFeed's prefetch
    thread, whose Future re-raises at ``next_segment``) — the same
    propagation path a real storage error takes. Reads stay pure:
    a failed read consumed no stream state, so a healed job re-reads
    the same offsets and gets the same bytes."""
    inner: object
    name: str = ""
    _armed: int = 0
    _fired: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def trip(self, n_reads: int = 1):
        with self._lock:
            self._armed += int(n_reads)

    @property
    def faults_fired(self) -> int:
        return self._fired

    def len_elements(self) -> int:
        return self.inner.len_elements()

    def read(self, offset: int, size: int) -> np.ndarray:
        with self._lock:
            if self._armed > 0:
                self._armed -= 1
                self._fired += 1
                raise InjectedIOError(
                    f"injected I/O fault on source {self.name!r} "
                    f"(read offset={offset}, size={size})")
        return self.inner.read(offset, size)
