"""The elastic fleet supervisor: keep a JobScheduler fleet live through
rank loss, stragglers, and I/O faults — re-meshing instead of restarting.

The paper decouples processes so an imbalanced workload cannot serialize
a fleet; this module applies the same stance to *failures*: losing ranks
must not mean losing the fleet. The supervisor owns the durable pieces —
job registry, collected results, the :class:`FleetCheckpoint` — and
treats the scheduler + mesh as disposable:

    sup = FleetSupervisor(n_procs=8, ckpt_dir=..., plan=chaos)
    sup.submit(cfg, corpus, name="wc0", tenant="batch")
    ...
    results = sup.run()          # survives whatever `chaos` throws at it

Each ``run`` tick: deliver due faults (:class:`FaultInjector`), stall
for active slow-rank penalties, drive the scheduler a few slices,
collect finished results, heal injected-I/O failures, and periodically
checkpoint the fleet (async — the storage-windows trick, so the ticks
keep flowing while snapshots drain).

Recovery model (kill): device state on dead ranks is gone, so the whole
scheduler is dropped — feeds closed, in-memory carries abandoned — and
the fleet is rebuilt at P_new = survivors from the last durable
snapshot: every uncollected job is resubmitted at P_new and
elastic-restored (:func:`repro.fleet.remesh.elastic_restore` — windows
folded, tasks re-bucketized, checksum-verified) or restarted from
scratch if it was never snapshotted. Re-executing the
since-last-snapshot suffix IS the recovery cost the fig13 benchmark
measures; results already collected are host data and survive in
memory. A ``join`` runs the same path in reverse (checkpoint first —
the state is still alive — then grow onto P + new ranks; the fold with
n_new > P_old leaves the new ranks' windows zero).

Heal (feed_error): the failed job is evicted (the duplicate-name guard
exists so two live jobs never share a snapshot dir — eviction frees the
name), resubmitted at the current P, and elastic-restored from its own
snapshot; a bounded retry budget keeps a genuinely broken job from
spinning. Only :class:`InjectedIOError` failures heal — a real bug in a
use-case stays FAILED and lands in :attr:`FleetSupervisor.failed`.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from collections.abc import Callable
from dataclasses import dataclass, field

from repro.ckpt.checkpoint import FleetCheckpoint
from repro.core.scheduler import DONE, FAILED, JobScheduler, TenantStats
from repro.data.source import as_source
from repro.distributed.mesh import make_mesh
from repro.fleet.faults import (FaultInjector, FaultPlan, FaultingSource,
                                InjectedIOError)
from repro.fleet.remesh import elastic_restore
from repro.ft.elastic import remesh_fleet


@dataclass
class FleetEntry:
    """One registered job — everything needed to resubmit it onto a new
    mesh (the scheduler's admission record dies with the mesh; this one
    belongs to the supervisor)."""
    name: str
    config: object                   # JobConfig; n_procs re-derived per mesh
    source: FaultingSource
    tenant: str = "default"
    priority: int = 0
    on_slice: Callable | None = None


@dataclass
class RecoveryRecord:
    """One re-mesh, as measured — the rows of fig13's MTTR table."""
    tick: int
    kind: str                        # "kill" | "join"
    p_old: int
    p_new: int
    seconds: float                   # wall time of the re-mesh itself
    jobs_restored: int               # elastic-restored from snapshots
    jobs_scratch: int                # never snapshotted: restarted


@dataclass
class _SlowState:
    factor: float
    remaining: int


class FleetSupervisor:
    """Run a fleet of jobs under fault injection; see module docstring.

    Parameters
    ----------
    n_procs:        initial mesh size (1-D ``("procs",)``).
    ckpt_dir:       FleetCheckpoint root — the durable recovery state.
    plan:           :class:`FaultPlan` to inject (default: no faults,
                    i.e. a plain supervised run).
    policy:         scheduler policy for every (re)built scheduler.
    ckpt_every:     fleet checkpoint period in ticks (0 disables — then
                    a kill restarts every job from scratch).
    slices_per_tick: scheduler slices driven per tick; smaller = finer
                    fault-delivery granularity, more checkpoints.
    heal_retries:   per-job budget for healing injected I/O failures.
    max_live_bytes: forwarded to every scheduler (shared feed budget).
    restore_on_remesh: when False, a re-mesh ignores existing snapshots
                    and restarts every job from scratch — the
                    restart-discipline control arm of the fig13
                    benchmark (same checkpoint cadence, snapshots
                    unused at recovery). Healing feed faults still
                    restores: that path never changes the mesh.
    """

    def __init__(self, *, n_procs: int, ckpt_dir: str,
                 plan: FaultPlan | None = None, policy: str = "fair",
                 ckpt_every: int = 2, slices_per_tick: int = 4,
                 heal_retries: int = 2,
                 max_live_bytes: int | None = None,
                 restore_on_remesh: bool = True):
        self.n_procs = int(n_procs)
        self.fleet = FleetCheckpoint(ckpt_dir)
        self.injector = FaultInjector(plan or FaultPlan())
        self.policy = policy
        self.ckpt_every = int(ckpt_every)
        self.slices_per_tick = int(slices_per_tick)
        self.heal_retries = int(heal_retries)
        self.max_live_bytes = max_live_bytes
        self.restore_on_remesh = bool(restore_on_remesh)
        self.entries: dict[str, FleetEntry] = {}
        self.results: dict = {}              # name -> JobResult
        self.failed: dict = {}               # name -> exception (terminal)
        self.recoveries: list[RecoveryRecord] = []
        self.timeline: list[dict] = []       # (tick, kind, detail) log
        self.ticks_run = 0
        self._sched: JobScheduler | None = None
        self._slow: list[_SlowState] = []
        self._heals: dict[str, int] = defaultdict(int)

    # -- registry ------------------------------------------------------------

    def submit(self, config, dataset, *, name: str,
               tenant: str = "default", priority: int = 0,
               on_slice: Callable | None = None) -> FleetEntry:
        """Register a job and admit it to the live scheduler. The
        dataset is wrapped in a :class:`FaultingSource` (reads stay
        pure, so resubmissions after a fault re-read identical bytes);
        the wrapper persists across re-meshes — it IS the durable
        dataset identity."""
        if name in self.entries:
            raise ValueError(f"duplicate fleet job name {name!r}")
        entry = FleetEntry(
            name=name, config=config,
            source=(dataset if isinstance(dataset, FaultingSource)
                    else FaultingSource(as_source(dataset), name=name)),
            tenant=tenant, priority=priority, on_slice=on_slice)
        self.entries[name] = entry
        self._admit(self._ensure_sched(), entry)
        return entry

    def _ensure_sched(self) -> JobScheduler:
        if self._sched is None:
            self._sched = JobScheduler(
                policy=self.policy,
                mesh=make_mesh(remesh_fleet(self.n_procs)),
                max_live_bytes=self.max_live_bytes)
        return self._sched

    def _admit(self, sched: JobScheduler, entry: FleetEntry):
        cfg = dataclasses.replace(entry.config, n_procs=self.n_procs)
        return sched.submit(cfg, entry.source, name=entry.name,
                            tenant=entry.tenant, priority=entry.priority,
                            on_slice=entry.on_slice)

    # -- introspection -------------------------------------------------------

    @property
    def done(self) -> bool:
        settled = set(self.results) | set(self.failed)
        return settled >= set(self.entries)

    @property
    def scheduler(self) -> JobScheduler | None:
        """The CURRENT scheduler — replaced wholesale by a re-mesh, so
        hold the supervisor, not this."""
        return self._sched

    def stats(self) -> dict:
        return {
            "n_procs": self.n_procs,
            "ticks_run": self.ticks_run,
            "results": sorted(self.results),
            "failed": sorted(self.failed),
            "recoveries": [dataclasses.asdict(r)
                           for r in self.recoveries],
            "timeline": list(self.timeline),
        }

    # -- the tick loop -------------------------------------------------------

    def run(self, max_ticks: int = 10_000) -> dict:
        """Drive the fleet to completion (or ``max_ticks``) under the
        fault plan; returns ``{name: JobResult}`` for every job that
        finished. Terminal failures are in :attr:`failed`, never raised
        — one broken tenant must not take the supervisor down with it."""
        self._ensure_sched()
        tick = self.ticks_run
        end = tick + int(max_ticks)
        while tick < end and not self.done:
            for ev in self.injector.poll(tick):
                self._apply(ev, tick)
            self._stall()
            self._sched.run_until_complete(
                max_slices=self.slices_per_tick)
            self._collect()
            self._heal(tick)
            if (self.ckpt_every and not self.done
                    and tick % self.ckpt_every == self.ckpt_every - 1):
                self._sched.checkpoint(self.fleet)
            tick += 1
            self.ticks_run = tick
        return dict(self.results)

    def _collect(self):
        for j in list(self._sched.jobs):
            if j.state == DONE and j.name not in self.results:
                self.results[j.name] = j.handle.result()

    # -- fault application ---------------------------------------------------

    def _apply(self, ev, tick: int):
        if ev.kind == "kill":
            dead = [r for r in ev.ranks if r < self.n_procs]
            self._log(tick, "kill", ranks=list(dead))
            self._remesh(max(1, self.n_procs - len(dead)), tick, "kill")
        elif ev.kind == "join":
            self._log(tick, "join", ranks=list(ev.ranks))
            self._remesh(self.n_procs + len(ev.ranks), tick, "join")
        elif ev.kind == "slow":
            self._log(tick, "slow", ranks=list(ev.ranks),
                      factor=ev.factor, duration=ev.duration)
            self._slow.append(_SlowState(ev.factor * len(ev.ranks),
                                         ev.duration))
        elif ev.kind == "feed_error":
            entry = self.entries.get(ev.job or "")
            if entry is not None and entry.name not in self.results:
                self._log(tick, "feed_error", job=entry.name,
                          reads=ev.duration)
                entry.source.trip(ev.duration)

    def _stall(self):
        """Serve active slow-rank penalties: the decoupled engines keep
        other ranks' *results* independent, but one mesh means one
        program — a straggling rank stretches every tick's wall time
        (which is exactly what fig13's slow scenario measures)."""
        for s in self._slow:
            time.sleep(s.factor)
            s.remaining -= 1
        self._slow = [s for s in self._slow if s.remaining > 0]

    # -- re-mesh (the tentpole) ----------------------------------------------

    def _remesh(self, p_new: int, tick: int, kind: str):
        t0 = time.perf_counter()
        p_old = self.n_procs
        old = self._sched
        if kind == "join" and old is not None and self.ckpt_every:
            # growing: nothing died, so snapshot the live state first —
            # the grow then loses no work at all
            old.checkpoint(self.fleet)
        if old is not None:
            old.close()          # feeds stop; in-memory carries are gone
        self.n_procs = int(p_new)
        sched = JobScheduler(
            policy=self.policy,
            mesh=make_mesh(remesh_fleet(self.n_procs)),
            max_live_bytes=self.max_live_bytes)
        restored = scratch = 0
        for name, entry in self.entries.items():
            if name in self.results or name in self.failed:
                continue         # already settled: host data, survives
            handle = self._admit(sched, entry)
            if self.restore_on_remesh and self.fleet.has_snapshot(name):
                elastic_restore(handle, self.fleet.manager(name))
                restored += 1
            else:
                scratch += 1
        if self.fleet.has_state():
            # fair share stays fair across the re-mesh: resume tenant
            # service accounting from the last committed fleet manifest
            state = self.fleet.load_state()
            for t, s in state.get("tenants", {}).items():
                sched.tenants[t] = TenantStats(**s)
        self._sched = sched
        self.recoveries.append(RecoveryRecord(
            tick=tick, kind=kind, p_old=p_old, p_new=self.n_procs,
            seconds=time.perf_counter() - t0,
            jobs_restored=restored, jobs_scratch=scratch))

    # -- heal (feed faults) --------------------------------------------------

    def _heal(self, tick: int):
        for j in [j for j in self._sched.jobs if j.state == FAILED]:
            name = j.name
            healable = (isinstance(j.error, InjectedIOError)
                        and self._heals[name] < self.heal_retries)
            self._sched.evict(name)
            if not healable:
                self.failed[name] = j.error
                self._log(tick, "job_failed", job=name,
                          error=repr(j.error))
                continue
            self._heals[name] += 1
            handle = self._admit(self._sched, self.entries[name])
            if self.fleet.has_snapshot(name):
                elastic_restore(handle, self.fleet.manager(name))
            self._log(tick, "healed", job=name,
                      attempt=self._heals[name])

    def _log(self, tick: int, kind: str, **detail):
        self.timeline.append({"tick": tick, "wall": time.perf_counter(),
                              "kind": kind, "p": self.n_procs, **detail})

    # -- teardown ------------------------------------------------------------

    def close(self):
        if self._sched is not None:
            self._sched.close()

    def __enter__(self) -> FleetSupervisor:
        return self

    def __exit__(self, *exc):
        self.close()
        return False
