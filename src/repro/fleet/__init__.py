"""Elastic fleet supervision: fault injection, re-meshing, recovery.

``faults``     — deterministic chaos (:class:`FaultPlan` /
                 :class:`FaultInjector` / :class:`FaultingSource`);
``remesh``     — fold a P_old snapshot onto a P_new mesh, exactly
                 (:func:`elastic_restore`, checksum-verified);
``supervisor`` — the tick loop that keeps a scheduler fleet live
                 through all of it (:class:`FleetSupervisor`).
"""
from repro.fleet.faults import (FaultEvent, FaultInjector, FaultPlan,
                                FaultingSource, InjectedIOError)
from repro.fleet.remesh import (RemeshChecksumError, elastic_restore,
                                fold_program, remesh_program_handles)
from repro.fleet.supervisor import (FleetEntry, FleetSupervisor,
                                    RecoveryRecord)

__all__ = [
    "FaultEvent", "FaultInjector", "FaultPlan", "FaultingSource",
    "InjectedIOError", "RemeshChecksumError", "elastic_restore",
    "fold_program", "remesh_program_handles", "FleetEntry",
    "FleetSupervisor", "RecoveryRecord",
]
