"""Re-mesh a checkpointed job onto a different process count — exactly.

The elastic pivot of the fleet subsystem: a snapshot taken by a P_old
fleet is folded onto P_new surviving ranks and resumed mid-stream, and
the resumed job's records are identical to an unfailed run. Three
properties of the framework make that a theorem rather than a hope:

  * Combine dup-sums records by key across ranks (ownership-transfer
    semantics, paper footnote 2) — ANY redistribution of the per-rank
    dense windows is exact, so ``r_old % P_new`` round-robin folding is
    as good as any;
  * task ids are global (``plan.file_offset = id * task_size`` is
    P-independent) and the planner is decentralized, so re-bucketizing
    the not-yet-executed assignment is pure arithmetic
    (:func:`repro.ft.elastic.rebucketize_tasks`);
  * the owner map is carry *data*, so folding it (``owner % P_new``) and
    clipping split widths re-targets the reduce side without recompiling
    anything the new mesh would not have compiled anyway.

The fold itself runs on the NEW mesh as a tiny SPMD program
(:func:`fold_program`): each surviving rank sums its group of old
windows with ``sat_add_i32`` (the engine's saturating adds — folding
near-full int32 count tables must saturate, not wrap) and the program
emits a psum checksum of the folded fleet. The host verifies it against
the independent numpy twin (:func:`repro.ft.elastic.fold_windows`,
int64-accumulate-then-clip) before the job resumes — a disagreement
means a real fold bug and raises :class:`RemeshChecksumError` instead
of silently resuming with corrupt windows. The program ships through
fleetlint like every engine program (:func:`remesh_program_handles`).
"""
from __future__ import annotations

import numpy as np

from repro.core.kv import KEY_SENTINEL
from repro.core.partition import fold_owner_map, hash_owner_map
from repro.core.windows import AXIS, EngineCarry
from repro.ft.elastic import fold_windows, rebucketize_tasks

I32_MASK = 0xFFFFFFFF


class RemeshChecksumError(RuntimeError):
    """The device fold and the host numpy twin disagree on the folded
    windows — the re-meshed job would resume from corrupt state, so the
    restore refuses. This is a framework bug (the two folds are
    independent implementations of the same sum), not a user error."""


def _wrap_i32_sum(a) -> int:
    """int32 wrap-around sum of an array — the checksum both sides
    compute (two's complement, so numpy int64 mod 2^32 matches XLA's
    int32 accumulation bit-for-bit)."""
    s = int(np.asarray(a, np.int64).sum()) & I32_MASK
    return s - (1 << 32) if s >= (1 << 31) else s


# -- the device fold program -------------------------------------------------

_PROGRAMS: dict = {}


def fold_program(mesh, n_old: int, vocab: int):
    """Compiled SPMD fold on the NEW mesh: (grouped old windows, owner
    map, owner split) -> (folded windows, folded map, clipped split,
    psum checksum).

    Inputs are host-grouped by destination: ``groups[(r % P_new),
    (r // P_new)] = window[r]`` — shape (P_new, G, vocab) with ``G =
    ceil(P_old / P_new)`` and zero padding, so each surviving rank sums
    exactly its own group with the engine's saturating adds. The owner
    map/split rows are replicated (every rank holds the same row); the
    elementwise ``% P_new`` / clip preserves that, and the checksum is
    psum-replicated — the replication contract fleetlint's REP001
    checks on this very program."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from repro.core.combine import sat_add_i32
    from repro.distributed.collectives import shard_map

    n_new = int(mesh.devices.size)
    G = -(-int(n_old) // n_new)
    key = (mesh, n_old, vocab)
    if key in _PROGRAMS:
        return _PROGRAMS[key]

    def body(groups, om, osplit):
        # groups: (1, G, vocab) per shard — ascending g matches the host
        # twin's accumulation order (saturating adds of non-negative
        # counts are order-independent anyway)
        t = groups[0, 0]
        for g in range(1, G):
            t = sat_add_i32(t, groups[0, g])
        om_new = jnp.mod(om, jnp.int32(n_new))
        os_new = jnp.clip(osplit, jnp.int32(1), jnp.int32(n_new))
        csum = lax.psum(jnp.sum(t, dtype=jnp.int32), AXIS)
        return t[None], om_new, os_new, csum[None]

    fn = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(AXIS), P(AXIS), P(AXIS)),
        out_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS))))
    _PROGRAMS[key] = fn
    return fn


def remesh_program_handles(mesh, n_old: int | None = None,
                           vocab: int = 64) -> list:
    """The fold program as fleetlint :class:`ProgramHandle`\\ s — the
    re-mesh path ships through the same static analysis as the engines
    (REP001 proves the folded owner map/split and the checksum really
    are replicated; SPMD001 that the fold only touches ``procs``)."""
    import jax
    import jax.numpy as jnp

    from repro.core.registry import ProgramHandle

    n_new = int(mesh.devices.size)
    if n_old is None:
        n_old = 2 * n_new        # a genuine shrink: G = 2
    G = -(-int(n_old) // n_new)
    fn = fold_program(mesh, n_old, vocab)
    args = (jax.ShapeDtypeStruct((n_new, G, vocab), jnp.int32),
            jax.ShapeDtypeStruct((n_new, vocab), jnp.int32),
            jax.ShapeDtypeStruct((n_new, vocab), jnp.int32))
    return [ProgramHandle(
        name=f"fleet/remesh/fold[{n_old}->{n_new}]",
        fn=fn, args=args,
        arg_paths=("tables", "owner_map", "owner_split"),
        out_paths=("table", "owner_map", "owner_split", "checksum"),
        replicated_in=("owner_map", "owner_split"),
        replicated_out=("owner_map", "owner_split", "checksum"),
        allowed_axes=(AXIS,))]


# -- host orchestration ------------------------------------------------------

def _zeros_like_carry() -> EngineCarry:
    """Structure/dtype-only template for ``CheckpointManager.restore``
    (leaf shapes come from the npz, so one scalar template restores a
    snapshot taken at ANY process count)."""
    return EngineCarry(*(np.zeros((), np.int32)
                         for _ in EngineCarry._fields))


def _fold_pending(carry: EngineCarry) -> np.ndarray:
    """Old per-rank windows with the in-flight ``pending_*`` chunks
    folded in, int32-saturated — the complete record of every executed
    task. Accumulates in int64 then clips, exactly what the engine's
    ``sat_add_i32`` would have produced had it drained the chunk
    (non-negative counts)."""
    table = np.asarray(carry.table)
    P_old = table.shape[0]
    acc = table.astype(np.int64)
    pk = np.asarray(carry.pending_k).reshape(P_old, -1)
    pv = np.asarray(carry.pending_v).reshape(P_old, -1)
    for r in range(P_old):
        valid = pk[r] != int(KEY_SENTINEL)
        np.add.at(acc[r], pk[r][valid], pv[r][valid].astype(np.int64))
    i32 = np.iinfo(np.int32)
    return np.clip(acc, i32.min, i32.max).astype(np.int32)


def _check_compat(handle, found: int, extra: dict):
    """The same snapshot-compatibility guards as ``JobHandle.restore``
    — a cross-P fold cannot paper over a backend/stealing/partitioner
    mismatch any more than a same-P restore can."""
    saved = extra.get("backend")
    if saved is not None and saved != handle.backend.name:
        raise ValueError(
            f"checkpoint step {found} was taken by backend {saved!r} — "
            f"it cannot elastic-restore into a {handle.backend.name!r} "
            f"handle; resubmit with JobConfig(backend={saved!r})")
    saved_steal = extra.get("stealing")
    if (saved_steal is not None
            and bool(saved_steal) != handle.config.stealing):
        raise ValueError(
            f"checkpoint step {found} was taken with "
            f"stealing={bool(saved_steal)} — resubmit with "
            f"JobConfig(stealing={bool(saved_steal)})")
    saved_part = extra.get("partitioner")
    if saved_part is not None and saved_part != handle.spec.partitioner:
        raise ValueError(
            f"checkpoint step {found} was taken with "
            f"partitioner={saved_part!r} — resubmit with "
            f"JobConfig(partitioner={saved_part!r})")


def elastic_restore(handle, manager, step: int | None = None):
    """Resume a snapshot taken at ANY process count into ``handle``
    (which runs at ``handle.spec.n_procs`` — the NEW mesh).

    Same-P snapshots take the ordinary seek-and-restore path. Cross-P
    snapshots are folded: pending chunks into the windows (host), old
    windows/owner maps onto the new ranks (device program on the new
    mesh, checksum-verified against the numpy twin), and the
    not-yet-executed tasks re-bucketized round-robin — then installed
    via :meth:`JobHandle.elastic_load`. No input read is replayed in
    either path; exactness is the module-docstring argument.

    Returns the handle."""
    found, extra = manager.peek(step)
    _check_compat(handle, found, extra)
    P_new = handle.spec.n_procs
    _, carry, extra = manager.restore(_zeros_like_carry(), step=found)
    P_old = int(np.asarray(carry.table).shape[0])
    if P_old == P_new:
        return handle.restore(manager, step=found)

    tables = _fold_pending(carry)                    # (P_old, vocab)
    vocab = tables.shape[1]
    G = -(-P_old // P_new)
    groups = np.zeros((P_new, G, vocab), np.int32)
    for r in range(P_old):
        groups[r % P_new, r // P_new] = tables[r]

    if handle.spec.partitioner == "hash":
        # the hash rule is P-dependent: folding the OLD map % P_new
        # would skew ownership, so feed the fresh P_new rule through the
        # program (its % P_new is then the identity)
        om = hash_owner_map(vocab, P_new)
        osplit = np.ones((vocab,), np.int32)
    else:
        # sampled maps reflect the data's skew, which did not change —
        # fold them (the host twin of the device's % / clip)
        om, osplit = fold_owner_map(
            np.asarray(carry.owner_map)[0],
            np.asarray(carry.owner_split)[0], P_new)
    om = np.ascontiguousarray(
        np.broadcast_to(np.asarray(om, np.int32), (P_new, vocab)))
    osplit = np.ascontiguousarray(
        np.broadcast_to(np.asarray(osplit, np.int32), (P_new, vocab)))

    fn = fold_program(handle.mesh, P_old, vocab)
    table_new, om_new, os_new, csum = fn(groups, om, osplit)
    got = int(np.asarray(csum)[0])
    want = _wrap_i32_sum(fold_windows(tables, P_new))
    if got != want:
        raise RemeshChecksumError(
            f"device fold checksum {got} != host twin {want} folding "
            f"{P_old} -> {P_new} ranks (vocab={vocab}) — refusing to "
            "resume from corrupt windows")

    ids, reps = rebucketize_tasks(
        np.asarray(extra["task_ids"], np.int32),
        np.asarray(extra["repeats"], np.int32),
        int(extra["cursor"]), P_new)
    return handle.elastic_load(np.asarray(table_new),
                               np.asarray(om_new)[0],
                               np.asarray(os_new)[0], ids, reps)
