"""Serving engine: prefill → batched decode with KV-cache management.

``make_serve_step`` builds the exact one-token program the decode dry-run
cells lower (``serve_step``, not ``train_step``): one new token against a
seq_len-sized cache. ``ServeEngine`` wraps it for the example drivers:
batched requests, greedy/temperature sampling, early-stop bookkeeping —
request batching amortizes the weight reads that dominate decode
(memory-roofline term, see EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.config import ModelConfig
from repro.models import transformer as tf
from repro.models.transformer import layer_kind


# ---------------------------------------------------------------------------
# prefill cache -> decode cache layout
# ---------------------------------------------------------------------------

def _convert_layer(cfg: ModelConfig, kind: str, raw: dict, S: int,
                   S_max: int) -> dict:
    """raw prefill cache (seq length S) -> decode layout (capacity S_max)."""
    out = {}
    if kind == "ssm":
        return raw  # state + conv carries are already the decode layout
    if kind == "mla":
        ckv = raw["ckv"]
        pad = [(0, 0), (0, S_max - S), (0, 0)]
        return {"ckv": jnp.pad(ckv, pad)}
    # gqa / swa
    if cfg.attn_type == "swa":
        W = min(cfg.sliding_window, S_max)
        n = min(S, W)
        pos = jnp.arange(S - n, S)          # absolute positions kept
        slots = pos % W
        for name in ("k", "v"):
            ring = jnp.zeros((raw[name].shape[0], W) + raw[name].shape[2:],
                             raw[name].dtype)
            out[name] = ring.at[:, slots].set(raw[name][:, S - n:])
    else:
        for name in ("k", "v"):
            pad = [(0, 0), (0, S_max - S)] + [(0, 0)] * (raw[name].ndim - 2)
            out[name] = jnp.pad(raw[name], pad)
    for name in ("cross_k", "cross_v"):
        if name in raw:
            out[name] = raw[name]
    return out


def prefill_to_decode_cache(cfg: ModelConfig, caches: dict, S: int,
                            S_max: int) -> dict:
    """Convert ``forward(want_cache=True)`` output to ``decode_step`` layout."""
    first = cfg.first_k_dense
    out: dict[str, Any] = {}
    if first:
        out["dense_layers"] = {
            f"layer{i}": _convert_layer(
                cfg, layer_kind(cfg, i)[0],
                caches["dense_layers"][f"layer{i}"], S, S_max)
            for i in range(first)
        }

    def per_block(block_cache):
        return {
            f"layer{j}": _convert_layer(
                cfg, layer_kind(cfg, first + j)[0],
                block_cache[f"layer{j}"], S, S_max)
            for j in range(cfg.block_pattern)
        }

    # blocks subtree is stacked (nb, ...) — convert under vmap so the layout
    # transform applies per block without unstacking
    out["blocks"] = jax.vmap(per_block)(caches["blocks"])
    return out


# ---------------------------------------------------------------------------
# the dry-run serve_step program
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig, *, mesh=None, dp_entry=None,
                    unroll: bool = False):
    """serve_step(params, cache, tokens_t (B,1), t) -> (logits, cache).

    This is the program the decode dry-run cells lower: one new token with a
    KV cache of seq_len.
    """
    def serve_step(params, cache, tokens_t, t):
        return tf.decode_step(cfg, params, cache, tokens_t, t,
                              mesh=mesh, dp_entry=dp_entry, unroll=unroll)
    return serve_step


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeEngine:
    """Batched request serving over one model replica."""
    cfg: ModelConfig
    params: Any
    max_len: int
    mesh: Any = None
    dp_entry: Any = None
    eos_id: int = -1

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.cfg, mesh=self.mesh,
                                             dp_entry=self.dp_entry))
        self._prefill = jax.jit(partial(
            tf.forward, self.cfg, mesh=self.mesh, dp_entry=self.dp_entry,
            want_cache=True))

    def generate(self, prompts: np.ndarray, n_new: int, *,
                 frontend_embeds: np.ndarray | None = None,
                 greedy: bool = True, temperature: float = 1.0,
                 seed: int = 0):
        """prompts: (B, S_prompt) int32 (same length; pad upstream).
        Returns (B, n_new) generated ids."""
        B, S = prompts.shape
        batch = {"tokens": jnp.asarray(prompts)}
        enc_len = 0
        if frontend_embeds is not None:
            batch["frontend_embeds"] = jnp.asarray(frontend_embeds)
            if self.cfg.n_enc_layers:
                enc_len = frontend_embeds.shape[1]
        logits, _, raw = self._prefill(self.params, batch)
        S_ctx = S + (batch["frontend_embeds"].shape[1]
                     if self.cfg.frontend == "vision_stub"
                     and frontend_embeds is not None else 0)
        cache = prefill_to_decode_cache(self.cfg, raw, S_ctx, self.max_len)

        key = jax.random.PRNGKey(seed)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        outs = [tok]
        done = np.zeros((B,), bool)
        for step in range(n_new - 1):
            logits, cache = self._step(self.params, cache, tok,
                                       jnp.int32(S_ctx + step))
            if greedy:
                tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / temperature)[:, None].astype(
                        jnp.int32)
            outs.append(tok)
            if self.eos_id >= 0:
                done |= np.asarray(tok[:, 0] == self.eos_id)
                if done.all():
                    break
        return np.concatenate([np.asarray(o) for o in outs], axis=1)
