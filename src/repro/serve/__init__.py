from repro.serve.engine import (ServeEngine, prefill_to_decode_cache,
                                make_serve_step)
