"""Configuration system for the repro framework.

Dataclass-based, fully static (hashable) so configs can key jit caches.
``ModelConfig`` spans every assigned architecture family (dense / MoE /
hybrid / SSM / enc-dec / VLM / audio); ``ShapeConfig`` carries the assigned
input-shape cells; ``MeshConfig``/``RunConfig`` describe the launch.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads

    # --- normalization ---
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    norm_eps: float = 1e-5

    # --- attention ---
    attn_type: str = "gqa"           # gqa | mla | swa | none
    sliding_window: int = 0          # >0 with attn_type == "swa"
    rope_theta: float = 10_000.0
    qkv_bias: bool = False           # qwen-style bias on qkv
    qk_norm: bool = False

    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1               # MoE on layers where (i % moe_every == moe_every - 1)
    first_k_dense: int = 0           # leading dense layers (deepseek)
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    dispatch_mode: str = "1s"        # "1s" decoupled (paper) | "2s" bulk baseline
    dispatch_groups: int = 4         # chunking for the 1s decoupled schedule
    router_aux_coef: float = 0.01
    expert_tp_axis: str = ""         # shard expert d_ff over this mesh axis
                                     #   (serving: TP-within-expert, no FSDP)

    # --- hybrid (jamba): attention layer every attn_every layers, at attn_offset
    attn_every: int = 0
    attn_offset: int = 0

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq_factor: int = 1          # encoder seq = decoder seq * factor (stub frontend)

    # --- modality frontend stubs ---
    frontend: str = "none"           # none | audio_stub | vision_stub

    # --- numerics / embedding ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # --- block scan structure ---
    block_pattern: int = 1           # layers per scanned super-block

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads > 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # ---- derived ----
    @property
    def n_scan_blocks(self) -> int:
        core = self.n_layers - self.first_k_dense
        assert core % self.block_pattern == 0, (self.name, core, self.block_pattern)
        return core // self.block_pattern

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.first_k_dense:
            return False
        return (i % self.moe_every) == (self.moe_every - 1)

    def is_attn_layer(self, i: int) -> bool:
        """Hybrid stacks: which layers carry attention (vs SSM)."""
        if self.family != "hybrid":
            return self.attn_type != "none"
        return (i % self.attn_every) == self.attn_offset

    @property
    def d_inner(self) -> int:        # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell."""
        return self.family in ("ssm", "hybrid") or self.attn_type == "swa"

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and memory napkin math)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        n_dec = self.n_layers
        for i in range(n_dec):
            total += self._layer_params(i)
            if self.n_enc_layers:        # enc-dec: cross-attn + its norm
                total += self._attn_params(cross=True) + d
        for _ in range(self.n_enc_layers):
            total += self._attn_params(cross=False) + 3 * d * ff + 2 * d
        total += d                        # enc final norm
        return total if self.n_enc_layers else total - d

    def active_param_count(self) -> int:
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            total += self._layer_params(i, active=True)
            if self.n_enc_layers:
                total += self._attn_params(cross=True) + d
        for _ in range(self.n_enc_layers):
            total += self._attn_params(cross=False) + 3 * d * self.d_ff + 2 * d
        return total

    def _attn_params(self, cross: bool = False) -> int:
        d = self.d_model
        if self.attn_type == "mla":
            # q: d->H*(nope+rope); kv down: d->kv_lora + rope; up: kv_lora->H*(nope+v)
            H = self.n_heads
            q = d * H * (self.qk_nope_dim + self.qk_rope_dim)
            kvd = d * (self.kv_lora_rank + self.qk_rope_dim)
            kvu = self.kv_lora_rank * H * (self.qk_nope_dim + self.v_head_dim)
            o = H * self.v_head_dim * d
            return q + kvd + kvu + o
        hd = self.d_head
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def _ssm_params(self) -> int:
        d, di = self.d_model, self.d_inner
        conv_dim = di + 2 * self.ssm_groups * self.ssm_state
        inproj = d * (2 * di + 2 * self.ssm_groups * self.ssm_state + self.n_ssm_heads)
        conv = self.ssm_conv * conv_dim
        out = di * d
        extra = 2 * self.n_ssm_heads + di  # A_log, D, gate norm
        return inproj + conv + out + extra

    def _layer_params(self, i: int, active: bool = False) -> int:
        d = self.d_model
        total = 2 * d  # norms (rms scale x2); nonparam -> 0 but negligible
        if self.family == "ssm" or (self.family == "hybrid" and not self.is_attn_layer(i)):
            total += self._ssm_params()
        else:
            total += self._attn_params()
        if self.family == "ssm":
            return total
        if self.is_moe_layer(i):
            ffe = self.d_ff_expert or self.d_ff
            n_e = (self.top_k if active else self.n_experts)
            total += 3 * d * ffe * (n_e + self.n_shared_experts)
            total += d * self.n_experts  # router
        else:
            total += 3 * d * self.d_ff
        return total


# ---------------------------------------------------------------------------
# Shapes (assigned cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Mesh / run
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...] = (16, 16)
    axes: tuple[str, ...] = ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def dp_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))

    @property
    def dp_size(self) -> int:
        n = 1
        for s, a in zip(self.shape, self.axes):
            if a in ("pod", "data"):
                n *= s
        return n

    @property
    def tp_size(self) -> int:
        for s, a in zip(self.shape, self.axes):
            if a == "model":
                return s
        return 1


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    moment_dtype: str = "float32"        # "bfloat16" for the big archs
    accum_dtype: str = "float32"         # grad-accum buffer ("bfloat16" for 400B-class)
    grad_accum: int = 1
    remat_policy: str = "full"           # full | dots | none
    decoupled_grad_sync: bool = True     # per-layer reduce-scatter (paper-style)
    compress_cross_pod: bool = False     # int8 error-feedback on pod axis
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = SINGLE_POD
    train: TrainConfig = field(default_factory=TrainConfig)
    microbatch: int = 0                  # 0 -> auto
    use_pallas: bool = False             # dry-run lowers the jnp reference path

    def resolved_microbatch(self) -> int:
        if self.microbatch:
            return self.microbatch
        if not self.shape.is_train:
            return self.shape.global_batch
        # Bound live logits: keep ~<=128k tokens per microbatch globally.
        tokens = self.shape.global_batch * self.shape.seq_len
        target = 131_072
        mb = max(1, min(self.shape.global_batch, target // max(1, self.shape.seq_len)))
        while self.shape.global_batch % mb:
            mb -= 1
        return mb

    @property
    def grad_accum_steps(self) -> int:
        if not self.shape.is_train:
            return 1
        return self.shape.global_batch // self.resolved_microbatch()


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
