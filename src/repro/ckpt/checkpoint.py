"""Async checkpointing — the MPI-storage-windows analogue.

The paper's fault tolerance maps windows to storage and calls
``MPI_Win_sync`` after each Map task / Reduce phase; the transfer itself
overlaps compute, so the observed overhead is only ~4.8% (paper Fig 5).

The JAX analogue: a snapshot *reference* (the pytree) is handed to a worker
thread; the worker's ``device_get`` blocks until the async-dispatched device
computation produces the values, while the main thread keeps enqueueing the
next steps — transfer and compute overlap exactly as with storage windows.
Manifest commit is an atomic rename, so a crash mid-write never corrupts the
restore point. ``keep`` bounds disk usage; restore returns (step, tree).

Works for both the MapReduce engine's window carries and the trainer's
param/opt state (launch/train.py). For engine jobs, the unified Job API
is the front door: a segmented ``JobHandle`` calls
``handle.checkpoint(manager)`` after each ``step()`` (async snapshot of
the backend-agnostic EngineCarry; the manifest also records the
SegmentFeed cursor + task assignment) and ``handle.restore(manager)``
resumes by *seeking* the feed — no input read is replayed — see
tests/test_ckpt_ft.py and benchmarks/fig5_ckpt.py.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 2):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------

    def save_async(self, step: int, tree: Any,
                   extra: dict | None = None) -> Future:
        """Non-blocking: the device_get happens in the worker thread, so it
        overlaps whatever the main thread enqueues next (the storage-window
        trick)."""
        return self._pool.submit(self._save, step, tree, extra or {})

    def save(self, step: int, tree: Any, extra: dict | None = None):
        return self._save(step, tree, extra or {})

    def _save(self, step: int, tree: Any, extra: dict):
        t0 = time.perf_counter()
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        arrays = {_leaf_key(path): np.asarray(jax.device_get(leaf))
                  for path, leaf in flat}
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"step-{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "n_leaves": len(arrays),
                       "extra": extra,
                       "wall": time.perf_counter() - t0}, f)
        with self._lock:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)           # atomic commit
            self._gc()
        return final

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def peek(self, step: int | None = None) -> tuple[int, dict]:
        """Read a snapshot's manifest ``extra`` without touching the
        arrays — compatibility checks (e.g. the Job API's backend guard)
        and feed-seek metadata cost no array I/O."""
        if step is None:
            step = self.latest_step()
        assert step is not None, f"no checkpoints in {self.dir}"
        with open(os.path.join(self.dir, f"step-{step}",
                               "manifest.json")) as f:
            return step, json.load(f).get("extra", {})

    def restore(self, tree_like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any, dict]:
        """tree_like provides structure; shardings (optional pytree of
        NamedSharding) places leaves — restore onto a *different* mesh than
        the one that saved is exactly the elastic-restart path."""
        if step is None:
            step = self.latest_step()
        assert step is not None, f"no checkpoints in {self.dir}"
        d = os.path.join(self.dir, f"step-{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        for path, like in flat[0]:
            arr = data[_leaf_key(path)]
            want = np.dtype(like.dtype)
            if arr.dtype != want:
                # npz round-trips ml_dtypes (bf16 etc.) as raw void bytes —
                # reinterpret when widths match, else cast
                arr = (arr.view(want) if arr.dtype.itemsize == want.itemsize
                       and arr.dtype.kind == "V" else arr.astype(want))
            leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(flat[1], leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return step, tree, manifest.get("extra", {})

    def wait(self):
        self._pool.shutdown(wait=True)
        self._pool = ThreadPoolExecutor(max_workers=1)


class FleetStateError(RuntimeError):
    """The fleet manifest (``fleet.json``) is missing or unreadable.

    Raised by :meth:`FleetCheckpoint.load_state` with the directory and
    the surviving per-job snapshot names in the message — after a crash
    the per-job snapshots usually survive even when the queue-state
    commit did not, and an operator (or the elastic supervisor) can
    still resume each job individually through
    ``FleetCheckpoint.manager(name)``."""


class FleetCheckpoint:
    """Scheduler-level checkpoint root: one :class:`CheckpointManager`
    per job (``<dir>/job-<name>/``) plus a queue-state manifest
    (``fleet.json``, atomic rename commit).

    A fleet snapshot is *the set of per-job snapshots + the scheduler's
    queue state* (admission order, tenants, priorities, accounting) —
    ``repro.core.scheduler.JobScheduler.checkpoint/restore`` is the
    front door. Finished jobs' results are not persisted: on restore
    they resume from their latest per-job snapshot (or from scratch if
    none was ever taken), which only re-runs work *after* that snapshot.
    """

    STATE = "fleet.json"

    def __init__(self, directory: str, keep: int = 2):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._managers: dict[str, CheckpointManager] = {}

    @staticmethod
    def _safe(name: str) -> str:
        safe = "".join(c if c.isalnum() or c in "-_." else "_"
                       for c in name)
        if safe == name:
            return safe
        # sanitization is lossy ("job/1" and "job_1" both map to
        # "job_1") — a stable digest of the raw name keeps two distinct
        # jobs from silently sharing one snapshot directory, while
        # restore (which re-derives the path from the same name)
        # still finds it
        import hashlib
        digest = hashlib.sha1(name.encode()).hexdigest()[:8]
        return f"{safe}-{digest}"

    def manager(self, name: str) -> CheckpointManager:
        """The per-job CheckpointManager (created on first use)."""
        if name not in self._managers:
            self._managers[name] = CheckpointManager(
                os.path.join(self.dir, f"job-{self._safe(name)}"),
                keep=self.keep)
        return self._managers[name]

    def has_snapshot(self, name: str) -> bool:
        d = os.path.join(self.dir, f"job-{self._safe(name)}")
        return (os.path.isdir(d)
                and self.manager(name).latest_step() is not None)

    def save_state(self, state: dict) -> str:
        tmp = os.path.join(self.dir, ".fleet.tmp")
        final = os.path.join(self.dir, self.STATE)
        with open(tmp, "w") as f:
            json.dump(state, f, indent=1)
            # the rename is only atomic for bytes that reached the disk:
            # without the fsync a crash can commit an empty/truncated
            # manifest — exactly the torn state load_state must never see
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)               # atomic commit
        return final

    def has_state(self) -> bool:
        """True when a committed fleet manifest exists (it may still be
        unreadable — ``load_state`` raises :class:`FleetStateError` with
        diagnostics in that case)."""
        return os.path.isfile(os.path.join(self.dir, self.STATE))

    def _snapshot_names(self) -> list[str]:
        try:
            return sorted(n for n in os.listdir(self.dir)
                          if n.startswith("job-")
                          and os.path.isdir(os.path.join(self.dir, n)))
        except OSError:
            return []

    def load_state(self) -> dict:
        path = os.path.join(self.dir, self.STATE)
        snaps = self._snapshot_names()
        surviving = (", ".join(snaps) if snaps
                     else "none — nothing was ever checkpointed here")
        if not os.path.isfile(path):
            raise FleetStateError(
                f"no fleet manifest ({self.STATE}) in {self.dir!r}; "
                f"surviving per-job snapshot dirs: {surviving}. Jobs can "
                "still be resumed one at a time via "
                "FleetCheckpoint.manager(<name>), but queue state "
                "(policy, tenants, accounting) is gone")
        try:
            with open(path) as f:
                return json.load(f)
        except ValueError as e:
            raise FleetStateError(
                f"fleet manifest {path!r} is unreadable ({e}); surviving "
                f"per-job snapshot dirs: {surviving}. The manifest commit "
                "is fsync+rename-atomic, so this file was likely "
                "corrupted after the fact") from e

    def wait(self):
        """Flush every job's async save — call before committing the
        fleet manifest so it never references a torn snapshot."""
        for m in self._managers.values():
            m.wait()
