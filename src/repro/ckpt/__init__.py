from repro.ckpt.checkpoint import (CheckpointManager, FleetCheckpoint,
                                   FleetStateError)
