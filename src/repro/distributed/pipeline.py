"""Pipeline parallelism across pods (GPipe schedule).

Why pods: the multi-pod mesh's ``pod`` axis is the thin link (DCN, not
ICI). Baseline multi-pod training runs pure DP across pods — a cross-pod
gradient all-reduce of every parameter each step. Pipelining the *layers*
across pods instead turns cross-pod traffic into per-microbatch activation
sends (collective-permute, point-to-point — the cheapest possible pattern
on DCN), which is the paper's decoupled-push principle applied at the pod
level: partial results (activations) stream forward as they are produced
rather than a bulk synchronous exchange at the end.

Mechanics: ``shard_map`` manual over ``pod`` only (data/model stay GSPMD-
automatic inside). Stage s owns ``blocks[s*nb_loc:(s+1)*nb_loc]`` (the
stacked scan-block dim is sharded over ``pod`` — optimizer state shards
with it for free). The GPipe wavefront runs M + S - 1 steps; step t moves
microbatch m = t - s through stage s, with a ``ppermute`` handing
activations to s+1. Invalid (bubble) slots compute masked work — the
standard GPipe bubble, fraction (S-1)/(M+S-1). Loss is computed on the
last stage and psum'd; ``jax.grad`` differentiates through the schedule
(ppermute transposes to the reverse permute).

Scope: dense stacks (MoE layers use a full-mesh shard_map dispatch that
does not nest inside a partial-manual region; PP+EP composition is future
work — recorded in DESIGN.md).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import axis_size, shard_map
from repro.config import ModelConfig
from repro.models.layers import apply_norm, cross_entropy, embed_tokens, \
    unembed
from repro.models.transformer import _superblock_forward


def _stage_fwd(cfg: ModelConfig, blocks_loc, x, positions, *, remat):
    """Run this stage's nb_loc scanned super-blocks on x."""
    def body(h, bp):
        h, _, _ = _superblock_forward(cfg, bp, h, positions, 0, causal=True)
        return h, None

    if remat != "none":
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, blocks_loc)
    return x


def gpipe_loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, mesh,
                  n_microbatches: int, stage_axis: str = "pod",
                  remat: str = "full"):
    """Pipeline-parallel loss over the ``stage_axis``.

    params["blocks"] leaves arrive stage-sharded (leading dim over
    ``stage_axis``); everything else replicated over it. batch: full
    global batch; microbatched internally (M = n_microbatches).
    """
    M = n_microbatches
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    assert B % M == 0, (B, M)
    mb = B // M
    tok_mb = tokens.reshape(M, mb, S)
    lab_mb = labels.reshape(M, mb, S)

    # tok_mb/lab_mb enter as explicit shard_map operands (not closure
    # captures): jax 0.4.x shard_map cannot infer specs for captured
    # tracers when the region is transposed for the backward pass
    def staged(blocks_loc, embed_p, head_p, tok_mb, lab_mb):
        n_stages = axis_size(stage_axis)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                     (mb, S))
        fwd = partial(_stage_fwd, cfg, blocks_loc, positions=positions,
                      remat=remat)
        # send stage s -> s+1 (last stage's send is dropped)
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def step(carry, t):
            # axis_index is taken per-step on purpose: as a loop-invariant
            # scalar it would become a rank-0 shard_map residual, which
            # jax 0.4.x partial-eval mislabels (see note at the call site)
            sid = lax.axis_index(stage_axis)
            x_in, loss_sum, tok_sum = carry
            m = t - sid                          # microbatch at this stage
            valid = (m >= 0) & (m < M)
            m_c = jnp.clip(m, 0, M - 1)
            # stage 0 ingests a fresh microbatch; others take the handoff
            x0 = embed_tokens(cfg, embed_p, tok_mb[m_c])
            x = jnp.where(sid == 0, x0, x_in).astype(x0.dtype)
            y = fwd(x)
            # last stage: head + CE on its finished microbatch
            h = apply_norm(cfg, head_p["final_norm"], y)
            logits = unembed(cfg, head_p, h)
            ce = cross_entropy(logits, lab_mb[m_c])
            use = valid & (sid == n_stages - 1)
            loss_sum = loss_sum + jnp.where(use, ce, 0.0)
            tok_sum = tok_sum + jnp.where(use, 1.0, 0.0)
            # hand off to the next stage (ppermute; transposed in backward)
            y_send = jnp.where(valid, y, 0.0).astype(y.dtype)
            x_next = lax.ppermute(y_send, stage_axis, perm)
            return (x_next, loss_sum, tok_sum), None

        zero_x = jnp.zeros((mb, S, cfg.d_model), jnp.dtype(cfg.dtype))
        carry = (zero_x, jnp.float32(0.0), jnp.float32(0.0))
        (x, loss_sum, tok_sum), _ = lax.scan(
            step, carry, jnp.arange(M + n_stages - 1))
        # only the last stage holds the loss — share it. The division by
        # the token count happens OUTSIDE the shard_map: as an internal
        # op it would make tok_sum a rank-0 residual, which jax 0.4.x
        # partial-eval mislabels with dim-0 axis names and the backward
        # pass then rejects (_SpecError).
        return (lax.psum(loss_sum, stage_axis)[None],
                lax.psum(tok_sum, stage_axis)[None])

    # check_vma=False: the model's inner scans allocate fresh (pod-
    # invariant) carries which the varying-axis type system would reject;
    # semantics are unaffected (ppermute/psum behave classically)
    loss_sum, tok_sum = shard_map(
        staged, mesh=mesh,
        in_specs=(P(stage_axis), P(), P(), P(), P()),
        out_specs=(P(), P()),
        axis_names={stage_axis},
        check_vma=False,
    )(params["blocks"],
      {"embed_tokens": params["embed_tokens"]},
      {"final_norm": params["final_norm"],
       **({"lm_head": params["lm_head"]} if "lm_head" in params
          else {"embed_tokens": params["embed_tokens"]})},
      tok_mb, lab_mb)
    loss = loss_sum[0] / jnp.maximum(tok_sum[0], 1.0)
    return loss, {"ce": loss, "aux": jnp.float32(0.0)}


def pp_param_specs(params: Any, cfg: ModelConfig, mesh_cfg,
                   stage_axis: str = "pod"):
    """Baseline specs + the blocks' scan dim sharded over the stage axis
    (each pod stores only its stage — optimizer state follows)."""
    from repro.distributed.sharding import param_specs

    base = param_specs(params, cfg, mesh_cfg)

    def visit(path, spec):
        keys = [str(getattr(p, "key", p)) for p in path]
        if "blocks" in keys and len(spec) > 0:
            return P(stage_axis, *spec[1:])
        return spec

    return jax.tree_util.tree_map_with_path(visit, base)


def make_pp_train_step(cfg: ModelConfig, tcfg, *, mesh,
                       n_microbatches: int, stage_axis: str = "pod"):
    """PP train step (AdamW update shared with the standard path)."""
    from repro.optim.adamw import adamw_update
    from repro.train.train_step import TrainState

    # inner jit is load-bearing on jax 0.4.x: differentiating the raw
    # shard_map hits a partial-eval path that mislabels rank-0 residuals
    # (_SpecError); grad-of-jit takes the pjit path, which is sound
    loss_jit = jax.jit(lambda p, b: gpipe_loss_fn(
        cfg, p, b, mesh=mesh, n_microbatches=n_microbatches,
        stage_axis=stage_axis, remat=tcfg.remat_policy))

    def train_step(state: TrainState, batch: dict):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_jit(p, batch), has_aux=True)(state.params)
        new_params, new_opt, om = adamw_update(state.params, grads,
                                               state.opt, tcfg)
        return TrainState(new_params, new_opt, state.residual), \
            dict(metrics, loss=loss, **om)

    return train_step
