"""Collective helpers shared by the MapReduce engine and the MoE layer.

Everything here runs *inside* ``shard_map`` regions (named-axis collectives).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def axis_size(name: str) -> int:
    return lax.axis_size(name)


def pvary(x, axis):
    """Mark fresh constants as axis-varying inside shard_map regions
    (required by the VMA type system for scan carries that meet collective
    outputs)."""
    return jax.tree.map(lambda a: lax.pcast(a, (axis,), to="varying"), x)


def all_to_all_blocks(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Exchange equal blocks: x has leading dim P (one block per peer).

    Row j of the result is the block rank j addressed to us. This is the
    JAX-native carrier for the paper's bucketed shuffle (MPI_Alltoallv with
    fixed-capacity buckets).
    """
    P = lax.axis_size(axis)
    assert x.shape[0] == P, (x.shape, P)
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def ring_send_right(x: jnp.ndarray, axis: str, shift: int = 1) -> jnp.ndarray:
    P = lax.axis_size(axis)
    perm = [(i, (i + shift) % P) for i in range(P)]
    return lax.ppermute(x, axis, perm)


def tree_gather_permute(x, axis: str, level: int):
    """collective_permute used by the combine tree: at ``level`` l, rank
    i + 2**l sends its payload to rank i (for i multiple of 2**(l+1))."""
    P = lax.axis_size(axis)
    stride = 1 << level
    perm = []
    for i in range(0, P, stride * 2):
        if i + stride < P:
            perm.append((i + stride, i))
    return lax.ppermute(x, axis, perm)


def psum_dp(x, mesh_cfg):
    """psum over all data-parallel axes (pod + data) under shard_map."""
    for ax in mesh_cfg.dp_axes:
        x = lax.psum(x, ax)
    return x
