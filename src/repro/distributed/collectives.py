"""Collective helpers shared by the MapReduce engine and the MoE layer.

Everything here runs *inside* ``shard_map`` regions (named-axis
collectives). The module doubles as the jax version-compat seam: the
container pins jax 0.4.x, where ``shard_map`` still lives under
``jax.experimental``, ``lax.axis_size`` does not exist (``lax.psum(1,
axis)`` folds to a concrete int at trace time — the classic idiom), and
the VMA type system (``lax.pcast``) has not landed. Newer jax keeps
working through the same wrappers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
else:                                               # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=None):
        # check_rep predates (and over-rejects) the collectives we use.
        # axis_names is dropped: 0.4.x partial-auto mode cannot be
        # differentiated through, while full-manual over a mesh whose
        # extra axes are simply unreferenced is semantically identical.
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def axis_size(name: str) -> int:
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)        # concrete int at trace time


def pvary(x, axis):
    """Mark fresh constants as axis-varying inside shard_map regions
    (required by the VMA type system for scan carries that meet collective
    outputs; identity on jax versions without VMA)."""
    if not hasattr(lax, "pcast"):
        return x
    return jax.tree.map(lambda a: lax.pcast(a, (axis,), to="varying"), x)


def all_to_all_blocks(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Exchange equal blocks: x has leading dim P (one block per peer).

    Row j of the result is the block rank j addressed to us. This is the
    JAX-native carrier for the paper's bucketed shuffle (MPI_Alltoallv with
    fixed-capacity buckets).
    """
    P = axis_size(axis)
    assert x.shape[0] == P, (x.shape, P)
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def coded_exchange(bk: jnp.ndarray, bv: jnp.ndarray, axis: str,
                   code_rate: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One XOR-coded multicast step of the bucket shuffle (Coded
    MapReduce, arXiv 1512.01625; host half in ``repro.core.coded``).

    ``bk``/``bv`` are the (P, cap) per-destination buckets that every
    member of an r-rank code group computed *identically* (the group
    maps the same replicated task block). Instead of unicasting r-1
    bucket rows to its group peers, each member ships ONE coded block —
    the XOR of the buckets destined for its peers — and each receiver
    decodes its own bucket from its designated peer's block by XOR-ing
    back the side information it mapped locally. Inter-group rows are
    deduplicated to a single speaker per destination (member ``q % r``
    of every group speaks for destination ``q``), so with the Combine
    dup-sum each record still folds exactly once fleet-wide.

    Returns the (P, cap) pending rows ready to fold: the decoded bucket
    on the designated-peer row, speaker buckets as received, and every
    other row (raw coded blocks, the self row, silent non-speakers)
    cleared to sentinel-empty.
    """
    from functools import reduce

    from repro.core.kv import KEY_SENTINEL
    r = int(code_rate)
    P = axis_size(axis)
    assert r > 1 and P % r == 0, (P, r)
    me = lax.axis_index(axis)
    g, m = me // r, me % r
    q = jnp.arange(P)
    in_group = (q // r) == g
    peer = in_group & (q != me)

    def _xor(x, mask):
        rows = jnp.where(mask[:, None], x, 0)
        return reduce(jnp.bitwise_xor, [rows[i] for i in range(P)])

    # encode: X = XOR of the buckets destined for my r-1 group peers
    xk, xv = _xor(bk, peer), _xor(bv, peer)
    speak = (~in_group) & ((q % r) == m)
    sk = jnp.where(peer[:, None], xk[None, :],
                   jnp.where(speak[:, None], bk, KEY_SENTINEL))
    sv = jnp.where(peer[:, None], xv[None, :],
                   jnp.where(speak[:, None], bv, 0))
    gk = all_to_all_blocks(sk, axis)
    gv = all_to_all_blocks(sv, axis)
    # decode my bucket from the designated peer's coded block: its XOR
    # covers the whole group but the sender, so XOR-ing the locally
    # mapped buckets of everyone else leaves exactly the one for me
    d = g * r + (m + 1) % r
    side = in_group & (q != me) & (q != d)
    dk = gk[d] ^ _xor(bk, side)
    dv = gv[d] ^ _xor(bv, side)
    is_d = (q == d)[:, None]
    rk = jnp.where(in_group[:, None],
                   jnp.where(is_d, dk[None, :], KEY_SENTINEL), gk)
    rv = jnp.where(in_group[:, None],
                   jnp.where(is_d, dv[None, :], 0), gv)
    return rk, rv


def ring_send_right(x: jnp.ndarray, axis: str, shift: int = 1) -> jnp.ndarray:
    P = axis_size(axis)
    perm = [(i, (i + shift) % P) for i in range(P)]
    return lax.ppermute(x, axis, perm)


def tree_gather_permute(x, axis: str, level: int):
    """collective_permute used by the combine tree: at ``level`` l, rank
    i + 2**l sends its payload to rank i (for i multiple of 2**(l+1))."""
    P = axis_size(axis)
    stride = 1 << level
    perm = []
    for i in range(0, P, stride * 2):
        if i + stride < P:
            perm.append((i + stride, i))
    return lax.ppermute(x, axis, perm)


def psum_dp(x, mesh_cfg):
    """psum over all data-parallel axes (pod + data) under shard_map."""
    for ax in mesh_cfg.dp_axes:
        x = lax.psum(x, ax)
    return x
