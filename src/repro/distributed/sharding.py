"""Logical → mesh sharding rules.

Parameters are nested dicts with conventional leaf names (see models/).
``param_specs`` walks the tree and assigns a PartitionSpec per leaf:

  * Megatron TP over the ``"model"`` axis on head / d_ff / vocab / expert dims,
    only when the dim is divisible by tp (GQA archs with kv_heads < tp use
    Megatron-style KV replication: q/o sharded on heads, k/v replicated).
  * FSDP (ZeRO-3-style) over the ``"data"`` axis on one remaining dim of every
    matrix, when divisible. Cross-pod stays pure DP (pod axis replicates
    params; gradients reduce over it) — the right default for DCN links.
  * Stacked scan blocks get a leading ``None`` for the layer dim.

Activations / logits / KV-cache specs live here too so train/, serve/ and
launch/ agree on one source of truth.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, MeshConfig


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _divisible(dim: int, by: int) -> bool:
    return by > 0 and dim % by == 0


def _dp_entry(mesh_cfg: MeshConfig):
    axes = mesh_cfg.dp_axes
    return axes[0] if len(axes) == 1 else tuple(axes)


def _fsdp_axis(mesh_cfg: MeshConfig) -> str:
    return "data"


def _fsdp_size(mesh_cfg: MeshConfig) -> int:
    for s, a in zip(mesh_cfg.shape, mesh_cfg.axes):
        if a == "data":
            return s
    return 1


# --------------------------------------------------------------------------
# per-leaf rule
# --------------------------------------------------------------------------

def _leaf_spec(name: str, shape, cfg: ModelConfig, mesh_cfg: MeshConfig,
               variant: str = "default") -> P:
    """Spec for an *unstacked* leaf (no leading scan dim).

    variants (§Perf hillclimb levers, EXPERIMENTS.md):
      default  — Megatron TP over "model" + FSDP over "data" (baseline)
      flat_dp  — no TP: pure FSDP with params sharded over the flattened
                 ("data","model") axes; batch over both axes too
      serve    — no FSDP (nothing re-gathers per step): dense TP over
                 "model", experts EP over "model" + d_ff TP over
                 ``cfg.expert_tp_axis``
    """
    tp = mesh_cfg.tp_size if "model" in mesh_cfg.axes else 0
    fa, fs = _fsdp_axis(mesh_cfg), _fsdp_size(mesh_cfg)
    if variant == "flat_dp":
        tp = 0                                    # no Megatron TP anywhere
        fa = tuple(mesh_cfg.axes)                 # flat FSDP
        fs = mesh_cfg.n_devices
    elif variant == "serve":
        fs = 0                                    # disables FSDP fill
    heads_ok = _divisible(cfg.n_heads, tp)
    kv_ok = _divisible(cfg.n_kv_heads, tp)
    ssm_ok = cfg.ssm_head_dim and _divisible(cfg.d_inner // cfg.ssm_head_dim, tp)

    def mat(d_in_axis, d_out_axis):
        """2D matrix (in, out); axes may be None."""
        spec = [d_in_axis, d_out_axis]
        # FSDP on the first unsharded, divisible dim.
        for i in range(2):
            if spec[i] is None and _divisible(shape[i], fs):
                spec[i] = fa
                break
        return P(*spec)

    V, D = cfg.vocab_size, cfg.d_model
    vocab_ok = _divisible(V, tp)

    if name == "embed_tokens":                      # (V, D)
        return mat("model" if vocab_ok else None, None)
    if name == "lm_head":                           # (D, V)
        return mat(None, "model" if vocab_ok else None)
    if name in ("wq", "q_a"):                       # (D, H*hd)
        return mat(None, "model" if heads_ok else None)
    if name in ("wk", "wv"):                        # (D, KV*hd)
        return mat(None, "model" if kv_ok else None)
    if name in ("bq",):                             # (H*hd,)
        return P("model") if heads_ok and _divisible(shape[0], tp) else P(None)
    if name in ("bk", "bv"):
        return P("model") if kv_ok and _divisible(shape[0], tp) else P(None)
    if name == "wo":                                # (H*hd, D)
        return mat("model" if heads_ok else None, None)
    if name in ("w_gate", "w_in"):                  # (D, F)
        return mat(None, "model" if _divisible(shape[1], tp) else None)
    if name == "w_out":                             # (F, D)
        return mat("model" if _divisible(shape[0], tp) else None, None)
    if name == "router":                            # (D, E)
        return mat(None, None)
    if name in ("we_gate", "we_in", "we_out"):      # (E, D, Fe) / (E, Fe, D)
        e_ax = "model" if _divisible(shape[0], tp) else None
        if variant == "serve" and cfg.expert_tp_axis:
            # TP-within-expert over the data axis: d_ff sharded, outputs
            # partial-summed (moe_forward psums) — zero per-step re-gather
            f_dim = 2 if name in ("we_gate", "we_in") else 1
            spec = [e_ax, None, None]
            spec[f_dim] = cfg.expert_tp_axis
            return P(*spec)
        rest = [None, None]
        for i in (1, 2):
            if _divisible(shape[i], fs):
                rest[i - 1] = fa
                break
        return P(e_ax, *rest)
    if name == "w_kv_a":                            # (D, lora+rope) — small, replicate TP
        return mat(None, None)
    if name == "w_kv_b":                            # (lora, H*(nope+v))
        return mat(None, "model" if heads_ok else None)
    # --- SSM leaves ---
    if name in ("w_z", "w_x"):                      # (D, d_inner)
        return mat(None, "model" if ssm_ok else None)
    if name in ("w_B", "w_C"):                      # (D, G*N) — shared across heads
        return mat(None, None)
    if name == "w_dt":                              # (D, n_ssm_heads)
        return mat(None, "model" if ssm_ok else None)
    if name == "conv_x":                            # (K, d_inner)
        return P(None, "model") if ssm_ok else P(None, None)
    if name in ("conv_B", "conv_C"):                # (K, G*N)
        return P(None, None)
    if name in ("A_log", "D_skip", "dt_bias"):      # (n_ssm_heads,)
        return P("model") if ssm_ok else P(None)
    if name == "gate_norm":                         # (d_inner,)
        return P("model") if ssm_ok else P(None)
    # norms / scalars / anything 1-D: replicate
    return P(*([None] * len(shape)))


def _stacked(spec: P) -> P:
    return P(None, *spec)


def param_specs(params_or_shapes: Any, cfg: ModelConfig, mesh_cfg: MeshConfig,
                variant: str = "default"):
    """Pytree of PartitionSpec matching ``params``.

    Leaves under a ``blocks`` / ``enc_blocks`` subtree are scan-stacked and get
    a leading None.
    """
    def visit(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = keys[-1]
        stacked = any(k in ("blocks", "enc_blocks") for k in keys)
        shape = leaf.shape
        if stacked:
            shape = shape[1:]
        spec = _leaf_spec(name, shape, cfg, mesh_cfg, variant)
        return _stacked(spec) if stacked else spec

    return jax.tree_util.tree_map_with_path(visit, params_or_shapes)


def shard_params(params, cfg: ModelConfig, mesh, mesh_cfg: MeshConfig):
    specs = param_specs(params, cfg, mesh_cfg)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params, specs
    )


# --------------------------------------------------------------------------
# activation / cache specs
# --------------------------------------------------------------------------

def activation_spec(mesh_cfg: MeshConfig, batch: int) -> P:
    """(B, S, D) hidden states: batch over dp axes when divisible."""
    dp = _dp_entry(mesh_cfg)
    if batch % mesh_cfg.dp_size == 0:
        return P(dp, None, None)
    if batch % _fsdp_size(mesh_cfg) == 0:
        return P("data", None, None)
    return P(None, None, None)


def tokens_spec(mesh_cfg: MeshConfig, batch: int) -> P:
    a = activation_spec(mesh_cfg, batch)
    return P(a[0], None)


def logits_spec(cfg: ModelConfig, mesh_cfg: MeshConfig, batch: int) -> P:
    a = activation_spec(mesh_cfg, batch)
    vocab_ok = _divisible(cfg.vocab_size, mesh_cfg.tp_size)
    return P(a[0], None, "model" if vocab_ok else None)


def kv_cache_spec(cfg: ModelConfig, mesh_cfg: MeshConfig, batch: int) -> P:
    """KV cache (B, S, KV, hd) [GQA] or (B, S, C) [MLA compressed].

    Sequence-sharded over ``model`` — uniform flash-decode layout that works
    for every kv_heads count and keeps 32k–512k caches within HBM.
    """
    a = activation_spec(mesh_cfg, batch)
    return P(a[0], "model")  # trailing dims replicated


def batch_axis_size(mesh_cfg: MeshConfig, batch: int) -> int:
    """How many ways the batch is actually sharded (for shard_map blocks)."""
    if batch % mesh_cfg.dp_size == 0:
        return mesh_cfg.dp_size
    if batch % _fsdp_size(mesh_cfg) == 0:
        return _fsdp_size(mesh_cfg)
    return 1
