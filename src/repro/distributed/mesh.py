"""Mesh construction helpers.

``launch/mesh.py`` owns the *production* mesh (16x16 / 2x16x16); this module
holds the generic machinery: building a mesh for any MeshConfig, including
tiny CPU meshes for tests, plus PartitionSpec helpers shared across the stack.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MeshConfig


def abstract_devices(n: int):
    """The devices visible to this process (CPU container: host devices)."""
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh needs {n} devices but only {len(devs)} are visible; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=N *before* "
            "importing jax (launch/dryrun.py does this)."
        )
    return devs[:n]


def make_mesh(cfg: MeshConfig) -> Mesh:
    devs = abstract_devices(cfg.n_devices)
    import numpy as np
    arr = np.array(devs).reshape(cfg.shape)
    return Mesh(arr, cfg.axes)


def local_mesh(shape=(1, 1), axes=("data", "model")) -> Mesh:
    """Tiny mesh over whatever devices exist — for smoke tests on CPU."""
    return make_mesh(MeshConfig(tuple(shape), tuple(axes)))


def dp_spec(mesh_cfg: MeshConfig) -> tuple:
    """The mesh axes carrying data parallelism, as a PartitionSpec entry."""
    axes = mesh_cfg.dp_axes
    if len(axes) == 1:
        return axes[0]
    return tuple(axes)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
