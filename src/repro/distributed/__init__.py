from repro.distributed.mesh import make_mesh, local_mesh, dp_spec, abstract_devices
from repro.distributed.sharding import (
    param_specs, activation_spec, logits_spec, kv_cache_spec, shard_params,
)
