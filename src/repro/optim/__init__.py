from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               lr_schedule, global_norm, clip_by_global_norm)
from repro.optim.compress import compress_int8, decompress_int8
