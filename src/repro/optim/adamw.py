"""AdamW with ZeRO-style sharded moments.

Moments inherit the parameter sharding (the sharding layer fully shards the
big leaves over data×model, so optimizer state is ZeRO-sharded for free —
the per-leaf reduce-scatter of FSDP gradients is the training-loop analogue
of the paper's "push partial results early": gradient communication happens
per scanned super-block inside the backward pass, not as one fused
end-of-step all-reduce).

``moment_dtype="bfloat16"`` halves optimizer memory for the 400B-class archs
(error analysis: second-moment bf16 rounding is dominated by eps at the
magnitudes LM training sees; first moment keeps a stochastic-rounding-free
bf16 with fp32 math at update time).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any                      # first moment (param pytree)
    nu: Any                      # second moment (param pytree)


def adamw_init(params, cfg: TrainConfig) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def lr_schedule(cfg: TrainConfig, step):
    """Linear warmup → cosine decay to 10%."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state: AdamWState, cfg: TrainConfig):
    """Returns (new_params, new_state, metrics). ``grad_clip <= 0`` disables
    clipping (but still reports the norm)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2, eps = cfg.b1, cfg.b2, cfg.eps
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mf.astype(mdt), vf.astype(mdt)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": lr}
