"""int8 gradient compression with fp32 error feedback.

At 1000+-node scale the cross-pod DCN link is ~10× thinner than in-pod ICI,
so the pod-axis gradient all-reduce is the one worth compressing. The
scheme: per-leaf symmetric int8 quantization, residual kept locally and
added back next step (error feedback keeps the quantization bias out of the
long-run gradient estimate). Applied only to the ``pod`` axis reduction
(train/train_step.py wires it in when ``compress_cross_pod=True``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jnp.ndarray):
    """x (fp) -> (int8 codes, fp32 scale). Symmetric, per-tensor."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress_leaf(g: jnp.ndarray, residual: jnp.ndarray):
    """One error-feedback round: returns (decompressed g_hat, new_residual).

    g_hat is what actually crosses the wire (int8 + one scale); the residual
    (g - g_hat) stays local and is folded into the next step's gradient.
    """
    g_corr = g.astype(jnp.float32) + residual
    q, scale = compress_int8(g_corr)
    g_hat = decompress_int8(q, scale)
    return g_hat.astype(g.dtype), g_corr - g_hat


def ef_compress(grads, residuals):
    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [ef_compress_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    g_hat = jax.tree.unflatten(tree, [o[0] for o in outs])
    new_res = jax.tree.unflatten(tree, [o[1] for o in outs])
    return g_hat, new_res


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
