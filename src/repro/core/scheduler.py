"""Multi-tenant job scheduler — many JobHandles multiplexed over one mesh.

The paper's decoupled strategy lets *processes* progress independently
when workloads are unbalanced; the same argument applies one level up:
when *jobs* are unbalanced, a long straggler job must not serialize
every other tenant behind it. OS4M (PAPERS.md) makes the case for
scheduling at operation granularity rather than job granularity; our
segmented engines expose exactly that granularity — ``JobHandle.step()``
runs one fixed-shape segment — so a host-side scheduler can time-slice
many live jobs over one device mesh and one set of compiled programs:

    sched = JobScheduler(policy="fair", max_live_bytes=256 << 20)
    h1 = sched.submit(cfg_big,   corpus,  tenant="batch")
    h2 = sched.submit(cfg_small, queries, tenant="interactive",
                      priority=1)
    results = sched.run_until_complete()       # {name: JobResult}

The cooperative contract with :class:`~repro.core.job.JobHandle`:

  * ``step()``  — runs exactly one fixed-shape segment then yields the
    host thread back (no job can hog the mesh between boundaries);
  * ``ready()`` — True when the next step would not block on input I/O,
    so the scheduler polls N feeds without blocking on any of them;
  * jitted-program memoization keys on ``JobSpec`` + use-case: jobs
    sharing a spec share ONE compiled engine (asserted at admission —
    K tenants pay one compile, see ``n_unique_programs``).

Every feed the scheduler creates shares one
:class:`~repro.data.feed.FeedBudget`, so N tenants prefetching
concurrently cannot OOM the host; a bounded admission queue
(``max_pending``) pushes back on submit instead of accepting unbounded
work. Per-tenant accounting (segments run, work executed, wall time)
feeds the fair-share policy and the multi-tenant benchmark's Jain
fairness index (benchmarks/fig11_multitenant.py).

Scheduling policies are pluggable (:class:`SchedulePolicy`):

  * ``"fifo"``     — strict admission order; the head-of-line baseline.
  * ``"fair"``     — least-service-first across tenants (processor
    sharing at segment granularity): a tenant's short job finishes in
    ~K × its own time, not after every earlier giant.
  * ``"priority"`` — highest priority first, FIFO within a class.

A fleet checkpoint (:meth:`JobScheduler.checkpoint`) is the set of
per-job snapshots plus the queue state
(:class:`~repro.ckpt.checkpoint.FleetCheckpoint`); restore seeks every
live job's feed — resuming mid-fleet without replaying any read — and
``repro.ft.straggler.rebalance_hook`` plugs the coarse re-planning loop
in as a per-job ``on_slice`` hook.
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import asdict, dataclass
from collections.abc import Callable, Sequence
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.job import JobConfig, JobHandle, JobResult
from repro.core.job import submit as _submit
from repro.data.feed import FeedBudget

QUEUED, LIVE, DONE, FAILED = "queued", "live", "done", "failed"


class AdmissionQueueFull(RuntimeError):
    """Backpressure: the scheduler's bounded admission queue is at
    ``max_pending`` open jobs — finish (or fail) some before submitting
    more. Catch it and retry after ``run_until_complete`` drains."""


@dataclass
class TenantStats:
    """Per-tenant service accounting (the currency of fair share)."""
    segments: int = 0        # engine segments executed for this tenant
    work: int = 0            # compute-repeat units executed
    wall: float = 0.0        # host seconds spent on this tenant's slices
    jobs_done: int = 0
    jobs_failed: int = 0


@dataclass
class SliceStats:
    """What one scheduler slice executed — handed to ``on_slice`` hooks
    (e.g. ``repro.ft.straggler.rebalance_hook``) and the unit of tenant
    service charging: ``work_executed`` is what lands in
    ``TenantStats.work``, so co-scheduled and solo slices are charged
    comparably (a domain slice executes SEVERAL tenants' tasks — each
    tenant is charged its slots' share from ``carry.job_work``, never
    the whole slice)."""
    seconds: float
    segments: int
    work_per_rank: np.ndarray    # assigned work consumed this slice (P,)
    work_executed: int = 0       # compute-repeats actually executed for
                                 #   the charged tenant this slice


@dataclass
class ScheduledJob:
    """One admitted job: the handle plus scheduling metadata/accounting."""
    name: str
    tenant: str
    priority: int
    seq: int                     # admission order (FIFO key)
    handle: JobHandle
    on_slice: Callable | None = None
    state: str = QUEUED
    segments_run: int = 0
    work_done: int = 0
    wall: float = 0.0            # host seconds across this job's slices
    submitted_at: float = 0.0    # perf_counter stamps
    finished_at: float | None = None
    error: BaseException | None = None
    # cross-job co-scheduling: set when this job is a member of a
    # WorkDomain (core/workdomain.py) — its tasks execute inside the
    # domain's composite program, so slicing/readiness delegate there
    domain: object | None = None

    @property
    def ready(self) -> bool:
        if self.domain is not None:
            return self.domain.ready()
        return self.handle.ready()


@runtime_checkable
class SchedulePolicy(Protocol):
    """Pick the next job to slice. ``candidates`` is the non-empty list
    of live jobs (admission order); ``tenants`` the scheduler's
    accounting, keyed by tenant name — policies may consult service
    received and per-job readiness, and must return one candidate."""

    name: str

    def pick(self, candidates: Sequence[ScheduledJob],
             tenants: dict[str, TenantStats]) -> ScheduledJob:
        ...


class FifoPolicy:
    """Strict admission order — the head-of-line-blocking baseline a
    straggler job turns into everyone's problem (fig11)."""
    name = "fifo"

    def pick(self, candidates, tenants):
        return min(candidates, key=lambda j: j.seq)


class PriorityPolicy:
    """Highest ``priority`` first; FIFO inside a priority class."""
    name = "priority"

    def pick(self, candidates, tenants):
        return min(candidates, key=lambda j: (-j.priority, j.seq))


class FairSharePolicy:
    """Least-service-first across tenants — processor sharing at
    segment granularity. The tenant that has executed the least work so
    far runs next; within the tie set, jobs whose next segment is
    already prefetched (``ready``) go first so the mesh never idles on
    one tenant's I/O; admission order breaks the final tie."""
    name = "fair"

    def pick(self, candidates, tenants):
        def service(j):
            return tenants[j.tenant].work
        least = min(service(j) for j in candidates)
        pool = [j for j in candidates if service(j) == least]
        ready = [j for j in pool if j.ready]
        return min(ready or pool, key=lambda j: j.seq)


_POLICIES = {p.name: p for p in (FifoPolicy, FairSharePolicy,
                                 PriorityPolicy)}


def available_policies() -> list[str]:
    return sorted(_POLICIES)


def resolve_policy(policy: str | SchedulePolicy) -> SchedulePolicy:
    if isinstance(policy, str):
        if policy not in _POLICIES:
            raise ValueError(f"unknown policy {policy!r}; available: "
                             f"{available_policies()}")
        return _POLICIES[policy]()
    if not isinstance(policy, SchedulePolicy):
        raise TypeError(f"{policy!r} does not implement SchedulePolicy")
    return policy


class JobScheduler:
    """Admit many jobs, time-slice them at segment granularity over one
    mesh. See the module docstring for the full contract.

    Parameters
    ----------
    policy:         ``"fifo" | "fair" | "priority"`` or any
                    :class:`SchedulePolicy` instance.
    mesh:           shared device mesh; built lazily from the first
                    job's ``n_procs`` when omitted. Every subsequent job
                    must match it — one mesh, many tenants.
    max_pending:    bounded admission queue — ``submit`` raises
                    :class:`AdmissionQueueFull` past this many open
                    (queued + live) jobs.
    max_active:     at most this many jobs are *live* (feeds prefetching,
                    being sliced) at once; the rest wait in admission
                    order. ``None`` = all admitted jobs run interleaved.
    max_live_bytes: shared :class:`~repro.data.feed.FeedBudget` over
                    every feed's in-flight prefetch bytes (``None`` =
                    unbounded).
    slice_segments: segments per time slice (1 = finest interleaving).
    coschedule:     form :class:`~repro.core.workdomain.WorkDomain`\\ s
                    at activation: program-compatible eligible jobs
                    merge into ONE composite engine run, so one device
                    step executes tasks from several tenants and fast
                    ranks backfill across job boundaries (global work
                    stealing). Ineligible jobs (fused_map, sampling
                    partitioners, '2s') cleanly fall back to solo
                    slicing. Each tenant is charged the work its slots
                    actually *executed* (``carry.job_work``), so fair
                    share stays fair under mixed slices.
    copack:         member segments packed per domain segment
                    (default: the domain size K).
    """

    def __init__(self, *, policy: str | SchedulePolicy = "fair",
                 mesh=None, max_pending: int | None = None,
                 max_active: int | None = None,
                 max_live_bytes: int | None = None,
                 slice_segments: int = 1,
                 coschedule: bool = False,
                 copack: int | None = None):
        self.policy = resolve_policy(policy)
        self.mesh = mesh
        self.max_pending = max_pending
        self.max_active = max_active
        self.slice_segments = int(slice_segments)
        self.coschedule = bool(coschedule)
        self.copack = copack
        self.budget = (FeedBudget(max_live_bytes)
                       if max_live_bytes else None)
        self.jobs: list[ScheduledJob] = []
        self.tenants: dict[str, TenantStats] = defaultdict(TenantStats)
        self.run_started_at: float | None = None
        self._by_name: dict[str, ScheduledJob] = {}
        self._programs: dict = {}        # (backend, spec, map_fn) -> fns
        self._domains: list = []         # live WorkDomains, admission order
        self._n_procs: int | None = None

    # -- admission -----------------------------------------------------------

    def submit(self, config: JobConfig, dataset, *, priority: int = 0,
               tenant: str = "default", name: str | None = None,
               on_slice: Callable | None = None,
               repeats=None) -> JobHandle:
        """Admit a job; returns its :class:`JobHandle` (nothing executes
        until :meth:`run_until_complete`; after it, ``handle.result()``
        is the cached outcome). Jobs must be segmented
        (``JobConfig(segment=N)``) — a oneshot job cannot yield the mesh
        between segments and would defeat the time slicing."""
        if config.segment <= 0:
            raise ValueError(
                "JobScheduler needs segmented jobs — set "
                "JobConfig(segment=N); a oneshot job runs its whole "
                "input in one step() and cannot be time-sliced")
        n_open = sum(j.state in (QUEUED, LIVE) for j in self.jobs)
        if self.max_pending is not None and n_open >= self.max_pending:
            raise AdmissionQueueFull(
                f"admission queue full: {n_open} open job(s) >= "
                f"max_pending={self.max_pending}; run_until_complete() "
                "(or raise max_pending) before submitting more")
        if self._n_procs is None:
            self._n_procs = config.n_procs
            if self.mesh is None:
                from repro.distributed.mesh import local_mesh
                self.mesh = local_mesh((config.n_procs,), ("procs",))
        elif config.n_procs != self._n_procs:
            raise ValueError(
                f"all jobs multiplex over ONE mesh: scheduler runs "
                f"n_procs={self._n_procs}, job asked for "
                f"{config.n_procs}")
        name = name or f"job-{len(self.jobs)}"
        if name in self._by_name:
            raise ValueError(f"duplicate job name {name!r}")
        handle = _submit(config, dataset, mesh=self.mesh,
                         repeats=repeats, feed_budget=self.budget)
        job = ScheduledJob(name=name, tenant=tenant, priority=priority,
                           seq=len(self.jobs), handle=handle,
                           on_slice=on_slice,
                           submitted_at=time.perf_counter())
        self.jobs.append(job)
        self._by_name[name] = job
        self.tenants[tenant]                  # materialize the entry
        return handle

    def evict(self, name: str) -> ScheduledJob:
        """Remove a job from the scheduler entirely (its feed is closed,
        its name becomes reusable). This is the heal path's first half:
        a FAILED job cannot be resubmitted under its own name — the
        duplicate-name guard exists precisely so two live jobs never
        share a snapshot directory — so the supervisor evicts the dead
        admission before re-admitting a fresh handle and restoring it
        from the per-job snapshot. Returns the evicted record (its
        accounting is final; tenant totals already include it)."""
        job = self._by_name.get(name)
        if job is None:
            raise KeyError(f"no job named {name!r} to evict")
        if job.domain is not None and not job.domain.done:
            raise RuntimeError(
                f"job {name!r} is co-scheduled in a live WorkDomain — "
                "members share one engine run and cannot be evicted "
                "individually (fail/finish the domain first)")
        del self._by_name[name]
        self.jobs.remove(job)
        job.handle.close()
        return job

    def close(self):
        """Stop every job's feed prefetch thread (supervisor teardown /
        simulated rank loss). Idempotent; results already computed stay
        readable on their handles."""
        for j in self.jobs:
            j.handle.close()
        for d in self._domains:
            d.close()

    # -- introspection -------------------------------------------------------

    def __getitem__(self, name: str) -> ScheduledJob:
        return self._by_name[name]

    @property
    def n_unique_programs(self) -> int:
        """Distinct compiled engine programs serving the fleet — K jobs
        sharing a (backend, spec, use-case) pay exactly one compile."""
        return len(self._programs)

    def latency(self, name: str) -> float:
        """Seconds from run start to the job's completion."""
        j = self._by_name[name]
        assert j.finished_at is not None, f"{name} has not finished"
        assert self.run_started_at is not None
        return j.finished_at - self.run_started_at

    def results(self) -> dict[str, JobResult]:
        """Results of every completed job (failed jobs carry their
        exception on ``scheduler[name].error`` instead)."""
        return {j.name: j.handle.result()
                for j in self.jobs if j.state == DONE}

    def stats(self) -> dict:
        """JSON-able snapshot of fleet accounting."""
        return {
            "policy": self.policy.name,
            "n_unique_programs": self.n_unique_programs,
            "budget_live_bytes": (self.budget.live_bytes
                                  if self.budget else None),
            "tenants": {t: asdict(s) for t, s in self.tenants.items()},
            "jobs": [{
                "name": j.name, "tenant": j.tenant, "state": j.state,
                "priority": j.priority, "segments_run": j.segments_run,
                "work_done": j.work_done, "wall": j.wall,
            } for j in self.jobs],
        }

    # -- the scheduling loop -------------------------------------------------

    def _mark_live(self, job: ScheduledJob):
        """Activate: build (or share) the compiled engine, assert the
        memoization contract, start the feed's first prefetch."""
        h = job.handle
        h._ensure_engine()
        key = (h.backend.name, h.spec, id(h._map_fn))
        prev = self._programs.setdefault(key, h._seg_fns)
        assert prev is h._seg_fns, (
            "backend jit memoization regressed: two jobs with identical "
            f"(backend, JobSpec, use-case) {key[:2]} compiled two "
            "programs — the scheduler relies on K tenants sharing one")
        h.feed.prime()
        job.state = LIVE

    def _form_domain(self, group: list[ScheduledJob], *,
                     pack=None, stride=None):
        """Merge a program-compatible group into one WorkDomain and mark
        every member live. The domain's composite program registers in
        the jit memo like any solo program (its JobSpec differs by
        ``coslots``/``costride``, so it IS a distinct compile — paid
        once per domain shape, shared by same-shape domains)."""
        from repro.core.workdomain import WorkDomain
        domain = WorkDomain(
            [j.handle for j in group], names=[j.name for j in group],
            priorities=[j.priority for j in group], mesh=self.mesh,
            pack=pack if pack is not None else self.copack,
            stride=stride, feed_budget=self.budget)
        h = domain.handle
        h._ensure_engine()
        key = (h.backend.name, h.spec, id(h._map_fn))
        prev = self._programs.setdefault(key, h._seg_fns)
        assert prev is h._seg_fns, "domain programs must memoize too"
        h.feed.prime()
        for j in group:
            j.domain = domain
            j.state = LIVE
        self._domains.append(domain)
        return domain

    def _activate(self):
        n_live = sum(j.state == LIVE for j in self.jobs)
        batch: list[ScheduledJob] = []
        for job in self.jobs:
            if job.state != QUEUED:
                continue
            if self.max_active is not None and n_live >= self.max_active:
                break
            batch.append(job)
            n_live += 1
        if self.coschedule:
            # the co-scheduling pass: program-compatible eligible jobs
            # activated together merge into one WorkDomain; everyone
            # else (fused, sampled, '2s', singletons) slices solo
            from repro.core.workdomain import can_coschedule, \
                coschedule_key
            groups: dict = defaultdict(list)
            for job in batch:
                if can_coschedule(job.handle):
                    groups[coschedule_key(job.handle)].append(job)
            for group in groups.values():
                if len(group) >= 2:
                    self._form_domain(group)
        for job in batch:
            if job.state == QUEUED:
                self._mark_live(job)

    def _charge(self, job: ScheduledJob, st: SliceStats):
        """Fold one slice's EXECUTED service into the job's and its
        tenant's accounting — the single place service is charged, so
        solo and co-scheduled slices are charged on the same basis
        (``st.work_executed``, never slice counts)."""
        job.segments_run += st.segments
        job.work_done += st.work_executed
        job.wall += st.seconds
        ts = self.tenants[job.tenant]
        ts.segments += st.segments
        ts.work += st.work_executed
        ts.wall += st.seconds

    def _slice(self, job: ScheduledJob, raise_on_error: bool):
        if job.domain is not None:
            self._slice_domain(job, job.domain, raise_on_error)
            return
        h = job.handle
        c0 = h.cursor
        t0 = time.perf_counter()
        try:
            if not h.step(self.slice_segments):
                h.result()           # drained: combine/finalize + close
                job.state = DONE
        except Exception as e:       # noqa: BLE001 — isolate the tenant
            job.state = FAILED
            job.error = e
            h.close()                # never leak the feed's prefetch
            if raise_on_error:
                raise
        dt = time.perf_counter() - t0
        c1 = h.cursor
        ids = h.feed.task_ids_grid[:, c0:c1]
        reps = h.feed.repeats_grid[:, c0:c1]
        work = (reps * (ids >= 0)).sum(axis=1).astype(np.int64)
        seg_w = h.feed.segment
        segs = (c1 - c0 + seg_w - 1) // seg_w
        # solo slices execute exactly their assignment (stealing only
        # moves work between ranks inside the job), so assigned == executed
        st = SliceStats(seconds=dt, segments=segs, work_per_rank=work,
                        work_executed=int(work.sum()))
        self._charge(job, st)
        ts = self.tenants[job.tenant]
        if job.state == DONE:
            ts.jobs_done += 1
            job.finished_at = time.perf_counter()
        elif job.state == FAILED:
            ts.jobs_failed += 1
            job.finished_at = time.perf_counter()
        elif job.on_slice is not None:
            job.on_slice(h, st)

    def _slice_domain(self, picked: ScheduledJob, domain,
                      raise_on_error: bool):
        """Advance a WorkDomain one slice: the composite segment
        executes a MIX of the member tenants' tasks (whichever the
        fleet-wide claims routed to fast ranks); each tenant is charged
        the work its slots actually executed (``carry.job_work``
        deltas), and members whose columns fully drained finalize
        early. A failing domain fails every member — they share one
        engine run."""
        members = [self._by_name[n] for n in domain.names]
        jw0 = domain.job_work()
        c0 = domain.handle.cursor
        t0 = time.perf_counter()
        try:
            domain.step(self.slice_segments)
            finished = domain.collect_finished()
        except Exception as e:       # noqa: BLE001 — isolate the domain
            domain.close()
            now = time.perf_counter()
            for j in members:
                if j.state == LIVE:
                    j.state = FAILED
                    j.error = e
                    j.finished_at = now
                    self.tenants[j.tenant].jobs_failed += 1
            if raise_on_error:
                raise
            return
        dt = time.perf_counter() - t0
        dw = domain.job_work() - jw0
        seg_w = domain.handle.feed.segment
        segs = (domain.handle.cursor - c0 + seg_w - 1) // seg_w
        total = max(int(dw.sum()), 1)
        for slot, j in enumerate(members):
            if int(dw[slot]) == 0 and j is not picked:
                continue
            self._charge(j, SliceStats(
                seconds=dt * (int(dw[slot]) / total),
                # the picked member "funded" the slice; segment counts
                # are informational — service is the work charged above
                segments=segs if j is picked else 0,
                work_per_rank=np.zeros((self._n_procs or 0,), np.int64),
                work_executed=int(dw[slot])))
        now = time.perf_counter()
        for name in finished:
            j = self._by_name[name]
            j.state = DONE
            j.finished_at = now
            self.tenants[j.tenant].jobs_done += 1

    def run_until_complete(self, *, max_slices: int | None = None,
                           raise_on_error: bool = False
                           ) -> dict[str, JobResult]:
        """Drive the fleet until every job is done or failed (or
        ``max_slices`` slices ran — resumable: call again to continue).
        A failing job is isolated: its feed is closed, its error kept on
        ``scheduler[name].error``, and its siblings keep running —
        unless ``raise_on_error`` asks for fail-fast. Returns
        :meth:`results`."""
        if self.run_started_at is None:
            self.run_started_at = time.perf_counter()
        n = 0
        while max_slices is None or n < max_slices:
            self._activate()
            live = [j for j in self.jobs if j.state == LIVE]
            if not live:
                break
            self._slice(self.policy.pick(live, self.tenants),
                        raise_on_error)
            n += 1
        return self.results()

    # -- fleet checkpoint / restore ------------------------------------------

    def checkpoint(self, fleet):
        """Snapshot the fleet: every *live* job's carry + feed position
        (async, overlapping the next slices) plus the queue state.
        ``fleet`` is a :class:`~repro.ckpt.checkpoint.FleetCheckpoint`
        or a directory path; returns the FleetCheckpoint. Queued jobs
        need no snapshot (nothing ran); finished jobs' results are not
        persisted — after a restore they re-run from their own latest
        snapshot, see FleetCheckpoint's docstring."""
        from repro.ckpt.checkpoint import FleetCheckpoint
        if isinstance(fleet, str):
            fleet = FleetCheckpoint(fleet)
        for j in self.jobs:
            if j.state == LIVE and j.domain is None:
                j.handle.checkpoint(fleet.manager(j.name))
        # a WorkDomain snapshots ONCE: the composite carry + the shared
        # fleet cursor + merged grids — members have no solo engine to
        # snapshot, and restore re-forms the domain from the manifest
        # before seeking, so a mid-co-schedule restore is
        # record-identical to the uninterrupted run
        for d in self._domains:
            if not d.done:
                d.checkpoint(fleet.manager(self._domain_name(d)))
        fleet.wait()          # manifest must never name a torn snapshot
        fleet.save_state({
            "policy": self.policy.name,
            "jobs": [{"name": j.name, "tenant": j.tenant,
                      "priority": j.priority, "seq": j.seq,
                      "state": j.state, "segments_run": j.segments_run,
                      "work_done": j.work_done, "wall": j.wall}
                     for j in self.jobs],
            "tenants": {t: asdict(s) for t, s in self.tenants.items()},
            "domains": [{"name": self._domain_name(d),
                         "members": list(d.names),
                         "stride": d.stride, "pack": d.pack}
                        for d in self._domains],
        })
        return fleet

    def _domain_name(self, domain) -> str:
        """Stable snapshot name for a domain: keyed by its first
        member's admission seq — deterministic across the resubmission
        restore() requires."""
        return f"codomain-{self._by_name[domain.names[0]].seq}"

    def restore(self, fleet) -> JobScheduler:
        """Resume a fleet snapshot into *this* scheduler: re-``submit``
        the same jobs (same names/configs/datasets) first, then restore.
        Every job that was live at snapshot time seeks its feed to its
        per-job snapshot (no read replayed); accounting and tenant
        service resume where they left off, so fair share stays fair
        across the restart."""
        from repro.ckpt.checkpoint import FleetCheckpoint
        if isinstance(fleet, str):
            fleet = FleetCheckpoint(fleet)
        state = fleet.load_state()
        for rec in state["jobs"]:
            job = self._by_name.get(rec["name"])
            if job is None:
                raise ValueError(
                    f"fleet snapshot contains job {rec['name']!r} which "
                    "was not resubmitted — restore() resumes jobs, it "
                    "cannot reconstruct their configs/datasets")
            if rec["state"] in (LIVE, DONE) \
                    and fleet.has_snapshot(rec["name"]):
                job.handle.restore(fleet.manager(rec["name"]))
                self._mark_live(job)
            job.segments_run = rec["segments_run"]
            job.work_done = rec["work_done"]
            job.wall = rec["wall"]
        # re-form co-scheduling domains over the resubmitted members and
        # seek them to the shared snapshot: members have no solo snapshot
        # (they share one engine run), so this is the only path that
        # resumes them. collect_finished() re-adopts results for members
        # that had already drained pre-snapshot; tenant counters are NOT
        # bumped here — they are restored wholesale below.
        for rec in state.get("domains", []):
            group = [self._by_name[n] for n in rec["members"]]
            domain = self._form_domain(group, pack=rec["pack"],
                                       stride=rec["stride"])
            if fleet.has_snapshot(rec["name"]):
                domain.restore(fleet.manager(rec["name"]))
            for name in domain.collect_finished():
                j = self._by_name[name]
                j.state = DONE
                j.finished_at = time.perf_counter()
        for t, s in state.get("tenants", {}).items():
            self.tenants[t] = TenantStats(**s)
        return self
