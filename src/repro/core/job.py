"""The unified job lifecycle: ``submit(config, dataset) -> JobHandle``.

This is the public entry point of the framework. A job is the triple
(use-case, backend, data source); the handle exposes the paper's
decoupled lifecycle instead of one opaque blocking call:

    cfg = JobConfig(usecase=WordCount(vocab=65_536), backend="1s",
                    task_size=4_096, push_cap=1_024, n_procs=8)
    result = submit(cfg, tokens).result()          # oneshot

    cfg = dataclasses.replace(cfg, segment=2)      # streaming / ckpt mode
    handle = submit(cfg, MmapTokenSource("corpus.bin"))
    while handle.step():                           # one segment at a time
        handle.checkpoint(manager)                 # async window snapshot
    result = handle.result()

``dataset`` is any :class:`repro.data.source.DataSource` (raw arrays are
auto-wrapped in an ``ArraySource``). Nothing is pre-sharded on the host:
a :class:`repro.data.feed.SegmentFeed` reads each segment's tasks by
``plan.file_offset`` in a background thread and dispatches the device
transfer while the engine computes the previous segment — the paper's
non-blocking I/O. Oneshot mode is internally "segmented with one big
segment", so both engines share the one streaming data path. In
segmented mode peak host residency is O(segment); oneshot's single
segment spans the input, so set ``JobConfig(segment=N)`` for datasets
that must never be fully resident.

A checkpoint snapshot carries the feed cursor and task assignment, so
``restore`` *seeks* the stream (no read is replayed), and a straggler
re-plan (``repro.ft.straggler.replan_handle``) re-routes exactly the
not-yet-read tasks through the same feed.

``JobResult`` is structured: the records dict, the use-case's finalized
output, wall time, and per-rank task/work counts (the imbalance stats the
paper's Fig 4 is about) — not raw key/value arrays.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.core import coded, planner
from repro.core.kv import KEY_SENTINEL
from repro.core.partition import (Partitioner, resolve_partitioner,
                                  sample_key_histogram)
from repro.core.registry import Backend, JobSpec, get_backend
from repro.core.usecase import UseCase, as_map_fn, finalize
from repro.core.windows import AXIS
from repro.data.feed import SegmentFeed
from repro.data.source import as_source


@dataclass(frozen=True)
class JobConfig:
    """Declarative job description (replaces ``MapReduceJob.init(...)``)."""
    usecase: UseCase
    backend: str = "1s"
    task_size: int = 4096
    push_cap: int = 1024
    n_procs: int = 8
    segment: int = 0          # 0 -> oneshot; >0 -> tasks per step()
    window: int = 0           # 0 -> usecase.window
    combine_capacity: int = 0
    stealing: bool = False    # device-side work stealing inside the engine
                              #   scan (core/steal.py) — fine-grained
                              #   rebalancing under the host re-planner
    partitioner: str | Partitioner = "hash"
                              # reduce-side key→owner strategy
                              #   (core/partition.py): "hash" (static
                              #   modulo rule), "sampled" (balanced owner
                              #   map from a planner pre-pass),
                              #   "sampled+split" (hot keys spread over
                              #   several owners), or any Partitioner
    fused_map: bool = False   # per-step hot path as one pallas kernel
                              #   (kernels/fused_map) — bit-identical to
                              #   the default unfused path; see the
                              #   README "Fused hot path" section for
                              #   when it wins
    code_rate: int = 1        # coded shuffle (core/coded.py): every map
                              #   task runs on r consecutive ranks and
                              #   the intra-group bucket push becomes
                              #   one XOR-coded multicast block — r×
                              #   map compute for ~1/r shuffle bytes.
                              #   Needs n_procs divisible by r; 1 is
                              #   today's path, bit-identical. See the
                              #   README "Coded shuffle" section.


@dataclass(frozen=True)
class JobResult:
    """Structured outcome of a job."""
    records: dict[int, int]   # engine output: {key: reduced value}
    output: Any               # usecase.finalize(records)
    keys: np.ndarray          # rank-0 sorted keys (sentinel padded)
    values: np.ndarray
    wall_time: float          # seconds spent executing (incl. compile)
    backend: str
    n_tasks: int
    tasks_per_rank: np.ndarray   # real (non-padding) tasks *assigned* per rank
    work_per_rank: np.ndarray    # compute-repeats *executed* per rank (with
                                 #   stealing this is the engine's progress
                                 #   row; otherwise it equals the assignment)
    steals_per_rank: np.ndarray  # tasks each rank executed for a peer
                                 #   (all-zero unless stealing was on)
    partitioner: str = "hash"    # reduce-side key→owner strategy that ran
    n_split_keys: int = 0        # hot keys spread over >1 owner (0 unless
                                 #   a splitting partitioner was active)
    combine_overflow: int = 0    # records lost to an undersized
                                 #   combine_capacity anywhere in the
                                 #   Combine phase; result() refuses to
                                 #   hand out records when it is nonzero
                                 #   (CombineOverflowError)

    @property
    def n_steals(self) -> int:
        """Total tasks executed by a rank other than their assignee."""
        return int(self.steals_per_rank.sum())

    @property
    def imbalance(self) -> float:
        """max/mean of per-rank work — 1.0 means perfectly balanced."""
        mean = self.work_per_rank.mean()
        return float(self.work_per_rank.max() / mean) if mean else 1.0


class CombineOverflowError(RuntimeError):
    """The Combine phase lost records to an undersized
    ``combine_capacity`` — the counts in ``self.result.records`` are
    WRONG (previously this truncation was silent). Size
    ``JobConfig(combine_capacity=...)`` to at least the number of
    distinct keys the job produces (0 defaults to the full window,
    which can never overflow)."""

    def __init__(self, result: JobResult):
        self.result = result
        super().__init__(
            f"Combine overflow: {result.combine_overflow} record(s) were "
            f"dropped because combine_capacity is smaller than the number "
            f"of distinct keys — the returned counts would be wrong. "
            f"Raise JobConfig(combine_capacity=...) (>= distinct keys; "
            f"0 uses the full window, which never overflows). The partial "
            f"result is attached as err.result.")


def submit(config: JobConfig, dataset, *, mesh=None, repeats=None,
           prefetch: bool = True, feed_budget=None) -> JobHandle:
    """Plan ``dataset`` (a DataSource, or a 1-D int32 array auto-wrapped
    into one) onto the mesh and return a handle. Nothing executes — and
    nothing beyond one segment is read — until ``step()`` or ``result()``.

    ``repeats`` is the optional (n_procs, tasks_per_proc) compute-repeat
    grid — the paper's footnote-5 imbalance model. ``prefetch=False``
    disables the background read (measurement baselines). ``feed_budget``
    is an optional shared :class:`repro.data.feed.FeedBudget` bounding
    the combined prefetch bytes of many live feeds (the multi-tenant
    scheduler passes its arbiter here)."""
    backend = get_backend(config.backend)        # fail fast on bad names
    if config.stealing and not getattr(backend, "supports_stealing", False):
        raise ValueError(
            f"backend {config.backend!r} does not implement device-side "
            "work stealing (no supports_stealing attribute) — drop "
            "stealing=True or use backend '1s'")
    if config.fused_map and not getattr(backend, "supports_fused_map",
                                        False):
        raise ValueError(
            f"backend {config.backend!r} does not implement the fused "
            "map hot path (no supports_fused_map attribute) — drop "
            "fused_map=True or use backend '1s'")
    if config.code_rate > 1 and not getattr(backend, "supports_coded",
                                            False):
        raise ValueError(
            f"backend {config.backend!r} does not implement the coded "
            "exchange (no supports_coded attribute) — drop code_rate or "
            "use backend '1s'")
    partitioner = resolve_partitioner(config.partitioner)  # fail fast too
    window = config.window or config.usecase.window
    spec = JobSpec(vocab=window, task_size=config.task_size,
                   push_cap=config.push_cap, n_procs=config.n_procs,
                   combine_capacity=config.combine_capacity,
                   segment=config.segment, stealing=config.stealing,
                   fused_map=config.fused_map, code_rate=config.code_rate,
                   partitioner=partitioner.name)
    from repro.distributed.mesh import local_mesh
    if mesh is None:
        mesh = local_mesh((config.n_procs,), ("procs",))
    source = as_source(dataset)
    plan = planner.plan_input(source.len_elements(), config.task_size,
                              config.n_procs)
    task_ids = planner.shard_task_ids(plan)
    T = plan.tasks_per_proc
    if repeats is None:
        repeats = np.ones((config.n_procs, T), np.int32)
    repeats = np.asarray(repeats, np.int32).reshape(config.n_procs, T)
    seg_tasks = config.segment if config.segment > 0 else max(T, 1)
    if config.code_rate > 1:
        # every member of an r-rank code group carries the group's tasks
        # as r-wide column blocks (core/coded.py); a segment of N blocks
        # is N*r grid columns, so the engine still advances N steps
        task_ids, repeats = coded.replicate_grids(task_ids, repeats,
                                                  config.code_rate)
        seg_tasks *= config.code_rate
    from jax.sharding import NamedSharding, PartitionSpec
    feed = SegmentFeed(
        source, plan, task_ids, repeats, segment=seg_tasks,
        sharding=NamedSharding(mesh, PartitionSpec(AXIS)),
        prefetch=prefetch, budget=feed_budget)
    return JobHandle(config, backend, spec, mesh, plan, feed, partitioner)


class JobHandle:
    """Streaming lifecycle of one submitted job.

    * oneshot (``segment == 0``): ``result()`` streams the whole input as
      one segment through the backend's segmented path and caches the
      outcome;
    * segmented (``segment > 0``): ``step()`` pulls the next prefetched
      segment from the feed and advances the backend's
      ``make_segment_fns`` triple; ``checkpoint(manager)`` snapshots the
      window carry (and feed position) asynchronously; ``restore(manager)``
      resumes by seeking the feed; ``replan(grid)`` re-routes unread
      tasks; ``result()`` finishes the remaining segments and the
      Combine phase.
    """

    def __init__(self, config, backend: Backend, spec, mesh, plan,
                 feed: SegmentFeed, partitioner: Partitioner | None = None):
        self.config = config
        self.backend = backend
        self.spec = spec
        self.mesh = mesh
        self.plan = plan
        self.feed = feed
        self.partitioner = (resolve_partitioner(config.partitioner)
                            if partitioner is None else partitioner)
        self._map_fn = as_map_fn(config.usecase)
        self._seg_fns = None
        self._carry = None
        self._owner_ready = False   # sampled owner map installed (or a
                                    #   snapshot's map adopted by restore)
        self._wall = 0.0
        self._result: JobResult | None = None

    # -- resource lifecycle -------------------------------------------------

    def close(self):
        """Stop the feed's prefetch thread. Idempotent; safe on a job in
        any state (an abandoned or failed handle must not leak the
        thread)."""
        self.feed.close()

    def __enter__(self) -> JobHandle:
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- introspection ------------------------------------------------------

    @property
    def cursor(self) -> int:
        """Per-rank task slots completed so far (segmented mode)."""
        return self.feed.cursor

    @property
    def done(self) -> bool:
        return self._result is not None

    def ready(self) -> bool:
        """True when the next ``step()`` would not block on input I/O —
        the feed's background read of the upcoming segment has landed
        (or the stream is exhausted / the job is done). The cooperative
        half of the scheduler contract: ``step()`` yields at segment
        boundaries, ``ready()`` lets the scheduler poll many jobs' feeds
        without blocking on any of them."""
        return self._result is not None or self.feed.ready()

    @property
    def carry(self):
        """The current EngineCarry snapshot reference (segmented mode)."""
        return self._carry

    @property
    def _task_ids(self) -> np.ndarray:
        """Full (P, T) task assignment (consumed prefix + upcoming)."""
        return self.feed.task_ids_grid

    @property
    def _repeats(self) -> np.ndarray:
        return self.feed.repeats_grid

    def windows(self) -> np.ndarray:
        """Per-rank dense Key-Value windows, host-side (P, window) — the
        state ``repro.ft.elastic.fold_windows`` redistributes. The 1s
        backend's in-flight ``pending_*`` chunk is folded in so the
        snapshot covers every record of every completed task (exactness
        of a mid-job redistribution depends on it)."""
        assert self._carry is not None, "no carry yet — call step() first"
        tables = np.array(self._carry.table)                 # copy
        P = tables.shape[0]
        pk = np.asarray(self._carry.pending_k).reshape(P, -1)
        pv = np.asarray(self._carry.pending_v).reshape(P, -1)
        for r in range(P):
            valid = pk[r] != int(KEY_SENTINEL)
            np.add.at(tables[r], pk[r][valid], pv[r][valid])
        return tables

    def remaining_task_ids(self) -> np.ndarray:
        """Global ids of tasks not yet executed (segmented mode) — what a
        straggler-aware re-plan redistributes."""
        return self.feed.remaining_task_ids()

    # -- segmented execution ------------------------------------------------

    def _ensure_engine(self):
        if self._seg_fns is None:
            self._seg_fns = self.backend.make_segment_fns(
                self.spec, self._map_fn, self.mesh)
            self._carry = self._seg_fns[0]()

    def _ensure_owner_map(self):
        """Overwrite the carry's hash-seeded owner map with the skew-aware
        one (planner pre-pass through the feed, so the sample bytes land
        in ``feed.stats``). The map is carry *data*: the jitted engine is
        shared across partitioners. Deferred until the first advance /
        checkpoint so a ``restore`` — which adopts the *snapshot's* map
        wholesale — never pays for a sample it would throw away; the
        pre-pass time counts into ``wall_time`` (it is real job cost)."""
        if self._owner_ready:
            return
        self._owner_ready = True
        if not self.partitioner.needs_sample:
            return                      # hash map already seeded by init
        t0 = time.perf_counter()
        self._install_partitioner()
        self._wall += time.perf_counter() - t0

    def _install_partitioner(self):
        # sized by the ENGINE's window (spec.vocab — a JobConfig(window=)
        # override may widen it past usecase.window): the owner map must
        # match the compiled carry's shape or restore would reject it
        hist = sample_key_histogram(
            self.feed.sample_tasks, self.plan, self.config.usecase,
            getattr(self.partitioner, "sample_tasks", 16),
            window=self.spec.vocab)
        omap, osplit = self.partitioner.build(hist, self.spec.n_procs)
        P = self.spec.n_procs
        self._carry = self._carry._replace(
            owner_map=np.ascontiguousarray(
                np.broadcast_to(np.asarray(omap, np.int32), (P, len(omap)))),
            owner_split=np.ascontiguousarray(
                np.broadcast_to(np.asarray(osplit, np.int32),
                                (P, len(osplit)))))

    def _ensure_segmented(self):
        if self.config.segment <= 0:
            raise RuntimeError(
                "step()/checkpoint() need a segmented job — set "
                "JobConfig(segment=N) with N tasks per step")
        self._ensure_engine()

    def _advance(self, n_segments: int) -> bool:
        self._ensure_owner_map()
        _, seg_fn, _ = self._seg_fns
        t0 = time.perf_counter()
        for _ in range(n_segments):
            seg = self.feed.next_segment()
            if seg is None:
                break
            tokens, task_ids, repeats = seg
            self._carry = seg_fn(self._carry, tokens, task_ids, repeats)
        self._wall += time.perf_counter() - t0
        return not self.feed.exhausted

    def step(self, n_segments: int = 1) -> bool:
        """Advance up to ``n_segments`` segments. Returns True while map
        work remains (so ``while handle.step(): ...`` drains the job)."""
        if self._result is not None:
            return False
        self._ensure_segmented()
        return self._advance(n_segments)

    def replan(self, task_id_grid) -> JobHandle:
        """Install a re-planned (P, W) assignment of the *unread* tasks
        (from ``repro.ft.straggler``); each task keeps its compute-repeat
        factor, so results stay exact by construction."""
        self._ensure_segmented()
        if self.spec.code_rate > 1:
            raise ValueError(
                "replan() does not support coded jobs (code_rate > 1): "
                "the r-replicated grid intentionally repeats every task "
                "r times, which the feed's exactly-once coverage "
                "contract rejects; resubmit the job instead")
        grid = np.asarray(task_id_grid, np.int32)
        by_task = {int(t): int(r) for t, r in
                   zip(self.feed.task_ids_grid.ravel(),
                       self.feed.repeats_grid.ravel()) if t >= 0}
        reps = np.ones_like(grid)
        for idx in zip(*np.nonzero(grid >= 0)):
            # unknown ids fall through to the feed's coverage check,
            # which names the offending tasks
            reps[idx] = by_task.get(int(grid[idx]), 1)
        self.feed.replan(grid, reps)
        return self

    def checkpoint(self, manager, **extra):
        """Asynchronously snapshot the window carry into ``manager`` (a
        ``repro.ckpt.CheckpointManager``). The device_get happens in the
        manager's worker thread, overlapping the next segment's compute —
        the paper's MPI-storage-windows trick. The manifest records the
        feed position and task assignment, so restore can seek."""
        self._ensure_segmented()
        self._ensure_owner_map()    # a pre-step snapshot must carry the
        assert self._carry is not None      # sampled map, not the seed
        # reserved keys win over caller extras: restore() trusts them
        return manager.save_async(
            self.cursor, self._carry,
            extra={**extra,
                   "cursor": self.cursor,
                   "backend": self.backend.name,
                   "stealing": self.config.stealing,
                   # cross-job co-scheduling shape: a composite domain
                   # carry cannot restore into a solo handle (or into a
                   # domain of a different width) — the shared fleet
                   # cursor and per-slot work row would be meaningless
                   "coslots": self.spec.coslots,
                   # recorded for provenance only: the fused and unfused
                   # hot paths are bit-identical and share carry shapes,
                   # so snapshots interchange freely across the flag
                   "fused_map": self.spec.fused_map,
                   # the saved grids are r-replicated column blocks for
                   # coded jobs — meaningless under a different r
                   "code_rate": self.spec.code_rate,
                   "partitioner": self.spec.partitioner,
                   "task_ids": self.feed.task_ids_grid.tolist(),
                   "repeats": self.feed.repeats_grid.tolist()})

    def restore(self, manager, step: int | None = None) -> JobHandle:
        """Resume from a snapshot taken by :meth:`checkpoint` (possibly in
        a previous process): install the carry, then *seek* the feed to
        the saved cursor/assignment — no segment read is ever replayed.

        Raises ``ValueError`` if the snapshot was taken by a different
        backend (its carry layout would be silently incompatible)."""
        import jax
        self._ensure_segmented()
        found, extra = manager.peek(step)
        saved = extra.get("backend")
        if saved is not None and saved != self.backend.name:
            raise ValueError(
                f"checkpoint step {found} "
                f"was taken by backend {saved!r} — it cannot restore into "
                f"a {self.backend.name!r} handle; resubmit with "
                f"JobConfig(backend={saved!r})")
        saved_steal = extra.get("stealing")
        if (saved_steal is not None
                and bool(saved_steal) != self.config.stealing):
            raise ValueError(
                f"checkpoint step {found} was taken with "
                f"stealing={bool(saved_steal)} — restoring into a "
                f"stealing={self.config.stealing} handle would corrupt "
                "the carry's progress/steal accounting; resubmit with "
                f"JobConfig(stealing={bool(saved_steal)})")
        saved_slots = extra.get("coslots")
        if (saved_slots is not None
                and int(saved_slots) != self.spec.coslots):
            raise ValueError(
                f"checkpoint step {found} was taken with "
                f"coslots={int(saved_slots)} — restoring into a "
                f"coslots={self.spec.coslots} handle would misroute the "
                "composite task/key space; re-form the WorkDomain with "
                "the same member jobs first")
        saved_rate = extra.get("code_rate")
        if (saved_rate is not None
                and int(saved_rate) != self.spec.code_rate):
            raise ValueError(
                f"checkpoint step {found} was taken with "
                f"code_rate={int(saved_rate)} — restoring into a "
                f"code_rate={self.spec.code_rate} handle would break the "
                "r-replicated assignment the snapshot's grids encode; "
                f"resubmit with JobConfig(code_rate={int(saved_rate)})")
        saved_part = extra.get("partitioner")
        if saved_part is not None and saved_part != self.spec.partitioner:
            raise ValueError(
                f"checkpoint step {found} was taken with "
                f"partitioner={saved_part!r} — restoring into a "
                f"{self.spec.partitioner!r} handle would mix two owner "
                "maps in one job (the windows already reflect the saved "
                "assignment); resubmit with "
                f"JobConfig(partitioner={saved_part!r})")
        # load exactly the snapshot the guard inspected (a concurrent
        # async save could otherwise re-resolve "latest" to a newer step)
        _, carry, extra = manager.restore(
            jax.eval_shape(lambda: self._carry), step=found)
        self._carry = carry
        self._owner_ready = True    # the snapshot's owner map IS the map
        self.feed.seek(int(extra["cursor"]),
                       task_ids=extra.get("task_ids"),
                       repeats=extra.get("repeats"))
        return self

    def load(self, carry, cursor: int) -> JobHandle:
        """Install an in-memory carry snapshot (elastic/straggler paths).
        The snapshot's owner map comes with it — no re-sample."""
        self._ensure_segmented()
        self._carry = carry
        self._owner_ready = True
        self.feed.seek(int(cursor))
        return self

    def elastic_load(self, table, owner_map, owner_split, task_ids,
                     repeats) -> JobHandle:
        """Resume a job that ran at a *different* process count: install
        windows/owner maps already folded onto this handle's mesh (from
        ``repro.fleet.remesh`` / ``repro.ft.elastic``) plus the
        re-bucketized assignment of the not-yet-executed tasks, and seek
        the feed to column 0 of that new grid.

        Unlike :meth:`load`, the saved carry cannot be adopted wholesale
        — every rank-shaped leaf (``pending_*``, ``work``, ``stolen``)
        has the wrong P. A fresh carry at the new P is semantically
        safe: pending chunks were folded into ``table`` by the caller,
        the steal progress row only seeds future claims, and the cursor
        is monotone bookkeeping. Exactness rests on the Combine dup-sum:
        the folded windows hold every executed record, wherever they
        now live."""
        self._ensure_segmented()
        P, vocab = self.spec.n_procs, self.spec.vocab
        table = np.ascontiguousarray(np.asarray(table, np.int32))
        if table.shape != (P, vocab):
            raise ValueError(
                f"elastic_load: folded windows have shape {table.shape}, "
                f"this handle runs (n_procs, window) = {(P, vocab)} — "
                "fold onto the NEW mesh before loading")

        def per_rank(m):
            m = np.asarray(m, np.int32)
            if m.ndim == 1:             # replicated row -> per-rank copies
                m = np.broadcast_to(m, (P, len(m)))
            assert m.shape == (P, vocab), m.shape
            return np.ascontiguousarray(m)

        self._carry = self._carry._replace(
            table=table, owner_map=per_rank(owner_map),
            owner_split=per_rank(owner_split))
        self._owner_ready = True        # folded map IS the map: no sample
        self.feed.seek(0, task_ids=task_ids, repeats=repeats)
        return self

    # -- completion ---------------------------------------------------------

    def adopt_result(self, result: JobResult) -> JobHandle:
        """Install a result computed on this job's behalf by a
        :class:`~repro.core.workdomain.WorkDomain` (cross-job
        co-scheduling): the member handle never built an engine of its
        own — its tasks ran inside the domain's composite program — but
        the adopted records are exactly the solo outcome (per-job
        dup-sum exactness). The feed stops prefetching; ``result()``
        serves the adopted outcome, overflow check included."""
        assert self._result is None, "job already has a result"
        self._result = result
        self.feed.close()
        return self

    def result(self) -> JobResult:
        """Run to completion (whatever mode) and return the JobResult.
        Oneshot jobs take the same streamed path with one big segment.

        Raises :class:`CombineOverflowError` when the Combine phase lost
        records to an undersized ``combine_capacity`` — the counts would
        be silently wrong otherwise (the partial result rides on the
        error). The feed's prefetch thread is stopped on every exit
        path, success or not — a raising ``segment_fn``/``finish_fn``
        must not leak it."""
        if self._result is None:
            try:
                self._result = self._finish()
            except BaseException:
                self.feed.close()          # error path: don't leak prefetch
                raise
        if self._result.combine_overflow:
            raise CombineOverflowError(self._result)
        return self._result

    def _finish(self) -> JobResult:
        self._ensure_engine()
        while self._advance(1):
            pass
        self.feed.close()                  # stream drained: stop prefetch
        _, _, fin_fn = self._seg_fns
        t0 = time.perf_counter()
        keys, vals, overflow = fin_fn(self._carry)
        keys = np.asarray(keys)[0]
        vals = np.asarray(vals)[0]
        overflow = int(np.asarray(overflow)[0])   # psum-replicated
        self._wall += time.perf_counter() - t0
        valid = keys != int(KEY_SENTINEL)
        records = dict(zip(keys[valid].tolist(), vals[valid].tolist()))
        ids, reps = self.feed.task_ids_grid, self.feed.repeats_grid
        task_valid = ids >= 0
        if self.config.stealing:
            # executed distribution from the engine's psum-maintained
            # progress rows (replicated: every shard holds the same row)
            work = np.asarray(self._carry.work)[0]
            steals = np.asarray(self._carry.stolen)[0]
        else:
            work = (reps * task_valid).sum(axis=1)
            steals = np.zeros((self.config.n_procs,), np.int32)
        return JobResult(
            records=records,
            output=finalize(self.config.usecase, records),
            keys=keys, values=vals,
            wall_time=self._wall,
            backend=self.backend.name,
            n_tasks=self.plan.n_tasks,
            tasks_per_rank=task_valid.sum(axis=1),
            work_per_rank=work,
            steals_per_rank=steals,
            partitioner=self.spec.partitioner,
            n_split_keys=int(
                (np.asarray(self._carry.owner_split)[0] > 1).sum()),
            combine_overflow=overflow,
        )
