"""The unified job lifecycle: ``submit(config, dataset) -> JobHandle``.

This is the public entry point of the framework. A job is the triple
(use-case, backend, dataset); the handle exposes the paper's decoupled
lifecycle instead of one opaque blocking call:

    cfg = JobConfig(usecase=WordCount(vocab=65_536), backend="1s",
                    task_size=4_096, push_cap=1_024, n_procs=8)
    result = submit(cfg, tokens).result()          # oneshot

    cfg = dataclasses.replace(cfg, segment=2)      # streaming / ckpt mode
    handle = submit(cfg, tokens)
    while handle.step():                           # one segment at a time
        handle.checkpoint(manager)                 # async window snapshot
    result = handle.result()

``JobResult`` is structured: the records dict, the use-case's finalized
output, wall time, and per-rank task/work counts (the imbalance stats the
paper's Fig 4 is about) — not raw key/value arrays.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

from repro.core import planner
from repro.core.kv import KEY_SENTINEL
from repro.core.registry import Backend, JobSpec, get_backend
from repro.core.usecase import UseCase, as_map_fn, finalize


@dataclass(frozen=True)
class JobConfig:
    """Declarative job description (replaces ``MapReduceJob.init(...)``)."""
    usecase: UseCase
    backend: str = "1s"
    task_size: int = 4096
    push_cap: int = 1024
    n_procs: int = 8
    segment: int = 0          # 0 -> oneshot; >0 -> tasks per step()
    window: int = 0           # 0 -> usecase.window
    combine_capacity: int = 0


@dataclass(frozen=True)
class JobResult:
    """Structured outcome of a job."""
    records: Dict[int, int]   # engine output: {key: reduced value}
    output: Any               # usecase.finalize(records)
    keys: np.ndarray          # rank-0 sorted keys (sentinel padded)
    values: np.ndarray
    wall_time: float          # seconds spent executing (incl. compile)
    backend: str
    n_tasks: int
    tasks_per_rank: np.ndarray   # real (non-padding) tasks per rank
    work_per_rank: np.ndarray    # sum of compute-repeats per rank

    @property
    def imbalance(self) -> float:
        """max/mean of per-rank work — 1.0 means perfectly balanced."""
        mean = self.work_per_rank.mean()
        return float(self.work_per_rank.max() / mean) if mean else 1.0


def submit(config: JobConfig, dataset, *, mesh=None,
           repeats=None) -> "JobHandle":
    """Plan ``dataset`` (a 1-D int32 token array) onto the mesh and return
    a handle. Nothing executes until ``step()`` or ``result()``.

    ``repeats`` is the optional (n_procs, tasks_per_proc) compute-repeat
    grid — the paper's footnote-5 imbalance model."""
    backend = get_backend(config.backend)        # fail fast on bad names
    window = config.window or config.usecase.window
    spec = JobSpec(vocab=window, task_size=config.task_size,
                   push_cap=config.push_cap, n_procs=config.n_procs,
                   combine_capacity=config.combine_capacity,
                   segment=config.segment)
    from repro.distributed.mesh import local_mesh
    if mesh is None:
        mesh = local_mesh((config.n_procs,), ("procs",))
    plan = planner.plan_input(len(dataset), config.task_size,
                              config.n_procs)
    tokens = planner.shard_tasks(np.asarray(dataset, np.int32), plan)
    task_ids = planner.shard_task_ids(plan)
    T = plan.tasks_per_proc
    if repeats is None:
        repeats = np.ones((config.n_procs, T), np.int32)
    repeats = np.asarray(repeats, np.int32).reshape(config.n_procs, T)
    return JobHandle(config, backend, spec, mesh, plan, tokens, task_ids,
                     repeats)


class JobHandle:
    """Streaming lifecycle of one submitted job.

    * oneshot (``segment == 0``): ``result()`` runs the backend's blocking
      ``run_job`` once and caches the outcome;
    * segmented (``segment > 0``): ``step()`` advances one segment through
      the backend's ``make_segment_fns`` triple; ``checkpoint(manager)``
      snapshots the window carry asynchronously; ``restore(manager)``
      resumes from the latest (or a given) snapshot; ``result()`` finishes
      the remaining segments and the Combine phase.
    """

    def __init__(self, config, backend: Backend, spec, mesh, plan,
                 tokens, task_ids, repeats):
        self.config = config
        self.backend = backend
        self.spec = spec
        self.mesh = mesh
        self.plan = plan
        self._tokens = tokens          # (P, T, S)
        self._task_ids = task_ids      # (P, T)
        self._repeats = repeats        # (P, T)
        self._map_fn = as_map_fn(config.usecase)
        self._seg_fns = None
        self._carry = None
        self._cursor = 0               # per-rank task slots completed
        self._wall = 0.0
        self._result: Optional[JobResult] = None

    # -- introspection ------------------------------------------------------

    @property
    def cursor(self) -> int:
        """Per-rank task slots completed so far (segmented mode)."""
        return self._cursor

    @property
    def done(self) -> bool:
        return self._result is not None

    @property
    def carry(self):
        """The current EngineCarry snapshot reference (segmented mode)."""
        return self._carry

    def windows(self) -> np.ndarray:
        """Per-rank dense Key-Value windows, host-side (P, window) — the
        state ``repro.ft.elastic.fold_windows`` redistributes. The 1s
        backend's in-flight ``pending_*`` chunk is folded in so the
        snapshot covers every record of every completed task (exactness
        of a mid-job redistribution depends on it)."""
        assert self._carry is not None, "no carry yet — call step() first"
        tables = np.array(self._carry.table)                 # copy
        P = tables.shape[0]
        pk = np.asarray(self._carry.pending_k).reshape(P, -1)
        pv = np.asarray(self._carry.pending_v).reshape(P, -1)
        for r in range(P):
            valid = pk[r] != int(KEY_SENTINEL)
            np.add.at(tables[r], pk[r][valid], pv[r][valid])
        return tables

    def remaining_task_ids(self) -> np.ndarray:
        """Global ids of tasks not yet executed (segmented mode) — what a
        straggler-aware re-plan redistributes."""
        ids = self._task_ids[:, self._cursor:]
        return np.sort(ids[ids >= 0])

    # -- segmented execution ------------------------------------------------

    def _ensure_segmented(self):
        if self.config.segment <= 0:
            raise RuntimeError(
                "step()/checkpoint() need a segmented job — set "
                "JobConfig(segment=N) with N tasks per step")
        if self._seg_fns is None:
            self._seg_fns = self.backend.make_segment_fns(
                self.spec, self._map_fn, self.mesh)
            self._carry = self._seg_fns[0]()

    def step(self, n_segments: int = 1) -> bool:
        """Advance up to ``n_segments`` segments. Returns True while map
        work remains (so ``while handle.step(): ...`` drains the job)."""
        if self._result is not None:
            return False
        self._ensure_segmented()
        _, seg_fn, _ = self._seg_fns
        T, seg = self.plan.tasks_per_proc, self.config.segment
        t0 = time.perf_counter()
        for _ in range(n_segments):
            if self._cursor >= T:
                break
            s, e = self._cursor, min(self._cursor + seg, T)
            self._carry = seg_fn(self._carry, self._tokens[:, s:e],
                                 self._task_ids[:, s:e],
                                 self._repeats[:, s:e])
            self._cursor = e
        self._wall += time.perf_counter() - t0
        return self._cursor < T

    def checkpoint(self, manager, **extra):
        """Asynchronously snapshot the window carry into ``manager`` (a
        ``repro.ckpt.CheckpointManager``). The device_get happens in the
        manager's worker thread, overlapping the next segment's compute —
        the paper's MPI-storage-windows trick."""
        self._ensure_segmented()
        assert self._carry is not None
        # reserved keys win over caller extras: restore() trusts "cursor"
        return manager.save_async(self._cursor, self._carry,
                                  extra={**extra,
                                         "cursor": self._cursor,
                                         "backend": self.backend.name})

    def restore(self, manager, step: Optional[int] = None) -> "JobHandle":
        """Resume from a snapshot taken by :meth:`checkpoint` (possibly in
        a previous process)."""
        import jax
        self._ensure_segmented()
        _, carry, extra = manager.restore(
            jax.eval_shape(lambda: self._carry), step=step)
        self._carry = carry
        self._cursor = int(extra["cursor"])
        return self

    def load(self, carry, cursor: int) -> "JobHandle":
        """Install an in-memory carry snapshot (elastic/straggler paths)."""
        self._ensure_segmented()
        self._carry = carry
        self._cursor = int(cursor)
        return self

    # -- completion ---------------------------------------------------------

    def result(self) -> JobResult:
        """Run to completion (whatever mode) and return the JobResult."""
        if self._result is not None:
            return self._result
        if self.config.segment > 0 or self._carry is not None:
            while self.step():
                pass
            _, _, fin_fn = self._seg_fns
            t0 = time.perf_counter()
            keys, vals = fin_fn(self._carry)
            keys = np.asarray(keys)[0]
            vals = np.asarray(vals)[0]
            self._wall += time.perf_counter() - t0
        else:
            t0 = time.perf_counter()
            keys, vals = self.backend.run_job(
                self.spec, self._map_fn, self.mesh, self._tokens,
                self._task_ids, self._repeats)
            self._wall += time.perf_counter() - t0
            keys, vals = np.asarray(keys), np.asarray(vals)
        valid = keys != int(KEY_SENTINEL)
        records = dict(zip(keys[valid].tolist(), vals[valid].tolist()))
        task_valid = self._task_ids >= 0
        self._result = JobResult(
            records=records,
            output=finalize(self.config.usecase, records),
            keys=keys, values=vals,
            wall_time=self._wall,
            backend=self.backend.name,
            n_tasks=self.plan.n_tasks,
            tasks_per_rank=task_valid.sum(axis=1),
            work_per_rank=(self._repeats * task_valid).sum(axis=1),
        )
        return self._result
