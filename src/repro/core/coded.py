"""Coded shuffle — r-replicated assignment grids for the XOR multicast.

Coded MapReduce (PAPERS.md, arXiv 1512.01625) trades replicated map
work for shuffle bytes: when every map task runs on r ranks, the ranks
of an r-group share enough side information that one XOR-coded block
per step replaces the r-1 unicast bucket transfers inside the group,
cutting push-shuffle traffic toward 1/r.

This module holds the host-side half of ``JobConfig(code_rate=r)``:

  * **code groups** — ranks are grouped into P/r consecutive groups;
    ``group = rank // r``, ``member = rank % r``. ``n_procs`` must be
    divisible by ``code_rate`` (enforced by ``JobSpec.__post_init__``).
  * **replicated grids** (:func:`replicate_grids`) — the r=1 planner
    grid (P, T) becomes (P, T*r): column block k of every member of
    group g holds the *same* r-wide block — the group's members' r=1
    tasks at column k. The engine scan consumes one block per step
    (same step count as r=1, r× map compute per step), so Combine's
    dup-sum keeps the result record-identical to the solo run.
  * **bytes model** (:func:`shuffle_bytes`) — the deterministic
    bytes-on-the-wire accounting ``benchmarks/fig15_coded.py`` states
    the win with. The coded intra-group block is counted ONCE per step
    (multicast convention, as in the Coded MapReduce literature);
    inter-group buckets are deduplicated to a single speaker each.

The device-side half (the XOR encode/decode itself) is
``repro.distributed.collectives.coded_exchange``; the engine step that
consumes these grids is ``repro.core.onesided._coded_step``.
"""
from __future__ import annotations

import numpy as np

# one shuffled record on the wire: int32 key + int32 value
RECORD_BYTES = 8


def group_of(rank: int, code_rate: int) -> int:
    """Code group of ``rank`` (r consecutive ranks per group)."""
    return rank // code_rate


def member_of(rank: int, code_rate: int) -> int:
    """Member slot of ``rank`` inside its code group."""
    return rank % code_rate


def replicate_grids(task_ids, repeats, code_rate: int):
    """Replicate an r=1 assignment onto r-rank code groups.

    ``task_ids``/``repeats`` are the planner's (P, T) grids. Returns
    (P, T*r) grids in which every member of group g carries the
    identical row: T column blocks of width r, block k holding the
    group's members' original column-k tasks ``[ids[g*r+0, k], ...,
    ids[g*r+r-1, k]]`` (repeats travel with their task). Padding ids
    (-1) replicate like real tasks — a block is partially padded when
    the r=1 grid was.
    """
    ids = np.asarray(task_ids, np.int32)
    reps = np.asarray(repeats, np.int32)
    r = int(code_rate)
    if r <= 1:
        return ids, reps
    P, T = ids.shape
    if P % r:
        raise ValueError(
            f"code_rate={r} needs n_procs divisible into r-rank code "
            f"groups (got n_procs={P})")
    out_ids = np.empty((P, T * r), np.int32)
    out_reps = np.empty((P, T * r), np.int32)
    for g in range(P // r):
        rows = slice(g * r, (g + 1) * r)
        # (r, T) -> (T, r) -> row-major flatten = [block 0 | block 1 | ...]
        out_ids[rows] = ids[rows, :].T.reshape(1, T * r)
        out_reps[rows] = reps[rows, :].T.reshape(1, T * r)
    return out_ids, out_reps


def shuffle_blocks_per_step(n_procs: int, code_rate: int) -> int:
    """Logical push-shuffle payload blocks one rank puts on the wire per
    engine step.

    r=1: one unicast bucket per peer (the self row never travels).
    r>1: ONE coded intra-group multicast block (counted once) plus one
    unicast bucket per inter-group destination this member *speaks* for
    (destination q is spoken for by member q % r of every other group —
    the dedup that keeps the dup-sum exact).
    """
    P, r = int(n_procs), int(code_rate)
    if r <= 1:
        return P - 1
    return 1 + (P // r - 1)


def shuffle_bytes(n_procs: int, steps: int, push_cap: int,
                  code_rate: int) -> int:
    """Total push-shuffle bytes on the wire for a run of ``steps`` engine
    steps (fixed-capacity buckets, as the engine actually ships them)."""
    return (int(n_procs) * int(steps)
            * shuffle_blocks_per_step(n_procs, code_rate)
            * int(push_cap) * RECORD_BYTES)
