"""Backend registry — pluggable MapReduce engines behind one protocol.

The paper compares two engines (decoupled MR-1S vs bulk-synchronous
MR-2S); this module makes "engine" a first-class, extensible concept
instead of a hardcoded ``"1s"|"2s"`` string branch:

  * :class:`Backend` — the protocol every engine implements: a blocking
    ``run_job`` AND a segmented ``make_segment_fns`` triple, so the
    checkpoint / fault-tolerance layers consume one interface regardless
    of engine (the segmented path is no longer a onesided-only side-door).
  * :func:`register_backend` — class decorator; the built-in engines
    register themselves as ``"1s"`` and ``"2s"`` on import.
  * :func:`get_backend` / :func:`available_backends` — resolution, with
    a clear error listing what exists when a name is unknown.

``JobSpec`` (the static engine settings) lives here because it is part
of the backend interface, shared by every engine.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from collections.abc import Callable
from typing import Protocol, runtime_checkable

# built-in engines register lazily on first resolution so importing the
# registry stays cheap (no jax compile machinery pulled in for --help paths)
_BUILTIN_MODULES = {
    "1s": "repro.core.onesided",
    "2s": "repro.core.twosided",
}
_REGISTRY: dict[str, type] = {}


@dataclass(frozen=True)
class JobSpec:
    """Static engine settings (paper: Init(filename, win_size, chunk_size,
    task_size, ...))."""
    vocab: int                   # dense Key-Value window size ("win_size")
    task_size: int               # elements per Map task
    push_cap: int                # records per one-sided push per owner
                                 #   ("maximum bytes per one-sided operation")
    n_procs: int
    combine_capacity: int = 0    # 0 -> vocab
    segment: int = 0             # checkpoint segment (tasks between syncs)
    stealing: bool = False       # device-side work stealing (core/steal.py);
                                 #   only engines advertising
                                 #   ``supports_stealing`` honor it
    fused_map: bool = False      # run the per-step hot path as one pallas
                                 #   kernel (kernels/fused_map) instead of
                                 #   plain XLA ops; bit-identical results,
                                 #   only engines advertising
                                 #   ``supports_fused_map`` honor it. A
                                 #   comparing field: it selects a different
                                 #   compiled program, unlike the
                                 #   carry-data ``partitioner`` tag.
    code_rate: int = 1           # r-replicated coded shuffle (core/coded.py
                                 #   + distributed/collectives.coded_exchange):
                                 #   every map task runs on r consecutive
                                 #   ranks and the intra-group bucket push is
                                 #   one XOR-coded multicast block instead of
                                 #   r-1 unicasts. 1 = today's path,
                                 #   bit-identical. A comparing field: the
                                 #   coded step is a different compiled
                                 #   program. Only engines advertising
                                 #   ``supports_coded`` honor r > 1.
    # cross-job co-scheduling (core/workdomain.py): a WorkDomain merges
    # K program-compatible jobs into ONE engine program over a composite
    # task/key space. ``coslots`` is K (1 = ordinary solo job) and
    # ``costride`` the task-id stride between member jobs: composite
    # task id = slot * costride + local_id, composite key =
    # slot * (vocab // coslots) + key. Both compare: a co-scheduled
    # program routes records per-slot, so it is a distinct compiled
    # program. Only engines advertising ``supports_coschedule`` accept
    # coslots > 1.
    coslots: int = 1
    costride: int = 0
    # reduce-side key→owner strategy name (core/partition.py). The owner
    # map itself is CARRY DATA, so the compiled program is identical for
    # every partitioner — compare=False keeps this provenance tag out of
    # eq/hash and therefore out of the backends' jit-program memo keys
    # (one compiled engine really does serve every map); checkpoint
    # compat checks read the attribute directly.
    partitioner: str = field(default="hash", compare=False)

    def __post_init__(self):
        if not self.combine_capacity:
            object.__setattr__(self, "combine_capacity", self.vocab)
        if self.code_rate < 1:
            raise ValueError(f"code_rate must be >= 1, got {self.code_rate}")
        if self.code_rate > 1:
            if self.n_procs % self.code_rate:
                raise ValueError(
                    f"code_rate={self.code_rate} needs n_procs divisible "
                    f"into r-rank code groups (got n_procs={self.n_procs})")
            if self.fused_map:
                raise ValueError(
                    "fused_map does not compose with the coded exchange "
                    "(code_rate > 1) — the fused kernel pushes per-task "
                    "unicast buckets; run coded jobs unfused")
            if self.coslots > 1:
                raise ValueError(
                    "co-scheduling (coslots > 1) does not compose with "
                    "code_rate > 1 — the fleet cursor claims single task "
                    "slots, which would break the r-group decode")
        if self.coslots > 1:
            if self.fused_map:
                # the fused kernel resolves owners in-kernel over the
                # solo key space; co-scheduling "cleanly rejects" it
                raise ValueError(
                    "fused_map does not compose with co-scheduling "
                    "(coslots > 1) — the WorkDomain falls back to solo "
                    "slicing for fused jobs instead")
            if self.costride <= 0:
                raise ValueError("coslots > 1 needs a positive costride")
            if self.vocab % self.coslots:
                raise ValueError(
                    f"co-scheduled vocab {self.vocab} must be "
                    f"coslots={self.coslots} equal per-job windows")


# map_fn(task_tokens, task_id, repeat) -> (keys, values); built from a
# UseCase by repro.core.usecase.as_map_fn.
MapFn = Callable


@runtime_checkable
class Backend(Protocol):
    """What every engine provides. Both methods take the same
    ``(spec, map_fn, mesh, ...)`` wiring; ``map_fn`` has the signature
    ``map_fn(task_tokens, task_id, repeat) -> (keys, values)``."""

    name: str

    def run_job(self, spec: JobSpec, map_fn: MapFn, mesh, tokens,
                task_ids, repeats) -> tuple:
        """Blocking end-to-end run. tokens: (P, T, S); task_ids/repeats:
        (P, T). Returns rank-0 (keys, values) host arrays."""
        ...

    def make_segment_fns(self, spec: JobSpec, map_fn: MapFn, mesh):
        """Returns ``(init_fn, segment_fn, finish_fn)``, each jitted over
        the mesh, sharing the :class:`~repro.core.windows.EngineCarry`
        carry type — ``segment_fn(carry, tok, tid, rep)`` advances a
        segment; the host may snapshot the carry between calls (the
        paper's per-task window sync)."""
        ...


class UnknownBackendError(KeyError):
    pass


def register_backend(name: str):
    """Class decorator: ``@register_backend("1s")`` makes the engine
    resolvable by name through :func:`get_backend`."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def _ensure_builtins():
    for name, module in _BUILTIN_MODULES.items():
        if name not in _REGISTRY:
            importlib.import_module(module)


_INSTANCES: dict[str, Backend] = {}


def get_backend(name: str) -> Backend:
    """Resolve a backend name to its (singleton) engine instance —
    singletons so the engines' jitted-program caches persist across
    jobs."""
    if name not in _REGISTRY and name in _BUILTIN_MODULES:
        importlib.import_module(_BUILTIN_MODULES[name])
    if name not in _REGISTRY:
        _ensure_builtins()
        raise UnknownBackendError(
            f"unknown backend {name!r}; available: {available_backends()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


# ---------------------------------------------------------------------------
# traceable program handles (consumed by repro.analysis — fleetlint)
# ---------------------------------------------------------------------------

# The engines' replication contract, by flattened argument/output path.
# Everything here is *asserted* replicated across ranks by the engine
# design (psum-maintained progress rows, carried owner maps, psum'd
# overflow totals); fleetlint's REP001 rule proves it from the jaxpr.
# ``carry.job_work`` is the cross-job executed-work row (one slot per
# co-scheduled member job) — psum-maintained exactly like ``carry.work``
# so every rank agrees on how much of each tenant's work actually ran.
ENGINE_REPLICATED_CARRY = ("carry.status", "carry.cursor", "carry.work",
                           "carry.stolen", "carry.job_work",
                           "carry.owner_map", "carry.owner_split")


@dataclass(frozen=True)
class ProgramHandle:
    """One traceable SPMD program: enough to ``jax.make_jaxpr`` it and to
    interpret the flattened inputs/outputs by name.

    ``fn(*args)`` must be traceable with ``args`` (ShapeDtypeStructs are
    fine — nothing executes). ``arg_paths``/``out_paths`` name the
    *flattened* (tree-leaf order) inputs/outputs; ``replicated_in`` /
    ``replicated_out`` are the subset the backend asserts replicated
    across ``allowed_axes`` — the analyzer's REP001 obligation."""
    name: str
    fn: Callable
    args: tuple
    arg_paths: tuple[str, ...]
    out_paths: tuple[str, ...]
    replicated_in: tuple[str, ...] = ()
    replicated_out: tuple[str, ...] = ()
    allowed_axes: tuple[str, ...] = ("procs",)


def segment_program_handles(backend: Backend, spec: JobSpec,
                            map_fn: MapFn, mesh, seg_tasks: int = 2,
                            tag: str = "") -> tuple[ProgramHandle, ...]:
    """Build :class:`ProgramHandle`\\ s for a backend's segmented triple.

    Shared by every backend whose segmented path speaks
    :class:`~repro.core.windows.EngineCarry` (both built-ins do); a
    backend with a different carry overrides ``trace_handles`` wholesale.
    Nothing is executed — args are ShapeDtypeStructs and the carry
    structure comes from ``jax.eval_shape(init_fn)``.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.windows import EngineCarry

    init_fn, seg_fn, fin_fn = backend.make_segment_fns(spec, map_fn, mesh)
    carry_shapes = jax.eval_shape(init_fn)
    P, S = spec.n_procs, spec.task_size
    tok = jax.ShapeDtypeStruct((P, seg_tasks, S), jnp.int32)
    tid = jax.ShapeDtypeStruct((P, seg_tasks), jnp.int32)
    rep = jax.ShapeDtypeStruct((P, seg_tasks), jnp.int32)

    carry_paths = tuple(f"carry.{f}" for f in EngineCarry._fields)
    if not tag:
        fn_name = getattr(map_fn, "__name__", "map_fn")
        tag = f"{backend.name}/{fn_name}"
    return (
        ProgramHandle(
            name=f"{tag}/init", fn=init_fn, args=(),
            arg_paths=(), out_paths=carry_paths,
            replicated_out=ENGINE_REPLICATED_CARRY),
        ProgramHandle(
            name=f"{tag}/segment", fn=seg_fn,
            args=(carry_shapes, tok, tid, rep),
            arg_paths=carry_paths + ("tokens", "task_ids", "repeats"),
            out_paths=carry_paths,
            replicated_in=ENGINE_REPLICATED_CARRY,
            replicated_out=ENGINE_REPLICATED_CARRY),
        ProgramHandle(
            name=f"{tag}/finish", fn=fin_fn, args=(carry_shapes,),
            arg_paths=carry_paths,
            out_paths=("keys", "values", "combine_overflow"),
            replicated_in=ENGINE_REPLICATED_CARRY,
            replicated_out=("combine_overflow",)),
    )


def memoized(cache: dict, key, builder):
    """Tiny jit-program memo helper for backends; falls back to building
    uncached when the key is unhashable."""
    try:
        hit = cache.get(key)
    except TypeError:
        return builder()
    if hit is None:
        cache[key] = hit = builder()
    return hit


def available_backends():
    _ensure_builtins()
    return sorted(_REGISTRY)
