"""Combine phase — tree-based merge of per-process sorted results.

Paper §2.1 / Fig 3: ⌈log2(P)⌉ + 1 levels; level 0 is each process's local
in-order records; at every further level, rank i+2^l sends its current run to
rank i (one-sided get in the paper → ``collective_permute`` here) which merges
the two sorted runs, summing duplicate keys (this also resolves the records
whose ownership was transferred during Map overflow). After the last level,
rank 0 holds the globally sorted result.

MPI_LOCK_EXCLUSIVE has no analogue (and no need): SPMD lockstep already
serializes levels.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax

from repro.core.kv import local_reduce


def n_levels(n_procs: int) -> int:
    return int(math.ceil(math.log2(max(n_procs, 2))))


# Overflow totals accumulate across ranks (psum) and tree levels in int32
# (jnp.int64 silently degrades to int32 without x64, so widening is not an
# option here). A wrapped counter could report 0 lost records after losing
# 2^32 — saturating at INT32_MAX keeps the "0 means exact" contract.
SAT_MAX = jnp.iinfo(jnp.int32).max


def sat_add_i32(a, b):
    """Saturating int32 add for non-negative operands: wrap -> SAT_MAX."""
    s = a + b
    return jnp.where(s < a, jnp.int32(SAT_MAX), s)


def _sat_psum(x, axis: str, n_procs: int):
    """psum of non-negative int32 counts that cannot wrap: each rank's
    contribution is pre-clamped to SAT_MAX // P so the P-way sum stays
    inside int32; a clamped contribution already means the true total
    saturates."""
    cap = jnp.int32(SAT_MAX // max(n_procs, 1))
    return lax.psum(jnp.minimum(x.astype(jnp.int32), cap), axis)


def tree_combine(keys, vals, axis: str, n_procs: int, overflow=None):
    """Run the merge tree inside a shard_map region.

    keys/vals: this process's sorted unique records, (W,), sentinel-padded.
    ``overflow`` seeds the per-rank count of records already lost before
    the tree (e.g. squeezing a window into W — see ``combine_records``).

    Returns ``(keys, vals, total_overflow)``: rank 0 holds the final
    merged records (other ranks return their last partial state —
    callers slice rank 0), while ``total_overflow`` is the *global*
    count of records dropped anywhere on the way to rank 0 — each
    W-wide merge of two runs whose key union exceeds W truncates the
    union, and that loss used to vanish silently at the next level.
    The count is psum-replicated, so every rank returns the same value
    and a 0 guarantees the rank-0 records are exact. It saturates at
    ``SAT_MAX`` instead of wrapping, so a huge loss can never read as 0.
    """
    W = keys.shape[0]
    rank = lax.axis_index(axis)
    if overflow is None:
        overflow = jnp.int32(0)
    total = _sat_psum(overflow, axis, n_procs)
    for level in range(n_levels(n_procs)):
        stride = 1 << level
        perm = [(i + stride, i) for i in range(0, n_procs, stride * 2)
                if i + stride < n_procs]
        rk = lax.ppermute(keys, axis, perm)
        rv = lax.ppermute(vals, axis, perm)
        # ppermute delivers zeros to non-receivers; treat key 0 as valid only
        # on true receivers by masking the merge with receiver-ship.
        is_receiver = (rank % (stride * 2) == 0) & (rank + stride < n_procs)
        mk, mv, n_union = local_reduce(jnp.concatenate([keys, rk]),
                                       jnp.concatenate([vals, rv]), W)
        lost = jnp.where(is_receiver,
                         jnp.maximum(n_union.astype(jnp.int32) - W, 0), 0)
        total = sat_add_i32(total, _sat_psum(lost, axis, n_procs))
        keys = jnp.where(is_receiver, mk, keys)
        vals = jnp.where(is_receiver, mv, vals)
    return keys, vals, total
