"""Self-scheduled task planner — the paper's decentralized Map distribution.

"Instead of following a master-slave approach, we design a mechanism that
enables processes to decide the next task to perform based on the rank, task
size, and file offset between tasks."  (paper §2.1)

Tasks are fixed-size slices of the input. Rank r takes tasks
{r, r+P, r+2P, ...} (round-robin by rank — no master, no coordination).
The planner also owns straggler re-issue bookkeeping (ft/straggler.py) and
the restart cursor for checkpointing.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TaskPlan:
    n_tasks: int
    task_size: int
    n_procs: int

    @property
    def tasks_per_proc(self) -> int:
        return (self.n_tasks + self.n_procs - 1) // self.n_procs

    def tasks_for_rank(self, rank: int) -> np.ndarray:
        """Round-robin self-schedule; padded with -1 (no-op tasks)."""
        ids = np.arange(rank, self.n_tasks, self.n_procs)
        pad = self.tasks_per_proc - len(ids)
        return np.concatenate([ids, -np.ones(pad, np.int64)]).astype(np.int32)

    def file_offset(self, task_id: int) -> int:
        """Byte/element offset of a task — the non-blocking I/O prefetch
        target for the *next* task while the current one computes."""
        return task_id * self.task_size


def plan_input(n_elements: int, task_size: int, n_procs: int) -> TaskPlan:
    n_tasks = (n_elements + task_size - 1) // task_size
    # round up so every rank runs the same scan length (SPMD requirement)
    return TaskPlan(n_tasks=n_tasks, task_size=task_size, n_procs=n_procs)


def shard_task_ids(plan: TaskPlan) -> np.ndarray:
    """Host-side: per-rank (tasks_per_proc,) grid of *global* task ids,
    -1 for padding slots — threaded through the engines so use-cases can
    key by position (e.g. document = task range)."""
    return np.stack([plan.tasks_for_rank(r) for r in range(plan.n_procs)])


def read_task(source, plan: TaskPlan, task_id: int) -> np.ndarray:
    """Read one task's input by file offset — the paper's non-blocking
    I/O unit. Returns a (task_size,) int32 block, KEY_SENTINEL padded
    (short reads at EOF, all-sentinel for padding ids < 0)."""
    from repro.core.kv import KEY_SENTINEL
    out = np.full((plan.task_size,), int(KEY_SENTINEL), np.int32)
    if task_id >= 0:
        chunk = source.read(plan.file_offset(task_id), plan.task_size)
        out[: len(chunk)] = chunk
    return out


def read_tasks(source, plan: TaskPlan, task_ids: np.ndarray) -> np.ndarray:
    """Vectorized :func:`read_task`: an arbitrary array of *global* task
    ids (any shape, -1 for padding) becomes a token block of matching
    leading shape. Addressing by global id — never by assignment slot —
    is what lets a work-stealing rank read a task originally assigned to
    a different rank (and lets the tests cross-check the engine's
    steal fetch against the source of truth)."""
    from repro.core.kv import KEY_SENTINEL
    ids = np.asarray(task_ids)
    out = np.full(ids.shape + (plan.task_size,), int(KEY_SENTINEL),
                  np.int32)
    for idx in np.ndindex(*ids.shape):
        if ids[idx] >= 0:
            out[idx] = read_task(source, plan, int(ids[idx]))
    return out


def gather_segment(source, plan: TaskPlan,
                   task_id_grid: np.ndarray) -> np.ndarray:
    """Offset-based per-segment shard plan: materialize exactly the
    (n_procs, n, task_size) token block for one segment's task-id grid —
    the only host residency the streaming path ever needs. Replaces the
    whole-input pre-shard for execution."""
    return read_tasks(source, plan, task_id_grid)


def shard_tasks(tokens: np.ndarray, plan: TaskPlan):
    """Host-side: the fully-resident pre-shard — per-rank
    (tasks_per_proc, task_size) input blocks, padding tasks all-sentinel.
    Kept for the legacy API shim and resident baselines; the Job API now
    streams per-segment via :func:`gather_segment` instead."""
    from repro.data.source import ArraySource
    return gather_segment(ArraySource(tokens), plan, shard_task_ids(plan))
