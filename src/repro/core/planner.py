"""Self-scheduled task planner — the paper's decentralized Map distribution.

"Instead of following a master-slave approach, we design a mechanism that
enables processes to decide the next task to perform based on the rank, task
size, and file offset between tasks."  (paper §2.1)

Tasks are fixed-size slices of the input. Rank r takes tasks
{r, r+P, r+2P, ...} (round-robin by rank — no master, no coordination).
The planner also owns straggler re-issue bookkeeping (ft/straggler.py) and
the restart cursor for checkpointing.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TaskPlan:
    n_tasks: int
    task_size: int
    n_procs: int

    @property
    def tasks_per_proc(self) -> int:
        return (self.n_tasks + self.n_procs - 1) // self.n_procs

    def tasks_for_rank(self, rank: int) -> np.ndarray:
        """Round-robin self-schedule; padded with -1 (no-op tasks)."""
        ids = np.arange(rank, self.n_tasks, self.n_procs)
        pad = self.tasks_per_proc - len(ids)
        return np.concatenate([ids, -np.ones(pad, np.int64)]).astype(np.int32)

    def file_offset(self, task_id: int) -> int:
        """Byte/element offset of a task — the non-blocking I/O prefetch
        target for the *next* task while the current one computes."""
        return task_id * self.task_size


def plan_input(n_elements: int, task_size: int, n_procs: int) -> TaskPlan:
    n_tasks = (n_elements + task_size - 1) // task_size
    # round up so every rank runs the same scan length (SPMD requirement)
    return TaskPlan(n_tasks=n_tasks, task_size=task_size, n_procs=n_procs)


def shard_task_ids(plan: TaskPlan) -> np.ndarray:
    """Host-side: per-rank (tasks_per_proc,) grid of *global* task ids,
    -1 for padding slots — threaded through the engines so use-cases can
    key by position (e.g. document = task range)."""
    return np.stack([plan.tasks_for_rank(r) for r in range(plan.n_procs)])


def shard_tasks(tokens: np.ndarray, plan: TaskPlan):
    """Host-side: build per-rank (tasks_per_proc, task_size) input blocks +
    validity mask. Padding tasks are all-sentinel."""
    from repro.core.kv import KEY_SENTINEL
    n = plan.n_tasks * plan.task_size
    flat = np.full((n,), int(KEY_SENTINEL), np.int32)
    flat[: len(tokens)] = tokens
    grid = flat.reshape(plan.n_tasks, plan.task_size)
    out = np.full((plan.n_procs, plan.tasks_per_proc, plan.task_size),
                  int(KEY_SENTINEL), np.int32)
    for r in range(plan.n_procs):
        ids = plan.tasks_for_rank(r)
        for j, t in enumerate(ids):
            if t >= 0:
                out[r, j] = grid[t]
    return out
