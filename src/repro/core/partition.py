"""Skew-aware reduce partitioning — pluggable key→owner assignment.

The paper owns each key by ``hash(key) % P`` (core/kv.py:owner_of),
which spreads *keys* uniformly but not *records*: a Zipf-skewed key
distribution — WordCount on natural text — floods one owner's window,
overflows its push buckets and shifts work into ownership transfer and
the Combine tree. Fan et al. (arXiv:1401.0355) and OS4M
(arXiv:1406.3901) both balance the *observed* key distribution instead;
this module brings that into the engines as a first-class subsystem:

  * :class:`Partitioner` — the protocol: ``build(hist, n_procs)``
    returns a dense **owner map** (``owner_map[key] -> rank``) plus a
    **split map** (``owner_split[key] = k`` replicas for hot keys).
  * :class:`HashPartitioner` — today's behavior, materialized as a
    dense map (``owner_of(arange(vocab), P)``), bit-identical to the
    modulo rule. The default.
  * :class:`SampledPartitioner` — greedy LPT bin-packing of the keys
    observed in a planner pre-pass (a histogram over a few sampled
    tasks, read through the job's own :class:`~repro.data.feed.
    SegmentFeed` so the bytes land in its stats). Keys never seen in
    the sample keep their hash owner, so the map is total.
  * **Hot-key splitting** (``SampledPartitioner(split=True)``): a key
    heavier than a fraction of the per-rank target load is assigned
    ``k > 1`` consecutive owners; mappers pick a replica by (mixed)
    task id. Exactness is free — the Combine tree's dup-sum already
    merges split partials, the same argument that makes ownership
    transfer and work stealing locality-independent.

The owner/split maps ride :class:`~repro.core.windows.EngineCarry`
(not the jitted program), so one compiled engine serves every map, a
checkpoint snapshots the map for free, and restore rejects a
partitioner mismatch exactly like the ``stealing`` flag.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core.kv import KEY_SENTINEL, mix32, owner_of


@runtime_checkable
class Partitioner(Protocol):
    """Key→owner assignment strategy for the reduce side."""

    name: str
    needs_sample: bool      # True -> submit runs the planner pre-pass

    def build(self, hist: np.ndarray,
              n_procs: int) -> tuple[np.ndarray, np.ndarray]:
        """(owner_map, owner_split) int32 arrays of shape (vocab,).

        ``hist[key]`` is the sampled load proxy (tasks containing the
        key — each task pushes at most one record per key, so task
        presence, not raw frequency, is the records-per-owner load).
        """
        ...


def hash_owner_map(vocab: int, n_procs: int) -> np.ndarray:
    """The paper's modulo rule as a dense map — bit-identical to
    ``owner_of`` on every key in [0, vocab)."""
    return np.asarray(owner_of(jnp.arange(vocab, dtype=jnp.int32),
                               n_procs), np.int32)


@dataclass(frozen=True)
class HashPartitioner:
    """Static ``hash(key) % P`` — the default, zero pre-pass cost."""

    name = "hash"
    needs_sample = False

    def build(self, hist, n_procs: int):
        vocab = len(hist)
        return hash_owner_map(vocab, n_procs), np.ones((vocab,), np.int32)


@dataclass(frozen=True)
class SampledPartitioner:
    """Balanced owner map from a sampled key histogram.

    Greedy LPT: observed keys, heaviest first, each to the currently
    least-loaded rank. With ``split=True`` a key heavier than
    ``split_threshold`` × (total/P) is divided across
    ``k = ceil(load / threshold)`` consecutive ranks (capped at
    ``max_split`` or P); the base rank is chosen to minimize the
    resulting max load. Unobserved keys keep their hash owner.
    """

    sample_tasks: int = 16
    split: bool = False
    max_split: int = 0            # 0 -> n_procs
    split_threshold: float = 0.5  # fraction of the per-rank target load

    needs_sample = True

    @property
    def name(self) -> str:
        return "sampled+split" if self.split else "sampled"

    def build(self, hist, n_procs: int):
        hist = np.asarray(hist, np.float64)
        vocab = len(hist)
        omap = hash_owner_map(vocab, n_procs)
        osplit = np.ones((vocab,), np.int32)
        total = float(hist.sum())
        if total <= 0 or n_procs <= 1:
            return omap, osplit
        omap = omap.copy()
        load = np.zeros((n_procs,), np.float64)
        order = np.argsort(-hist, kind="stable")
        order = order[hist[order] > 0]
        chunk = max(self.split_threshold * total / n_procs, 1.0)
        cap = self.max_split or n_procs
        for key in order.tolist():
            c = float(hist[key])
            k = min(cap, int(np.ceil(c / chunk))) if self.split else 1
            if k > 1:
                share = c / k
                spans = np.array([[(b + j) % n_procs for j in range(k)]
                                  for b in range(n_procs)])
                base = int(np.argmin(load[spans].max(axis=1) + share))
                omap[key], osplit[key] = base, k
                load[spans[base]] += share
            else:
                b = int(np.argmin(load))
                omap[key] = b
                load[b] += c
        return omap, osplit


_NAMED = {
    "hash": HashPartitioner(),
    "sampled": SampledPartitioner(),
    "sampled+split": SampledPartitioner(split=True),
}


def available_partitioners():
    return sorted(_NAMED)


def resolve_partitioner(p: str | Partitioner) -> Partitioner:
    """Name or instance -> instance, with a clear error on unknowns."""
    if isinstance(p, str):
        if p not in _NAMED:
            raise ValueError(f"unknown partitioner {p!r}; available: "
                             f"{available_partitioners()} (or pass a "
                             "Partitioner instance)")
        return _NAMED[p]
    if not isinstance(p, Partitioner):
        raise TypeError(f"not a Partitioner: {p!r}")
    return p


def fold_owner_map(owner_map: np.ndarray, owner_split: np.ndarray,
                   n_new: int) -> tuple[np.ndarray, np.ndarray]:
    """Project a key→owner assignment onto ``n_new`` ranks (host twin of
    the device fold in :mod:`repro.fleet.remesh`): owners wrap modulo
    the new rank count and split widths clamp to it. Any total map is
    *correct* after a re-mesh — the Combine dup-sum merges records
    wherever they land — so folding preserves a sampled map's balance
    intent without re-running the planner pre-pass (which would cost
    dataset reads exactly when recovery time matters most)."""
    omap = np.asarray(owner_map, np.int32) % np.int32(n_new)
    osplit = np.clip(np.asarray(owner_split, np.int32), 1, n_new)
    return omap, osplit.astype(np.int32)


# ---------------------------------------------------------------------------
# planner pre-pass: sampled key histogram
# ---------------------------------------------------------------------------

def sample_key_histogram(read_tasks_fn, plan, usecase, n_sample: int,
                         window: int = 0) -> np.ndarray:
    """Histogram the keys of up to ``n_sample`` tasks spread evenly over
    the input — the load proxy :meth:`Partitioner.build` consumes.

    ``read_tasks_fn(ids)`` serves token blocks by global task id (pass
    ``feed.sample_tasks`` so the read lands in the feed's stats); the
    use-case's ``map_emit`` runs per sampled task, and each task counts
    every distinct key it emits once (a task pushes at most one record
    per key after its local reduce). ``window`` sizes the histogram —
    pass the *engine's* window (``JobSpec.vocab``, which a
    ``JobConfig(window=...)`` override may widen past
    ``usecase.window``) so the owner map built from it matches the
    carry's shape; 0 falls back to ``usecase.window``.
    """
    sent = int(KEY_SENTINEL)
    window = int(window) or usecase.window
    hist = np.zeros((window,), np.int64)
    if plan.n_tasks <= 0:
        return hist
    n = max(1, min(int(n_sample), plan.n_tasks))
    ids = np.unique(np.linspace(0, plan.n_tasks - 1, n).round()
                    .astype(np.int64)).astype(np.int32)
    tokens = read_tasks_fn(ids)
    for i, t in enumerate(ids.tolist()):
        keys = np.asarray(usecase.map_emit(jnp.asarray(tokens[i]),
                                           jnp.int32(t))[0])
        keys = keys[(keys != sent) & (keys >= 0) & (keys < window)]
        np.add.at(hist, np.unique(keys), 1)
    return hist


# ---------------------------------------------------------------------------
# device side: owner lookup (runs inside the engines' scan)
# ---------------------------------------------------------------------------

def lookup_owner(owner_map: jnp.ndarray, owner_split: jnp.ndarray,
                 keys: jnp.ndarray, task_id: jnp.ndarray,
                 n_procs: int) -> jnp.ndarray:
    """Owner of each key under a dense (owner_map, owner_split) pair.

    Split keys (``owner_split[key] = k > 1``) resolve to one of the k
    consecutive replica ranks ``(base + j) % P``, picked by the mixed
    task id — every mapper working task t agrees, different tasks
    spread across replicas. Invalid keys (sentinel / out of window) map
    to the ghost owner ``n_procs``, same as :func:`~repro.core.kv.
    bucketize`'s own masking.
    """
    vocab = owner_map.shape[0]
    valid = (keys != KEY_SENTINEL) & (keys >= 0) & (keys < vocab)
    idx = jnp.where(valid, keys, 0)
    base = owner_map[idx]
    k = jnp.maximum(owner_split[idx], 1)
    pick = (mix32(task_id.astype(jnp.uint32))
            % k.astype(jnp.uint32)).astype(jnp.int32)
    owner = (base + jnp.where(k > 1, pick, 0)) % jnp.int32(n_procs)
    return jnp.where(valid, owner, jnp.int32(n_procs))


def owner_loads(hist: np.ndarray, owner_map: np.ndarray,
                owner_split: np.ndarray, n_procs: int) -> np.ndarray:
    """Expected records per owner under a map — the reduce-side load
    model fig10 and the balance tests share. Split keys contribute
    ``hist/k`` to each of their k replica ranks."""
    hist = np.asarray(hist, np.float64)
    load = np.zeros((n_procs,), np.float64)
    keys = np.nonzero(hist > 0)[0]
    for key in keys.tolist():
        k = max(int(owner_split[key]), 1)
        share = hist[key] / k
        for j in range(k):
            load[(int(owner_map[key]) + j) % n_procs] += share
    return load
