"""Device-side work stealing for the decoupled 1S engine.

The paper's claim is that decoupling pays off when "the workload per
process is unexpectedly unbalanced"; OS4M (arXiv:1406.3901) locates the
win at *operation*-level scheduling. Host re-planning at segment
boundaries (``repro.ft.straggler``) is too coarse for that — a slow rank
still gates every segment. This module moves rebalancing inside the
engine scan:

  * every scan step, each rank's executed work lands in a **progress
    row** of :class:`~repro.core.windows.EngineCarry` (``carry.work``),
    maintained with a one-hot ``psum`` — the one-sided-window analogue
    of publishing a cursor that every peer can read;
  * a **pure claim function** (:func:`claim_step`) maps that shared
    cursor state to this step's task assignment: ranks that ran ahead
    (least cumulative work) claim tasks from the *tail* of the most
    loaded rank's unstarted range;
  * because the claim is a deterministic function of replicated state,
    every rank computes the identical assignment — each task slot is
    popped from exactly one deque exactly once, so **exactly-once
    semantics hold with no dedup machinery** (same argument as the
    host re-planner, one level down).

The engine (:mod:`repro.core.onesided`) serves a claimed task to its
executor by global task id through one extra fixed-shape
``all_to_all`` per step — the one-sided "get" mirroring the push
shuffle. Results are exact regardless of who executes a task: records
are bucketized by key ownership and the Combine tree dup-sums across
every rank's window, so execution locality never changes the output.

:func:`steal_schedule` replays the same claim function on the host over
a full assignment grid — the property tests pin exactly-once on random
cursor states with it, and ``benchmarks/fig9_imbalance.py`` feeds the
realized schedule into the calibrated lockstep model.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# Work-unit hysteresis: a rank only claims a peer's task when the peer's
# cumulative work exceeds its own by at least this margin. One unit ==
# one compute-repeat. Strictly uniform task costs therefore never
# trigger a steal; per-task jitter above the margin causes some benign
# churn — harmless, because a steal only re-routes rows inside the
# task-fetch all_to_all the steal engine ships every step anyway, and
# results are locality-independent.
STEAL_MARGIN = 1


def claim_step(head: jnp.ndarray, tail: jnp.ndarray, work: jnp.ndarray,
               margin: int = STEAL_MARGIN) -> tuple[jnp.ndarray, ...]:
    """One scheduling round of the work-stealing claim.

    ``head``/``tail`` are the per-rank cursors into each rank's own
    unstarted column range ``[head[v], tail[v])`` (replicated: every
    rank holds the identical (P,) rows); ``work`` is the psum-maintained
    cumulative-work progress row. Executors are processed
    fastest-first (least work, ties by rank id); each either

      * pops its **own head** (the default, keeping the self-scheduled
        order), or
      * **steals the tail** of the most-loaded rank still holding
        unstarted tasks — when it has fallen ``margin`` work units
        behind that victim, or when its own range is empty, or
      * idles (src ``-1``) when every deque is empty.

    Returns ``(src_rank, src_col, head, tail)``: executor ``e`` runs the
    task at column ``src_col[e]`` of rank ``src_rank[e]``'s grid row.
    Pure and deterministic — identical on every rank for identical
    inputs, which is what makes the claims exactly-once with no dedup.
    """
    head, tail, work = (jnp.asarray(x, jnp.int32)
                        for x in (head, tail, work))
    P = head.shape[0]
    order = jnp.lexsort((jnp.arange(P), work))          # fastest first

    def assign(i, st):
        head, tail, src_r, src_c = st
        e = order[i]
        rem = tail - head
        # victim: max cumulative work among ranks with unstarted tasks
        v = jnp.argmax(jnp.where(rem > 0, work, -1))
        own = rem[e] > 0
        victim_ok = (rem[v] > 0) & (v != e)
        behind = work[v] - work[e] >= margin
        steal = victim_ok & (behind | ~own)
        take_own = own & ~steal
        src_r = src_r.at[e].set(
            jnp.where(take_own, e, jnp.where(steal, v, -1)).astype(jnp.int32))
        src_c = src_c.at[e].set(
            jnp.where(take_own, head[e],
                      jnp.where(steal, tail[v] - 1, -1)).astype(jnp.int32))
        head = head.at[e].add(take_own.astype(head.dtype))
        tail = tail.at[jnp.where(steal, v, e)].add(
            -steal.astype(tail.dtype))
        return head, tail, src_r, src_c

    idle = jnp.full((P,), -1, jnp.int32)
    head, tail, src_rank, src_col = lax.fori_loop(
        0, P, assign, (head, tail, idle, idle))
    return src_rank, src_col, head, tail


def segment_cursors(task_ids: jnp.ndarray, axis: str | None = None):
    """Initial (head, tail) rows for one segment grid.

    ``tail`` counts each rank's *real* columns (padding id ``-1`` is
    excluded from the deques — a fast rank steals work instead of
    running a no-op). On device, pass ``axis`` to build the replicated
    row from each rank's local count via the one-hot psum; on host,
    pass the full (P, n) grid with ``axis=None``.
    """
    if axis is None:
        ids = jnp.asarray(task_ids)
        tail = (ids >= 0).sum(axis=1).astype(jnp.int32)
        return jnp.zeros_like(tail), tail
    me = lax.axis_index(axis)
    P = lax.psum(1, axis)
    count = (jnp.asarray(task_ids) >= 0).sum().astype(jnp.int32)
    tail = lax.psum(jnp.where(jnp.arange(P) == me, count, 0), axis)
    return jnp.zeros_like(tail), tail


def compact_columns(task_ids: jnp.ndarray):
    """Permutation putting a grid row's real columns before its padding
    (``claim_step`` addresses each deque as a dense ``[0, count)``
    range). Stable, so the self-scheduled order is preserved."""
    return jnp.argsort(jnp.asarray(task_ids) < 0)


# ---------------------------------------------------------------------------
# fleet-wide cursor — composite (job, task) grids for cross-job stealing
# ---------------------------------------------------------------------------
#
# ``claim_step`` itself is already fleet-ready: it schedules over opaque
# deque columns, so feeding it a grid whose columns come from SEVERAL
# jobs turns intra-job stealing into global work stealing with the same
# pure/replicated/exactly-once argument (each composite column is still
# popped exactly once). What the fleet adds is the *encoding*: a
# composite task id ``slot * stride + local_id`` names (member job,
# task), and :func:`fleet_merge` lays the members' columns out per rank
# with a job-priority lane ordering — the shared cursor every rank's
# claims draw from. :func:`composite_slots` inverts the encoding.

def composite_slots(task_ids, stride: int):
    """Member-job slot of each composite task id (-1 for padding)."""
    ids = np.asarray(task_ids, np.int64)
    return np.where(ids >= 0, ids // int(stride), -1).astype(np.int32)


def fleet_merge(task_ids, repeats, *, stride: int,
                priorities=None) -> tuple[np.ndarray, np.ndarray]:
    """Merge K member assignment grids into one fleet grid.

    ``task_ids`` / ``repeats`` are parallel sequences of (P, T_j) member
    grids (padding id -1, any T_j). Member ``j``'s local ids are lifted
    to composite ids ``j * stride + local``; per rank the columns are
    ordered by **priority lane** (higher ``priorities[j]`` first, stable
    in member order within a tie) and round-robin interleaved across the
    members of a lane — co-resident equal-priority jobs progress
    together, while a higher lane's tasks sit at the head of every
    deque so they are claimed (and stolen) first. Returns ``(ids,
    reps)`` of shape (P, N), -1/1 padded.

    A single-member merge is the identity (ids unchanged, order
    preserved) — the single-job fleet reduces bit-identically to the
    solo schedule, which the property tests pin.
    """
    K = len(task_ids)
    assert K == len(repeats) and K >= 1
    stride = int(stride)
    prios = ([0] * K if priorities is None else list(priorities))
    assert len(prios) == K
    grids = [np.asarray(g, np.int32) for g in task_ids]
    rgrids = [np.asarray(r, np.int32) for r in repeats]
    P = grids[0].shape[0]
    for g, r in zip(grids, rgrids):
        assert g.shape == r.shape and g.shape[0] == P, \
            "member grids must share the rank count"
        assert g.max(initial=-1) < stride, \
            f"member local ids must fit the stride ({stride})"
    # lanes: higher priority first, admission (member) order within
    lanes: dict[int, list[int]] = {}
    for j in sorted(range(K), key=lambda j: (-prios[j], j)):
        lanes.setdefault(prios[j], []).append(j)
    rows_ids: list[list[int]] = [[] for _ in range(P)]
    rows_reps: list[list[int]] = [[] for _ in range(P)]
    for r in range(P):
        for prio in sorted(lanes, reverse=True):
            members = lanes[prio]
            cols = [[(int(t), int(rep)) for t, rep in
                     zip(grids[j][r], rgrids[j][r]) if t >= 0]
                    for j in members]
            width = max((len(c) for c in cols), default=0)
            for k in range(width):        # round-robin interleave
                for j, c in zip(members, cols):
                    if k < len(c):
                        t, rep = c[k]
                        rows_ids[r].append(j * stride + t)
                        rows_reps[r].append(rep)
    N = max((len(row) for row in rows_ids), default=0)
    ids = np.full((P, max(N, 1)), -1, np.int32)
    reps = np.ones((P, max(N, 1)), np.int32)
    for r in range(P):
        ids[r, : len(rows_ids[r])] = rows_ids[r]
        reps[r, : len(rows_reps[r])] = rows_reps[r]
    return ids, reps


# ---------------------------------------------------------------------------
# host replay — the same claim function, driven over a whole grid
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StealSchedule:
    """The realized execution schedule of one segment under stealing."""
    src_rank: np.ndarray     # (P, n) rank whose slot step k executed (-1 idle)
    src_col: np.ndarray      # (P, n) column within the source rank's row
    exec_ids: np.ndarray     # (P, n) global task id executed (-1 idle)
    exec_reps: np.ndarray    # (P, n) compute-repeats executed (0 idle)
    work: np.ndarray         # (P,) final cumulative work row
    stolen: np.ndarray       # (P,) tasks each rank executed for a peer
    slot_work: np.ndarray | None = None
                             # (coslots,) executed work per member-job
                             #   slot when replaying a composite fleet
                             #   grid — the host twin of the engine's
                             #   psum-maintained ``carry.job_work`` row

    @property
    def n_stolen(self) -> int:
        return int(self.stolen.sum())


@lru_cache(maxsize=None)
def _jitted_claim(margin: int):
    """One compiled claim program per margin, shared by every
    steal_schedule call (the jit cache is keyed on the callable, so a
    fresh partial per call would re-trace every time)."""
    return jax.jit(partial(claim_step, margin=margin))


def steal_schedule(task_ids: np.ndarray, repeats: np.ndarray,
                   margin: int = STEAL_MARGIN,
                   work0: np.ndarray | None = None,
                   coslots: int = 1,
                   costride: int = 0) -> StealSchedule:
    """Replay :func:`claim_step` over one (P, n) assignment grid.

    This is bit-identical to the schedule the device scan realizes (it
    is the same jitted claim function, fed the same replicated state),
    which is what lets the benchmark model a steal run's makespan and
    the tests check exactly-once without touching the engine.
    ``work0`` seeds the progress row (cumulative across segments).

    For a composite fleet grid (:func:`fleet_merge`), pass the domain's
    ``coslots``/``costride`` to also get ``slot_work`` — executed work
    split by member-job slot, matching ``carry.job_work`` on device.
    """
    ids = np.asarray(task_ids, np.int32)
    reps = np.asarray(repeats, np.int32)
    assert ids.shape == reps.shape
    P, n = ids.shape
    # per-rank compaction: real columns first, as the engine sees them
    perm = np.argsort(ids < 0, axis=1, kind="stable")
    cids = np.take_along_axis(ids, perm, axis=1)
    creps = np.take_along_axis(reps, perm, axis=1)
    head = np.zeros((P,), np.int32)
    tail = (ids >= 0).sum(axis=1).astype(np.int32)
    work = (np.zeros((P,), np.int32) if work0 is None
            else np.asarray(work0, np.int32).copy())
    step = _jitted_claim(margin)
    src_rank = np.full((P, n), -1, np.int32)
    src_col = np.full((P, n), -1, np.int32)
    exec_ids = np.full((P, n), -1, np.int32)
    exec_reps = np.zeros((P, n), np.int32)
    stolen = np.zeros((P,), np.int32)
    for k in range(n):
        sr, sc, h, t = (np.asarray(x) for x in step(
            jnp.asarray(head), jnp.asarray(tail), jnp.asarray(work)))
        head, tail = h.astype(np.int32), t.astype(np.int32)
        live = sr >= 0
        src_rank[:, k], src_col[:, k] = sr, sc
        exec_ids[live, k] = cids[sr[live], sc[live]]
        exec_reps[live, k] = creps[sr[live], sc[live]]
        work = work + exec_reps[:, k]
        stolen += (live & (sr != np.arange(P))
                   & (exec_ids[:, k] >= 0)).astype(np.int32)
    if coslots > 1:
        assert costride > 0, "composite replay needs the domain stride"
        slot_work = np.zeros((coslots,), np.int64)
        done = exec_ids >= 0
        np.add.at(slot_work, exec_ids[done] // costride,
                  exec_reps[done].astype(np.int64))
    else:
        slot_work = np.asarray([int(exec_reps.sum())], np.int64)
    return StealSchedule(src_rank, src_col, exec_ids, exec_reps,
                         work, stolen, slot_work)
