"""Declarative use-case protocol — what a MapReduce scenario provides.

The old API required subclassing :class:`MapReduceJob` and overriding
``map_task`` (which also had to embed the simulated-imbalance work loop).
The redesigned protocol is declarative and engine-agnostic:

  * ``window``   — dense Key-Value window size this scenario needs
                   (the paper's ``win_size``);
  * ``map_emit(tokens, task_id) -> (keys, values)``
                 — pure Map logic: emit fixed-length int32 record arrays
                   (KEY_SENTINEL marks empty slots). Keys MUST lie in
                   [0, window) — records outside the window are silently
                   dropped by the dense Key-Value fold. ``task_id`` is the
                   global task index (-1 for padding tasks), so scenarios
                   may key by position/document, not just by token;
  * ``local_reduce(keys, values)`` *(optional)*
                 — a per-task combiner applied before the engine's own
                   sort-based reduce (the paper fuses Local Reduce into
                   Map; engines always run their exact reduce regardless);
  * ``finalize(records)`` *(optional)*
                 — decode the engine's ``{key: value}`` dict into the
                   scenario's natural output (arrays, posting lists, ...).

Engines never see a ``UseCase`` — :func:`as_map_fn` adapts one into the
``map_fn(tokens, task_id, repeat)`` callable of the Backend protocol,
attaching the paper's footnote-5 imbalance model (a task is *computed*
``repeat`` times while its input is read once) uniformly for every
scenario instead of per-subclass.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax.numpy as jnp
from jax import lax

from repro.core.kv import mix32


@runtime_checkable
class UseCase(Protocol):
    window: int

    def map_emit(self, tokens: jnp.ndarray,
                 task_id: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        ...


def work_dependency(tokens: jnp.ndarray, repeat: jnp.ndarray) -> jnp.ndarray:
    """Zero-valued scalar carrying a data dependency on ``repeat``
    iterations of real per-token mixing work, so the simulated imbalance
    compute cannot be dead-code-eliminated (paper footnote 5)."""
    def body(i, acc):
        return acc ^ mix32(tokens.astype(jnp.uint32) +
                           jnp.uint32(i)).astype(jnp.int32)

    acc = lax.fori_loop(0, jnp.maximum(repeat, 1), body,
                        (tokens * 0).astype(jnp.int32))
    return (acc & 0).sum()


def _build_map_fn(usecase: UseCase):
    combiner = getattr(usecase, "local_reduce", None)

    def map_fn(tokens, task_id, repeat):
        keys, vals = usecase.map_emit(tokens, task_id)
        vals = vals + work_dependency(tokens, repeat)
        if combiner is not None:
            keys, vals = combiner(keys, vals)
        return keys, vals

    return map_fn


_MAP_FN_CACHE: dict = {}


def as_map_fn(usecase: UseCase):
    """Adapt a UseCase into the Backend protocol's
    ``map_fn(tokens, task_id, repeat) -> (keys, values)``.

    Memoized per (hashable) use-case, so re-submitting the same job hits
    the engines' jit caches instead of recompiling."""
    try:
        fn = _MAP_FN_CACHE.get(usecase)
        if fn is None:
            _MAP_FN_CACHE[usecase] = fn = _build_map_fn(usecase)
        return fn
    except TypeError:                     # unhashable custom use-case
        return _build_map_fn(usecase)


def finalize(usecase, records: dict):
    fin = getattr(usecase, "finalize", None)
    return fin(records) if fin is not None else records
