"""Window abstractions — the JAX analogue of the paper's MPI windows.

The paper allocates four windows per process: Status, Key-Value, Combine and
Displacement. On TPU these become preallocated device-resident arrays carried
through the engine's scan:

  * ``DenseWindow``   — the Key-Value window for bounded key spaces
                        (wordcount over a known vocab): a dense accumulation
                        table indexed by key. Remote "puts" land here via the
                        chunked push shuffle.
  * ``SortedWindow``  — the generic (unbounded keys) Key-Value window: a
                        log-structured sorted-run table, merged incrementally.
  * ``status``        — per-process phase/task cursor vector (observability,
                        checkpoint manifest, ownership-transfer bookkeeping).
  * fill ``counts``   — play the Displacement window's role (where the next
                        record lands per bucket).

STATUS codes mirror the paper's (e.g. ``STATUS_REDUCE``).

``EngineCarry`` — the windows as carried through an engine's scan — lives
here too, shared by every backend so the checkpoint / fault-tolerance
layers see one snapshot type regardless of engine (the MR-2S segmented
path simply leaves the in-flight ``pending_*`` buffers empty).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kv import KEY_SENTINEL

AXIS = "procs"

STATUS_INIT = 0
STATUS_MAP = 1
STATUS_REDUCE = 2
STATUS_COMBINE = 3
STATUS_DONE = 4


class DenseWindow(NamedTuple):
    """Dense Key-Value window: ``table[k]`` accumulates the value for key k
    owned by this process (non-owned slots stay 0)."""
    table: jnp.ndarray          # (vocab,) value dtype

    @staticmethod
    def alloc(vocab: int, dtype=jnp.int32) -> DenseWindow:
        return DenseWindow(jnp.zeros((vocab,), dtype))

    def put(self, keys, values) -> DenseWindow:
        """Fold a chunk of records (the receive side of a one-sided put)."""
        valid = keys != KEY_SENTINEL
        idx = jnp.where(valid, keys, 0)
        return DenseWindow(self.table.at[idx].add(jnp.where(valid, values, 0)))

    def to_records(self, my_rank, n_procs):
        """Sorted unique (key, value) records owned by this process."""
        keys = jnp.arange(self.table.shape[0], dtype=jnp.int32)
        valid = self.table != 0
        return jnp.where(valid, keys, KEY_SENTINEL), jnp.where(valid, self.table, 0)


class SortedWindow(NamedTuple):
    """Generic Key-Value window: sorted unique runs, merged on arrival."""
    keys: jnp.ndarray           # (capacity,) int32, KEY_SENTINEL padded
    values: jnp.ndarray         # (capacity,)

    @staticmethod
    def alloc(capacity: int, dtype=jnp.int32) -> SortedWindow:
        return SortedWindow(
            jnp.full((capacity,), KEY_SENTINEL, jnp.int32),
            jnp.zeros((capacity,), dtype),
        )

    def put(self, keys, values) -> SortedWindow:
        from repro.core.kv import merge_sorted
        k, v = merge_sorted(self.keys, self.values, keys, values,
                            self.keys.shape[0])
        return SortedWindow(k, v)


def status_vector(n_procs: int) -> jnp.ndarray:
    return jnp.full((n_procs,), STATUS_INIT, jnp.int32)


# ---------------------------------------------------------------------------
# the engine carry (Status + Key-Value + in-flight chunk windows)
# ---------------------------------------------------------------------------

class EngineCarry(NamedTuple):
    table: jnp.ndarray       # dense Key-Value window (vocab,)
    pending_k: jnp.ndarray   # in-flight received chunk (P, cap)
    pending_v: jnp.ndarray
    status: jnp.ndarray      # scalar per process (STATUS_*)
    cursor: jnp.ndarray      # tasks completed (restart point)
    # work-stealing claim state (core/steal.py): psum-maintained progress
    # rows, replicated on every rank. ``work`` is cumulative executed
    # compute-repeats per rank; ``stolen`` counts tasks a rank executed
    # for a peer. Engines without stealing leave both at zero; the rows
    # ride the carry so checkpoints capture mid-job claim state for free.
    work: jnp.ndarray        # (P,) int32 progress row
    stolen: jnp.ndarray      # (P,) int32 steal counters
    # cross-job co-scheduling (core/workdomain.py): executed work per
    # member job *slot*, psum-maintained like ``work``. Solo jobs carry
    # a single always-zero slot (coslots == 1 skips the update — zero
    # overhead on the solo path); a WorkDomain reads the deltas to
    # charge each tenant for work actually EXECUTED in a mixed slice.
    job_work: jnp.ndarray    # (coslots,) int32 executed work per job
    # reduce-side partitioning state (core/partition.py): the dense
    # key→owner map and per-key replica counts, replicated per rank.
    # Riding the carry (not the jitted program) means one compiled
    # engine serves every owner map, and a checkpoint snapshots the
    # map for free — restore resumes with the exact assignment that
    # produced the windows.
    owner_map: jnp.ndarray   # (vocab,) int32 key -> base owner rank
    owner_split: jnp.ndarray  # (vocab,) int32 replicas per key (>= 1)


def init_carry(spec) -> EngineCarry:
    from repro.core.kv import owner_of
    from repro.distributed.collectives import pvary
    P, cap = spec.n_procs, spec.push_cap
    return pvary(EngineCarry(
        table=jnp.zeros((spec.vocab,), jnp.int32),
        pending_k=jnp.full((P, cap), KEY_SENTINEL, jnp.int32),
        pending_v=jnp.zeros((P, cap), jnp.int32),
        status=jnp.int32(STATUS_MAP),
        cursor=jnp.int32(0),
        work=jnp.zeros((P,), jnp.int32),
        stolen=jnp.zeros((P,), jnp.int32),
        job_work=jnp.zeros((getattr(spec, "coslots", 1) or 1,),
                           jnp.int32),
        # the hash rule as a dense map — bit-identical to owner_of, and
        # the seed a skew-aware partitioner overwrites before step 0
        owner_map=owner_of(jnp.arange(spec.vocab, dtype=jnp.int32), P),
        owner_split=jnp.ones((spec.vocab,), jnp.int32),
    ), AXIS)


def combine_records(table: jnp.ndarray, spec):
    """Window -> sorted records entering the Combine tree, honoring
    ``spec.combine_capacity`` identically in every backend and mode.

    Returns ``(keys, vals, overflow)``: ``overflow`` counts the records
    this rank *lost* squeezing its window into the Combine width W (0
    whenever W covers the window — truncation is never silent)."""
    from repro.core.kv import local_reduce
    keys, vals = DenseWindow(table).to_records(None, spec.n_procs)
    W = spec.combine_capacity
    overflow = jnp.int32(0)
    if W != keys.shape[0]:
        keys, vals, n_unique = local_reduce(keys, vals, W)
        overflow = jnp.maximum(n_unique.astype(jnp.int32) - W, 0)
    return keys, vals, overflow


def wrap_segment_fns(mesh, spec, seg_body, fin_body):
    """Lift per-shard segment bodies into jitted shard_map fns.

    ``seg_body(carry, tok, tid, rep)`` and ``fin_body(carry)`` operate on
    the un-sharded (per-device) view; the returned
    ``(init_fn, segment_fn, finish_fn)`` operate on host arrays with a
    leading shard dimension — the shape every backend's segmented path
    shares, so the ckpt/ft layers are backend-agnostic.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.collectives import shard_map
    spec_p = P(AXIS)
    carry_specs = EngineCarry(*([spec_p] * len(EngineCarry._fields)))

    def init():
        c = init_carry(spec)
        # broadcast per-shard carry: every leaf gains a leading shard dim
        return jax.tree.map(lambda x: x[None], c)

    seg_sm = jax.jit(shard_map(
        lambda c, t, i, r: jax.tree.map(
            lambda x: x[None],
            seg_body(jax.tree.map(lambda x: x[0], c), t[0], i[0], r[0])),
        mesh=mesh, in_specs=(carry_specs, spec_p, spec_p, spec_p),
        out_specs=carry_specs))
    fin_sm = jax.jit(shard_map(
        lambda c: tuple(
            x[None] for x in fin_body(jax.tree.map(lambda x: x[0], c))),
        mesh=mesh, in_specs=(carry_specs,),
        out_specs=(spec_p, spec_p, spec_p)))
    init_sm = jax.jit(shard_map(
        lambda: init(), mesh=mesh, in_specs=(), out_specs=carry_specs))
    return init_sm, seg_sm, fin_sm
