"""Window abstractions — the JAX analogue of the paper's MPI windows.

The paper allocates four windows per process: Status, Key-Value, Combine and
Displacement. On TPU these become preallocated device-resident arrays carried
through the engine's scan:

  * ``DenseWindow``   — the Key-Value window for bounded key spaces
                        (wordcount over a known vocab): a dense accumulation
                        table indexed by key. Remote "puts" land here via the
                        chunked push shuffle.
  * ``SortedWindow``  — the generic (unbounded keys) Key-Value window: a
                        log-structured sorted-run table, merged incrementally.
  * ``status``        — per-process phase/task cursor vector (observability,
                        checkpoint manifest, ownership-transfer bookkeeping).
  * fill ``counts``   — play the Displacement window's role (where the next
                        record lands per bucket).

STATUS codes mirror the paper's (e.g. ``STATUS_REDUCE``).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.kv import KEY_SENTINEL

STATUS_INIT = 0
STATUS_MAP = 1
STATUS_REDUCE = 2
STATUS_COMBINE = 3
STATUS_DONE = 4


class DenseWindow(NamedTuple):
    """Dense Key-Value window: ``table[k]`` accumulates the value for key k
    owned by this process (non-owned slots stay 0)."""
    table: jnp.ndarray          # (vocab,) value dtype

    @staticmethod
    def alloc(vocab: int, dtype=jnp.int32) -> "DenseWindow":
        return DenseWindow(jnp.zeros((vocab,), dtype))

    def put(self, keys, values) -> "DenseWindow":
        """Fold a chunk of records (the receive side of a one-sided put)."""
        valid = keys != KEY_SENTINEL
        idx = jnp.where(valid, keys, 0)
        return DenseWindow(self.table.at[idx].add(jnp.where(valid, values, 0)))

    def to_records(self, my_rank, n_procs):
        """Sorted unique (key, value) records owned by this process."""
        keys = jnp.arange(self.table.shape[0], dtype=jnp.int32)
        valid = self.table != 0
        return jnp.where(valid, keys, KEY_SENTINEL), jnp.where(valid, self.table, 0)


class SortedWindow(NamedTuple):
    """Generic Key-Value window: sorted unique runs, merged on arrival."""
    keys: jnp.ndarray           # (capacity,) int32, KEY_SENTINEL padded
    values: jnp.ndarray         # (capacity,)

    @staticmethod
    def alloc(capacity: int, dtype=jnp.int32) -> "SortedWindow":
        return SortedWindow(
            jnp.full((capacity,), KEY_SENTINEL, jnp.int32),
            jnp.zeros((capacity,), dtype),
        )

    def put(self, keys, values) -> "SortedWindow":
        from repro.core.kv import merge_sorted
        k, v = merge_sorted(self.keys, self.values, keys, values,
                            self.keys.shape[0])
        return SortedWindow(k, v)


def status_vector(n_procs: int) -> jnp.ndarray:
    return jnp.full((n_procs,), STATUS_INIT, jnp.int32)
