"""MapReduce-2S — the bulk-synchronous reference (Hoefler et al. [7]).

Same Map / Local Reduce / mapping / bucket memory management as MR-1S (the
paper keeps these identical on purpose), but:

  * all Map tasks complete first, buffering *every* task's buckets
    (this is why its memory footprint scales with total map output — Fig 6);
  * one bulk all_to_all (MPI_Alltoallv analogue) shuffles everything after
    the implicit barrier;
  * Reduce runs as one post-shuffle spike;
  * the Combine tree is shared with MR-1S (point-to-point in the paper; the
    ppermute tree is the faithful analogue of both variants on TPU).

Master-slave MPI_Scatter task distribution maps to the initial sharded
device_put of the task grid (the host "master" owns placement).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.api import JobSpec
from repro.core.combine import tree_combine
from repro.core.kv import (KEY_SENTINEL, bucketize, local_reduce,
                           local_reduce_repeated)
from repro.core.windows import DenseWindow
from repro.distributed.collectives import all_to_all_blocks

AXIS = "procs"


def _engine(spec: JobSpec, map_fn: Callable, tokens, repeats):
    tokens, repeats = tokens[0], repeats[0]
    P, cap = spec.n_procs, spec.push_cap
    T = tokens.shape[0]

    # ---- Map phase (all tasks; buckets buffered, nothing sent yet) --------
    def map_one(_, xs):
        task, rep = xs
        keys, vals = map_fn(task, rep)
        # same repeated task compute as MR-1S (the engines share the Map /
        # Local Reduce mechanics by design — paper §2.2.1)
        uk, uv = local_reduce_repeated(keys, vals, keys.shape[0], rep)
        bk, bv, counts, (ofk, ofv) = bucketize(uk, uv, P, cap)
        return None, (bk, bv, ofk, ofv)

    _, (BK, BV, OFK, OFV) = lax.scan(map_one, None, (tokens, repeats))
    # (T, P, cap) -> (P, T*cap): the full send buffer (the 2S memory spike)
    BK = jnp.swapaxes(BK, 0, 1).reshape(P, T * cap)
    BV = jnp.swapaxes(BV, 0, 1).reshape(P, T * cap)

    # ---- barrier + bulk shuffle (MPI_Alltoallv) ---------------------------
    RK = all_to_all_blocks(BK, AXIS)
    RV = all_to_all_blocks(BV, AXIS)

    # ---- Reduce (post-shuffle spike) --------------------------------------
    win = DenseWindow(jnp.zeros((spec.vocab,), jnp.int32))
    win = win.put(RK.reshape(-1), RV.reshape(-1))
    win = win.put(OFK.reshape(-1), OFV.reshape(-1))   # overflow kept local

    # ---- Combine ----------------------------------------------------------
    keys, vals = win.to_records(None, P)
    keys, vals = tree_combine(keys, vals, AXIS, P)
    return keys[None], vals[None]


def run_job(spec: JobSpec, map_fn: Callable, mesh, tokens, repeats):
    from jax.sharding import PartitionSpec as P
    fn = jax.jit(jax.shard_map(
        partial(_engine, spec, map_fn), mesh=mesh,
        in_specs=(P(AXIS), P(AXIS)), out_specs=(P(AXIS), P(AXIS))))
    keys, vals = fn(tokens, repeats)
    return jax.device_get(keys)[0], jax.device_get(vals)[0]
