"""MapReduce-2S — the bulk-synchronous reference (Hoefler et al. [7]).

Same Map / Local Reduce / mapping / bucket memory management as MR-1S (the
paper keeps these identical on purpose), but:

  * all Map tasks complete first, buffering *every* task's buckets
    (this is why its memory footprint scales with total map output — Fig 6);
  * one bulk all_to_all (MPI_Alltoallv analogue) shuffles everything after
    the implicit barrier;
  * Reduce runs as one post-shuffle spike;
  * the Combine tree is shared with MR-1S (point-to-point in the paper; the
    ppermute tree is the faithful analogue of both variants on TPU).

Master-slave MPI_Scatter task distribution maps to the initial sharded
device_put of the task grid (the host "master" owns placement).

Registered as backend ``"2s"`` (:mod:`repro.core.registry`). Through the
shared Backend protocol it also exposes a segmented path: between two
window syncs the engine is classically bulk-synchronous *over that
segment* (map-all, barrier, bulk shuffle, reduce spike), and the dense
Key-Value window carried across segments is what the checkpoint layer
snapshots — the same :class:`~repro.core.windows.EngineCarry` type as
MR-1S, with the in-flight ``pending_*`` buffers simply left empty.
"""
from __future__ import annotations

from functools import partial
from collections.abc import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.combine import tree_combine
from repro.core.kv import local_reduce_repeated, bucketize
from repro.core.partition import lookup_owner
from repro.core.registry import JobSpec, memoized, register_backend
from repro.core.windows import (AXIS, DenseWindow, combine_records,
                                init_carry, wrap_segment_fns)
from repro.distributed.collectives import all_to_all_blocks, shard_map


def _map_all(spec: JobSpec, map_fn: Callable, tokens, task_ids, repeats,
             owner_map, owner_split):
    """The bulk Map phase over a task grid: every task's buckets are
    buffered before anything is sent (the 2S memory spike)."""
    P, cap = spec.n_procs, spec.push_cap
    T = tokens.shape[0]

    def map_one(_, xs):
        task, tid, rep = xs
        keys, vals = map_fn(task, tid, rep)
        # same repeated task compute as MR-1S (the engines share the Map /
        # Local Reduce mechanics by design — paper §2.2.1)
        uk, uv = local_reduce_repeated(keys, vals, keys.shape[0], rep)
        owners = lookup_owner(owner_map, owner_split, uk, tid, P)
        bk, bv, counts, (ofk, ofv) = bucketize(uk, uv, P, cap,
                                               owners=owners)
        return None, (bk, bv, ofk, ofv)

    _, (BK, BV, OFK, OFV) = lax.scan(map_one, None,
                                     (tokens, task_ids, repeats))
    # (T, P, cap) -> (P, T*cap): the full send buffer
    BK = jnp.swapaxes(BK, 0, 1).reshape(P, T * cap)
    BV = jnp.swapaxes(BV, 0, 1).reshape(P, T * cap)
    return BK, BV, OFK, OFV


def _shuffle_reduce(win: DenseWindow, BK, BV, OFK, OFV) -> DenseWindow:
    """Barrier + bulk shuffle (MPI_Alltoallv), then the Reduce spike."""
    RK = all_to_all_blocks(BK, AXIS)
    RV = all_to_all_blocks(BV, AXIS)
    win = win.put(RK.reshape(-1), RV.reshape(-1))
    return win.put(OFK.reshape(-1), OFV.reshape(-1))  # overflow kept local


def _engine(spec: JobSpec, map_fn: Callable, tokens, task_ids, repeats):
    from repro.core.kv import owner_of
    tokens, task_ids, repeats = tokens[0], task_ids[0], repeats[0]
    # legacy blocking path: always the hash rule (the Job API's segmented
    # path carries skew-aware maps in the EngineCarry)
    omap = owner_of(jnp.arange(spec.vocab, dtype=jnp.int32), spec.n_procs)
    osplit = jnp.ones((spec.vocab,), jnp.int32)
    BK, BV, OFK, OFV = _map_all(spec, map_fn, tokens, task_ids, repeats,
                                omap, osplit)
    win = DenseWindow(jnp.zeros((spec.vocab,), jnp.int32))
    win = _shuffle_reduce(win, BK, BV, OFK, OFV)
    # ---- Combine ----------------------------------------------------------
    keys, vals, overflow = combine_records(win.table, spec)
    keys, vals, _ = tree_combine(keys, vals, AXIS, spec.n_procs, overflow)
    return keys[None], vals[None]


@register_backend("2s")
class TwoSidedBackend:
    """The bulk-synchronous engine behind the ``Backend`` protocol."""

    def __init__(self):
        self._programs: dict = {}

    def run_job(self, spec: JobSpec, map_fn: Callable, mesh, tokens,
                task_ids, repeats):
        from jax.sharding import PartitionSpec as P
        fn = memoized(
            self._programs, ("run", spec, map_fn, mesh),
            lambda: jax.jit(shard_map(
                partial(_engine, spec, map_fn), mesh=mesh,
                in_specs=(P(AXIS), P(AXIS), P(AXIS)),
                out_specs=(P(AXIS), P(AXIS)))))
        keys, vals = fn(tokens, task_ids, repeats)
        return jax.device_get(keys)[0], jax.device_get(vals)[0]

    def trace_handles(self, spec: JobSpec, map_fn: Callable, mesh,
                      seg_tasks: int = 2, tag: str = ""):
        """Traceable :class:`~repro.core.registry.ProgramHandle`\\ s for
        fleetlint (repro.analysis)."""
        from repro.core.registry import segment_program_handles
        return segment_program_handles(self, spec, map_fn, mesh,
                                       seg_tasks=seg_tasks, tag=tag)

    def make_segment_fns(self, spec: JobSpec, map_fn: Callable, mesh):
        """Segmented 2S: each segment runs bulk-synchronously (map-all,
        bulk shuffle, reduce spike) and folds into the carried window —
        the window sync point the checkpoint layer snapshots."""
        return memoized(self._programs, ("seg", spec, map_fn, mesh),
                        lambda: self._build_segment_fns(spec, map_fn, mesh))

    def _build_segment_fns(self, spec: JobSpec, map_fn: Callable, mesh):
        if spec.coslots > 1:
            # no supports_coschedule: the bulk path never learned to
            # route composite keys — reject instead of mis-reducing
            raise ValueError(
                "backend '2s' does not support cross-job co-scheduling "
                "(coslots > 1) — WorkDomains form over '1s' only")

        def seg(carry, tok, tid, rep):
            BK, BV, OFK, OFV = _map_all(spec, map_fn, tok, tid, rep,
                                        carry.owner_map, carry.owner_split)
            win = _shuffle_reduce(DenseWindow(carry.table), BK, BV,
                                  OFK, OFV)
            return carry._replace(table=win.table,
                                  cursor=carry.cursor + tok.shape[0])

        def fin(carry):
            keys, vals, overflow = combine_records(carry.table, spec)
            return tree_combine(keys, vals, AXIS, spec.n_procs, overflow)

        return wrap_segment_fns(mesh, spec, seg, fin)


# -- module-level aliases (pre-registry call sites) -------------------------

def run_job(spec, map_fn, mesh, tokens, task_ids, repeats):
    from repro.core.registry import get_backend
    return get_backend("2s").run_job(spec, map_fn, mesh, tokens, task_ids,
                                     repeats)


def make_segment_fns(spec, map_fn, mesh):
    from repro.core.registry import get_backend
    return get_backend("2s").make_segment_fns(spec, map_fn, mesh)
