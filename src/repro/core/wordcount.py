"""Word-Count use case (paper §3.1, PUMA benchmark).

Map emits <word, 1>; Reduce sums occurrences; Combine produces the sorted
<word, count> result. Words arrive as token ids from data/tokenizer.py.

Imbalance is simulated the way the paper does it (footnote 5): a task is
*computed* ``repeat`` times while its input is read once — the repeat loop
re-derives a value from the tokens each iteration so the work is real, but
the emitted count stays 1 per occurrence (results remain exact).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.api import MapReduceJob
from repro.core.kv import KEY_SENTINEL, mix32


class WordCount(MapReduceJob):

    def map_task(self, toks: jnp.ndarray, repeat: jnp.ndarray):
        def body(i, acc):
            return acc ^ mix32(toks.astype(jnp.uint32) +
                               jnp.uint32(i)).astype(jnp.int32)

        acc = lax.fori_loop(0, jnp.maximum(repeat, 1), body,
                            (toks * 0).astype(jnp.int32))
        valid = toks != KEY_SENTINEL
        # keep a (zero-valued) data dependency on the repeat loop so the
        # simulated work cannot be dead-code-eliminated
        vals = jnp.where(valid, 1, 0) + (acc & 0)
        return toks, vals


def wordcount_oracle(tokens, vocab: int):
    """numpy reference for tests: exact counts over the whole input."""
    import numpy as np
    tokens = np.asarray(tokens)
    tokens = tokens[tokens != int(KEY_SENTINEL)]
    counts = np.bincount(tokens, minlength=vocab)
    keys = np.nonzero(counts)[0]
    return {int(k): int(counts[k]) for k in keys}
