"""Word-Count use case (paper §3.1, PUMA benchmark) — legacy module.

The declarative version lives in :mod:`repro.core.usecases` (class
``WordCount`` with ``map_emit``); this module keeps the deprecated
subclass-style job for one release plus the oracle re-export, so old
imports (``from repro.core.wordcount import WordCount,
wordcount_oracle``) keep working.

Imbalance is simulated the way the paper does it (footnote 5): a task is
*computed* ``repeat`` times while its input is read once — the repeat loop
re-derives a value from the tokens each iteration so the work is real, but
the emitted count stays 1 per occurrence (results remain exact).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.api import MapReduceJob
from repro.core.kv import KEY_SENTINEL, mix32
from repro.core.usecases import wordcount_oracle  # noqa: F401  (re-export)


class WordCount(MapReduceJob):
    """Deprecated: use ``repro.core.usecases.WordCount`` with
    ``repro.core.submit`` instead."""

    def map_task(self, toks: jnp.ndarray, repeat: jnp.ndarray):
        def body(i, acc):
            return acc ^ mix32(toks.astype(jnp.uint32) +
                               jnp.uint32(i)).astype(jnp.int32)

        acc = lax.fori_loop(0, jnp.maximum(repeat, 1), body,
                            (toks * 0).astype(jnp.int32))
        valid = toks != KEY_SENTINEL
        # keep a (zero-valued) data dependency on the repeat loop so the
        # simulated work cannot be dead-code-eliminated
        vals = jnp.where(valid, 1, 0) + (acc & 0)
        return toks, vals
