"""Built-in scenarios for the unified Job API, each with a numpy oracle.

Three use-cases demonstrate the protocol's range on the same engines:

  * :class:`WordCount`     — the paper's §3.1 PUMA benchmark: <token, 1>.
  * :class:`Histogram`     — bin token ids into B buckets: <bin, 1> (a
                             different key space than the emit domain).
  * :class:`InvertedIndex` — grep-style posting lists with term
                             frequencies: for a query set Q and documents
                             made of consecutive tasks, emit
                             <doc·|Q|+q, 1> — a positional scenario only
                             possible now that ``map_emit`` sees the
                             global task id.

All values are additive (the engines' Reduce is an exact keyed sum), so
every scenario is oracle-exact on both the ``"1s"`` and ``"2s"``
backends, balanced or not.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.kv import KEY_SENTINEL


# ---------------------------------------------------------------------------
# WordCount
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class WordCount:
    """<token, 1>: counts occurrences of each token id."""
    vocab: int

    @property
    def window(self) -> int:
        return self.vocab

    def map_emit(self, tokens, task_id):
        valid = tokens != KEY_SENTINEL
        return tokens, jnp.where(valid, 1, 0).astype(jnp.int32)


def wordcount_oracle(tokens, vocab: int) -> dict[int, int]:
    """numpy reference: exact counts over the whole input."""
    tokens = np.asarray(tokens)
    tokens = tokens[tokens != int(KEY_SENTINEL)]
    counts = np.bincount(tokens, minlength=vocab)
    keys = np.nonzero(counts)[0]
    return {int(k): int(counts[k]) for k in keys}


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Histogram:
    """<bin, 1>: equal-width histogram of token ids over [0, vocab)."""
    vocab: int
    n_bins: int

    @property
    def window(self) -> int:
        return self.n_bins

    def __post_init__(self):
        # bin mapping is computed in int32 (x64 may be disabled)
        assert self.vocab * self.n_bins < 2 ** 31, "vocab*n_bins overflows"

    def map_emit(self, tokens, task_id):
        valid = tokens != KEY_SENTINEL
        bins = jnp.where(valid, tokens, 0) * self.n_bins // self.vocab
        keys = jnp.where(valid, bins, KEY_SENTINEL)
        return keys, jnp.where(valid, 1, 0).astype(jnp.int32)

    def finalize(self, records: dict[int, int]) -> np.ndarray:
        out = np.zeros((self.n_bins,), np.int64)
        for b, c in records.items():
            out[b] = c
        return out


def histogram_oracle(tokens, vocab: int, n_bins: int) -> np.ndarray:
    tokens = np.asarray(tokens)
    tokens = tokens[tokens != int(KEY_SENTINEL)]
    bins = tokens.astype(np.int64) * n_bins // vocab
    return np.bincount(bins, minlength=n_bins).astype(np.int64)


# ---------------------------------------------------------------------------
# InvertedIndex (grep with term frequencies)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InvertedIndex:
    """Posting lists for a query set: key = doc · |Q| + query_index.

    A "document" is ``tasks_per_doc`` consecutive Map tasks — derived
    from the global ``task_id``, which is why this scenario needs the
    redesigned ``map_emit(tokens, task_id)`` signature.
    """
    queries: tuple          # token ids to index (hashable for dataclass)
    n_docs: int
    tasks_per_doc: int

    @property
    def window(self) -> int:
        return self.n_docs * len(self.queries)

    def map_emit(self, tokens, task_id):
        q = jnp.asarray(self.queries, jnp.int32)            # (Q,)
        eq = tokens[:, None] == q[None, :]                  # (S, Q)
        qidx = jnp.argmax(eq, axis=1).astype(jnp.int32)
        hit = eq.any(axis=1) & (tokens != KEY_SENTINEL) & (task_id >= 0)
        doc = jnp.clip(task_id // self.tasks_per_doc, 0, self.n_docs - 1)
        keys = jnp.where(hit, doc * len(self.queries) + qidx, KEY_SENTINEL)
        return keys.astype(jnp.int32), jnp.where(hit, 1, 0).astype(jnp.int32)

    def finalize(self, records: dict[int, int]) -> dict[int, dict[int, int]]:
        """{query_token: {doc: term_frequency}} — sparse posting lists."""
        out: dict[int, dict[int, int]] = {int(t): {} for t in self.queries}
        Q = len(self.queries)
        for k, v in records.items():
            doc, qidx = divmod(int(k), Q)
            out[int(self.queries[qidx])][doc] = int(v)
        return out


def inverted_index_oracle(tokens, queries, task_size: int,
                          tasks_per_doc: int, n_docs: int):
    """numpy reference mirroring the planner's task slicing."""
    tokens = np.asarray(tokens)
    out = {int(t): {} for t in queries}
    n_tasks = (len(tokens) + task_size - 1) // task_size
    for t in range(n_tasks):
        doc = min(t // tasks_per_doc, n_docs - 1)
        chunk = tokens[t * task_size: (t + 1) * task_size]
        chunk = chunk[chunk != int(KEY_SENTINEL)]
        for q in queries:
            n = int((chunk == q).sum())
            if n:
                d = out[int(q)]
                d[doc] = d.get(doc, 0) + n
    return out
