# The paper's primary contribution: the decoupled (one-sided) MapReduce
# engine and its bulk-synchronous reference, behind the unified Job API —
# pluggable backends (registry), declarative use-cases, and a streaming
# JobHandle lifecycle.
from repro.core.job import JobConfig, JobHandle, JobResult, submit
from repro.core.registry import (Backend, JobSpec, UnknownBackendError,
                                 available_backends, get_backend,
                                 register_backend)
from repro.core.usecase import UseCase, as_map_fn
from repro.core.usecases import (Histogram, InvertedIndex, WordCount,
                                 histogram_oracle, inverted_index_oracle,
                                 wordcount_oracle)
# deprecated class-based API (one-release migration shim)
from repro.core.api import MapReduceJob
