# The paper's primary contribution: the decoupled (one-sided) MapReduce
# engine and its bulk-synchronous reference, as composable JAX modules.
from repro.core.api import JobSpec, MapReduceJob
from repro.core.wordcount import WordCount, wordcount_oracle
