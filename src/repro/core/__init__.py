# The paper's primary contribution: the decoupled (one-sided) MapReduce
# engine and its bulk-synchronous reference, behind the unified Job API —
# pluggable backends (registry), declarative use-cases, and a streaming
# JobHandle lifecycle.
from repro.core.job import (CombineOverflowError, JobConfig, JobHandle,
                            JobResult, submit)
from repro.core.partition import (HashPartitioner, Partitioner,
                                  SampledPartitioner,
                                  available_partitioners,
                                  resolve_partitioner)
from repro.core.registry import (Backend, JobSpec, UnknownBackendError,
                                 available_backends, get_backend,
                                 register_backend)
from repro.core.scheduler import (AdmissionQueueFull, FairSharePolicy,
                                  FifoPolicy, JobScheduler, PriorityPolicy,
                                  SchedulePolicy, TenantStats,
                                  available_policies, resolve_policy)
from repro.core.usecase import UseCase, as_map_fn
from repro.core.usecases import (Histogram, InvertedIndex, WordCount,
                                 histogram_oracle, inverted_index_oracle,
                                 wordcount_oracle)
from repro.core.workdomain import WorkDomain, can_coschedule
