"""Key-value record machinery: hashing, ownership, sort-based local reduce.

The paper encodes variable-length ``<h|key|value>`` records and owns each key
by a 64-bit hash. On TPU we keep fixed-width int32 records (variable-length
keys are resolved to ids by the ingest tokenizer — see DESIGN.md §2.1) and a
bijective 32-bit mixing hash (Murmur3-style finalizer) for ownership, which
preserves the paper's "uniformly spread keys across owners" property.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

KEY_SENTINEL = jnp.iinfo(jnp.int32).max  # marks an empty / invalid record


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 fmix32 — bijective on uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def owner_of(keys: jnp.ndarray, n_procs: int) -> jnp.ndarray:
    """hash(key) % P — the paper's ownership rule."""
    return (mix32(keys) % jnp.uint32(n_procs)).astype(jnp.int32)


def local_reduce(keys: jnp.ndarray, values: jnp.ndarray, capacity: int):
    """Paper phase II (Local Reduce): aggregate duplicate keys.

    Sorts by key and segment-sums, returning ``capacity`` records
    (key ascending, KEY_SENTINEL padding). Pure jnp oracle for the
    wordcount_hash kernel and the generic (unbounded-key) engine path.
    """
    order = jnp.argsort(keys)
    sk = keys[order]
    sv = values[order]
    valid = sk != KEY_SENTINEL
    # head of each run of equal keys
    head = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]]) & valid
    seg = jnp.cumsum(head) - 1                      # segment id per element
    # ghost slot ``capacity`` for invalid / non-head writes, so slot
    # capacity-1 is never clobbered when n_unique == capacity
    seg = jnp.where(valid, seg, capacity)
    sums = jnp.zeros((capacity + 1,), values.dtype).at[seg].add(
        jnp.where(valid, sv, 0))
    uk = jnp.full((capacity + 1,), KEY_SENTINEL, keys.dtype).at[
        jnp.where(head, seg, capacity)
    ].set(jnp.where(head, sk, KEY_SENTINEL))
    n_unique = jnp.sum(head)
    idx = jnp.arange(capacity)
    uk = jnp.where(idx < n_unique, uk[:capacity], KEY_SENTINEL)
    sums = jnp.where(idx < n_unique, sums[:capacity], 0)
    return uk, sums, n_unique


def local_reduce_repeated(keys, vals, capacity: int, rep):
    """Paper footnote 5 imbalance model: the task is *computed* ``rep``
    times while its input is read once; the result is identical for any
    rep >= 1.

    Each extra repetition re-runs a full local_reduce (the task's compute)
    seeded with a value-preserving dependency on the previous iteration —
    ``uv < 0`` is never true in value but XLA cannot prove it, so the loop
    body can be neither CSE'd nor dead-code-eliminated."""
    uk0, uv0, _ = local_reduce(keys, vals, capacity)

    def body(i, carry):
        uk, uv = carry
        k_dep = jnp.where(uv < 0, uk, KEY_SENTINEL)
        v_dep = jnp.where(uv < 0, uv, 0)
        uk2, uv2, _ = local_reduce(jnp.concatenate([keys, k_dep]),
                                   jnp.concatenate([vals, v_dep]), capacity)
        return uk2, uv2

    return lax.fori_loop(1, jnp.maximum(rep, 1), body, (uk0, uv0))


def merge_sorted(keys_a, vals_a, keys_b, vals_b, capacity: int):
    """Merge two key-ascending unique record arrays, summing duplicates."""
    k = jnp.concatenate([keys_a, keys_b])
    v = jnp.concatenate([vals_a, vals_b])
    return local_reduce(k, v, capacity)[:2]


def bucketize(keys, values, n_procs: int, cap: int, owners=None):
    """Scatter records into per-owner buckets — the paper's one-sided put
    target layout: (P, cap) records + per-owner fill counts.

    ``owners`` overrides the default ``hash(key) % P`` rule with a
    precomputed per-record owner vector (values in [0, n_procs]; the
    skew-aware maps of :mod:`repro.core.partition` resolve it from the
    carried owner map). Records beyond ``cap`` for a hot owner are
    *dropped from the push* and reported in ``overflow`` so the caller
    can retain them locally (the paper's ownership-transfer semantics,
    footnote 2).
    """
    if owners is None:
        owners = owner_of(keys, n_procs)
    valid = keys != KEY_SENTINEL
    owners = jnp.where(valid, owners, n_procs)      # invalid -> ghost bucket
    order = jnp.argsort(owners, stable=True)
    so, sk, sv = owners[order], keys[order], values[order]
    # position within its bucket
    one = jnp.ones_like(so)
    pos_in_owner = jnp.cumsum(one) - 1
    start = jnp.searchsorted(so, jnp.arange(n_procs + 1))
    pos = pos_in_owner - start[jnp.clip(so, 0, n_procs)]
    counts = jnp.minimum(start[1:] - start[:-1], cap)[:n_procs]
    in_cap = (pos < cap) & (so < n_procs)
    flat_idx = jnp.where(in_cap, so * cap + pos, n_procs * cap)
    bk = jnp.full((n_procs * cap + 1,), KEY_SENTINEL, keys.dtype).at[flat_idx].set(
        jnp.where(in_cap, sk, KEY_SENTINEL)
    )[:-1].reshape(n_procs, cap)
    bv = jnp.zeros((n_procs * cap + 1,), values.dtype).at[flat_idx].set(
        jnp.where(in_cap, sv, 0)
    )[:-1].reshape(n_procs, cap)
    overflow_k = jnp.where(in_cap | (so >= n_procs), KEY_SENTINEL, sk)
    overflow_v = jnp.where(in_cap | (so >= n_procs), 0, sv)
    return bk, bv, counts, (overflow_k, overflow_v)
