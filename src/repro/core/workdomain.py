"""WorkDomain — cross-job operation-level co-scheduling (OS4M direction).

The decoupled engine stops idling *ranks* inside a job (core/steal.py);
this module stops idling them at the job boundary: K admitted jobs that
share one compiled program (asserted at admission since the scheduler
landed) merge into ONE composite engine program, so a rank drained by
job A's tail executes job B's tasks *in the same device step* — global
work stealing at operation granularity, per OS4M (arXiv:1406.3901).

The merge is an encoding, not new engine machinery:

  * **composite task ids** — member job ``j``'s task ``t`` becomes
    ``j * costride + t`` (:func:`repro.core.steal.fleet_merge` lays the
    members' columns into one fleet grid, priority lanes first,
    round-robin within a lane — the shared cursor every rank's claims
    draw from). A :class:`~repro.data.source.FleetSource` places member
    ``j``'s bytes at element ``j * costride * task_size``, so the
    ordinary ``plan.file_offset`` addresses any member's task — the
    feed, the prefetcher and the engine's steal fetch all serve
    cross-job reads unchanged.
  * **composite keys** — the engine offsets every emitted key by
    ``slot * (vocab // coslots)`` into the owning job's disjoint window
    slice (``repro.core.onesided._step``), so bucketize/combine/fold
    route each record to its job's windows and per-job dup-sum
    exactness follows from the solo argument, window by window. Every
    member's records are bit-identical to its solo run, wherever
    stealing executed its tasks.
  * **executed-work row** — ``carry.job_work`` (one psum-maintained
    slot per member) tells the scheduler how much of each tenant's work
    actually ran in a mixed slice, so fair share charges execution, not
    assignment.

Why the domain can beat K solo-sliced jobs: a solo segment of width 1
has one task per rank — nothing to steal inside the step. The domain
packs ``pack`` members' columns into each segment, so the in-scan claim
function balances across job boundaries at task granularity; under
imbalanced per-job tails the merged segment's makespan approaches the
mean load instead of the max (benchmarks/fig14_crossjob.py).

Members finalize independently: as soon as the shared cursor has
consumed all of member ``j``'s columns, the (pure) finish program runs
on the current carry, the composite records are split by key range and
the member's :class:`~repro.core.job.JobResult` is adopted by its
handle — a short job co-scheduled with a long one still finishes early.
``work_per_rank`` on a member result reports its *assigned* per-rank
work (per-member×per-rank execution is intentionally not tracked — the
domain-level split lives on the domain handle's carry rows).

Eligibility (:func:`can_coschedule`): segmented '1s' jobs sharing
(backend, JobSpec, map_fn) with a non-sampling partitioner and no
fused_map — the fused kernel resolves owners in-kernel over the solo
key space, so fused jobs cleanly fall back to solo slicing.

Checkpoint/restore: the domain checkpoints ONCE through the ordinary
:meth:`~repro.core.job.JobHandle.checkpoint` — the snapshot carries the
composite carry plus the shared fleet cursor and merged grids, so a
mid-co-schedule restore resumes record-identically. The scheduler
records domain membership in the fleet manifest and re-forms domains
deterministically before restoring them.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import steal
from repro.core.job import JobHandle, JobResult
from repro.core.kv import KEY_SENTINEL
from repro.core.planner import TaskPlan
from repro.core.usecase import finalize
from repro.core.windows import AXIS
from repro.data.feed import SegmentFeed
from repro.data.source import FleetSource


def coschedule_key(handle: JobHandle) -> tuple:
    """Program-compatibility key: jobs sharing it can merge into one
    WorkDomain (the same key the scheduler's jit-memo assert uses)."""
    return (handle.backend.name, handle.spec, id(handle._map_fn))


def can_coschedule(handle: JobHandle) -> bool:
    """Whether this job may join a WorkDomain. Fused/coded jobs and
    sampling partitioners cleanly reject (solo slicing instead): the
    fused kernel has no composite-key path, the coded exchange's r-group
    decode has no fleet-cursor claim granularity, and a sampled owner
    map is built per-job over the solo key space."""
    spec = handle.spec
    return (getattr(handle.backend, "supports_coschedule", False)
            and spec.coslots == 1
            and not spec.fused_map
            and spec.code_rate == 1
            and not handle.partitioner.needs_sample
            and handle.config.segment > 0
            and handle.cursor == 0
            and handle._carry is None
            and handle._result is None)


class WorkDomain:
    """K program-compatible jobs fused into one co-scheduled engine run.

    ``handles`` must all satisfy :func:`can_coschedule` and share
    :func:`coschedule_key`. ``pack`` is how many member segments one
    domain segment packs (default: K — every live member contributes a
    column per step); ``stride`` overrides the computed task-id stride
    (checkpoint re-formation passes the recorded one).
    """

    def __init__(self, handles: list[JobHandle], *, names=None,
                 priorities=None, mesh=None, pack: int | None = None,
                 stride: int | None = None, feed_budget=None):
        if len(handles) < 2:
            raise ValueError("a WorkDomain needs at least two member "
                             "jobs (one job co-schedules with nobody)")
        key0 = coschedule_key(handles[0])
        for h in handles:
            if not can_coschedule(h):
                raise ValueError(
                    "job is not co-schedulable (backend without "
                    "supports_coschedule, fused_map, code_rate > 1, "
                    "sampling partitioner, oneshot, or already started)")
            if coschedule_key(h) != key0:
                raise ValueError(
                    "WorkDomain members must share one compiled program "
                    f"(backend, JobSpec, use-case): {coschedule_key(h)} "
                    f"!= {key0}")
        self.members = list(handles)
        self.names = (list(names) if names is not None
                      else [f"member-{j}" for j in range(len(handles))])
        assert len(self.names) == len(self.members)
        self.priorities = (list(priorities) if priorities is not None
                           else [0] * len(self.members))
        self.K = len(self.members)
        spec0 = self.members[0].spec
        cfg0 = self.members[0].config
        need = max(h.plan.n_tasks for h in self.members)
        self.stride = int(stride) if stride is not None else need
        if self.stride < need:
            raise ValueError(f"stride {self.stride} < widest member "
                             f"({need} tasks)")
        self.pack = int(pack) if pack else self.K
        self.mesh = mesh if mesh is not None else self.members[0].mesh

        # the composite program: K disjoint window slices, pack-wide
        # segments (a solo segment of width 1 has nothing to steal
        # inside a step; the domain segment spans the members)
        seg_d = spec0.segment * self.pack
        self.spec = dataclasses.replace(
            spec0, vocab=spec0.vocab * self.K,
            combine_capacity=spec0.combine_capacity * self.K,
            segment=seg_d, coslots=self.K, costride=self.stride)
        config = dataclasses.replace(cfg0, segment=seg_d)

        # composite address space: member j's bytes at element
        # j * stride * task_size, served through one ordinary TaskPlan
        source = FleetSource([h.feed.source for h in self.members],
                             self.stride * spec0.task_size)
        plan = TaskPlan(n_tasks=self.K * self.stride,
                        task_size=spec0.task_size,
                        n_procs=spec0.n_procs)
        ids, reps = steal.fleet_merge(
            [h.feed.task_ids_grid for h in self.members],
            [h.feed.repeats_grid for h in self.members],
            stride=self.stride, priorities=self.priorities)
        from jax.sharding import NamedSharding, PartitionSpec
        feed = SegmentFeed(
            source, plan, ids, reps, segment=seg_d,
            sharding=NamedSharding(self.mesh, PartitionSpec(AXIS)),
            prefetch=True, budget=feed_budget)
        self.handle = JobHandle(config, self.members[0].backend,
                                self.spec, self.mesh, plan, feed,
                                partitioner=self.members[0].partitioner)
        # members never run engines of their own; their solo feeds stop
        # prefetching now (grids stay readable for result accounting)
        self._member_grids = [
            (np.array(h.feed.task_ids_grid), np.array(h.feed.repeats_grid))
            for h in self.members]
        self._member_n_tasks = [int((g >= 0).sum())
                                for g, _ in self._member_grids]
        for h in self.members:
            h.feed.close()
        self._finalized: set[int] = set()

    # -- introspection -------------------------------------------------------

    @property
    def done(self) -> bool:
        return len(self._finalized) == self.K

    def ready(self) -> bool:
        return self.handle.ready()

    def job_work(self) -> np.ndarray:
        """Executed work per member slot so far — the replicated
        ``carry.job_work`` row (zeros before the first step)."""
        if self.handle._carry is None:
            return np.zeros((self.K,), np.int64)
        return np.asarray(self.handle._carry.job_work)[0].astype(np.int64)

    # -- execution -----------------------------------------------------------

    def step(self, n_segments: int = 1) -> bool:
        """Advance the shared cursor by up to ``n_segments`` domain
        segments (each packs ``pack`` member segments). Returns True
        while map work remains."""
        return self.handle.step(n_segments)

    def collect_finished(self) -> dict[str, JobResult]:
        """Finalize every member whose columns the shared cursor has
        fully consumed (and not finalized yet): one finish-program run
        splits the composite records by key range; each member's
        JobResult is adopted by its handle. Returns {name: result} of
        the newly finished members."""
        consumed = self.handle.feed.consumed_task_ids()
        counts = np.bincount(consumed // self.stride, minlength=self.K) \
            if len(consumed) else np.zeros((self.K,), np.int64)
        newly = [j for j in range(self.K) if j not in self._finalized
                 and counts[j] >= self._member_n_tasks[j]]
        if not newly:
            return {}
        results = self._finalize(newly)
        self._finalized.update(newly)
        return {self.names[j]: results[j] for j in newly}

    def _finalize(self, slots: list[int]) -> dict[int, JobResult]:
        """Run the (pure) finish program on the current carry and split
        its composite records for ``slots``. The carry is NOT mutated —
        the domain keeps scanning; finishing drains a *copy* of the
        in-flight chunk, so a member's last pushed records are covered
        the moment its tasks are all executed."""
        h = self.handle
        assert h._carry is not None, "no carry — domain never stepped"
        _, _, fin_fn = h._seg_fns
        keys, vals, overflow = fin_fn(h._carry)
        keys = np.asarray(keys)[0]
        vals = np.asarray(vals)[0]
        overflow = int(np.asarray(overflow)[0])
        valid = keys != int(KEY_SENTINEL)
        keys, vals = keys[valid], vals[valid]
        base = self.spec.vocab // self.K
        jw = self.job_work()
        total = max(int(jw.sum()), 1)
        out: dict[int, JobResult] = {}
        for j in slots:
            inside = (keys >= j * base) & (keys < (j + 1) * base)
            lk = (keys[inside] - j * base).astype(keys.dtype)
            lv = vals[inside]
            records = dict(zip(lk.tolist(), lv.tolist()))
            member = self.members[j]
            gids, greps = self._member_grids[j]
            task_valid = gids >= 0
            out[j] = JobResult(
                records=records,
                output=finalize(member.config.usecase, records),
                keys=lk, values=lv,
                # wall attribution: the domain's engine seconds split by
                # executed work share — the only meaningful per-member
                # cut of a mixed slice
                wall_time=h._wall * (int(jw[j]) / total),
                backend=h.backend.name,
                n_tasks=member.plan.n_tasks,
                tasks_per_rank=task_valid.sum(axis=1),
                work_per_rank=(greps * task_valid).sum(axis=1),
                steals_per_rank=np.zeros((self.spec.n_procs,), np.int32),
                partitioner=self.spec.partitioner,
                n_split_keys=0,
                combine_overflow=overflow,
            )
            member.adopt_result(out[j])
        return out

    # -- lifecycle -----------------------------------------------------------

    def close(self):
        """Stop the domain feed's prefetch (member feeds are already
        closed). Idempotent."""
        self.handle.close()

    def checkpoint(self, manager):
        """One snapshot for the whole domain: composite carry + shared
        fleet cursor + merged grids (through the ordinary JobHandle
        path), tagged with the membership so restore can re-form the
        domain before seeking."""
        return self.handle.checkpoint(
            manager, domain_members=list(self.names),
            domain_stride=self.stride, domain_pack=self.pack)

    def restore(self, manager) -> WorkDomain:
        """Resume a mid-co-schedule snapshot: the composite carry is
        installed and the domain feed seeks the shared cursor (saved
        merged grids included) — record-identical to the uninterrupted
        run. Call :meth:`collect_finished` afterwards to re-finalize
        members the saved cursor had already drained."""
        found, extra = manager.peek(None)
        saved = extra.get("domain_members")
        if saved is not None and list(saved) != list(self.names):
            raise ValueError(
                f"domain snapshot at step {found} was taken over members "
                f"{list(saved)} — this domain has {list(self.names)}; "
                "re-form the WorkDomain with the same jobs in the same "
                "order")
        self.handle.restore(manager)
        return self
