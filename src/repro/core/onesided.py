"""MapReduce-1S — the paper's decoupled one-sided engine, TPU-native.

Structure (paper §2.1, Fig 1) and its JAX mapping:

  Map + Local Reduce   scan step t: map_fn -> local_reduce -> bucketize
  one-sided put        per-step small all_to_all pushes task t's buckets
                       into every owner's Key-Value window; XLA's async
                       collectives let the push of step t overlap the map of
                       step t+1 (the carry holds the in-flight chunk, folded
                       one step later — an explicit double buffer)
  Reduce               incremental: each received chunk is folded into the
                       dense Key-Value window immediately (no post-barrier
                       reduce spike — this is where the imbalance win lives)
  ownership transfer   bucket overflow stays local and is folded into the
                       mapper's own window (paper footnote 2); the Combine
                       dup-sum makes the result exact
  Combine              ⌈log2 P⌉-level merge tree (core/combine.py)

Registered as backend ``"1s"`` (:mod:`repro.core.registry`). Both the
blocking ``run_job`` and the segmented ``make_segment_fns`` paths are
methods of :class:`OneSidedBackend`, sharing the per-step body — the
segmented path is what the checkpoint layer snapshots between calls (the
paper's "window sync after each Map task" storage-window checkpoints).
"""
from __future__ import annotations

from functools import partial
from collections.abc import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.combine import tree_combine
from repro.core.kv import (KEY_SENTINEL, bucketize, local_reduce,
                           local_reduce_repeated)
from repro.core.partition import lookup_owner
from repro.core.registry import JobSpec, memoized, register_backend
from repro.core.windows import (AXIS, DenseWindow, EngineCarry,
                                STATUS_REDUCE, combine_records, init_carry,
                                wrap_segment_fns)
from repro.distributed.collectives import (all_to_all_blocks, coded_exchange,
                                           shard_map)
from repro.kernels.fused_map.ops import fused_map_step


def _step(spec: JobSpec, map_fn: Callable, carry: EngineCarry, xs):
    task, task_id, rep = xs
    P, cap = spec.n_procs, spec.push_cap
    if spec.coslots > 1:
        # cross-job co-scheduling (core/workdomain.py): the composite
        # task id encodes (member job slot, local task id). The map_fn
        # must see the LOCAL id (use-cases key records by task id), and
        # every emitted key is offset into the owning job's disjoint
        # window slice — per-job dup-sum exactness then follows from the
        # solo argument, window by window. Executed repeats land in the
        # psum-maintained per-slot row so the scheduler charges tenants
        # for work actually run, wherever stealing routed it.
        base = spec.vocab // spec.coslots
        live = task_id >= 0
        slot = jnp.where(live, task_id // spec.costride, 0)
        local_id = jnp.where(live, task_id - slot * spec.costride,
                             task_id)
        keys, vals = map_fn(task, local_id, rep)
        keys = jnp.where(keys == KEY_SENTINEL, keys, keys + slot * base)
        carry = carry._replace(job_work=carry.job_work + lax.psum(
            jnp.zeros((spec.coslots,), jnp.int32).at[slot].add(
                jnp.where(live, rep, 0)), AXIS))
    else:
        # Phase I: Map (+ simulated imbalance via data-dependent repeats)
        keys, vals = map_fn(task, task_id, rep)
    if spec.fused_map:
        # Phases II+III fused into one pallas kernel (kernels/fused_map):
        # local reduce, owner lookup, bucketize and both window folds in
        # a single vocab pass — bit-identical to the unfused path below.
        table, bk, bv, counts = fused_map_step(
            keys, vals, rep, task_id, carry.owner_map, carry.owner_split,
            carry.pending_k, carry.pending_v, carry.table,
            n_procs=P, cap=cap)
        rk = all_to_all_blocks(bk, AXIS)
        rv = all_to_all_blocks(bv, AXIS)
        return carry._replace(table=table, pending_k=rk, pending_v=rv,
                              cursor=carry.cursor + 1), counts
    # Phase II: Local Reduce (inside Map, as in the paper). The repeat
    # factor re-computes the whole task (paper footnote 5) — per-rank
    # while-trip-counts differ, which is exactly the imbalance mechanism.
    uk, uv = local_reduce_repeated(keys, vals, keys.shape[0], rep)
    # one-sided put: bucket by the carried owner map (hash rule by
    # default; a skew-aware map from core/partition.py otherwise) and
    # push this chunk
    owners = lookup_owner(carry.owner_map, carry.owner_split, uk,
                          task_id, P)
    bk, bv, counts, (ofk, ofv) = bucketize(uk, uv, P, cap, owners=owners)
    rk = all_to_all_blocks(bk, AXIS)
    rv = all_to_all_blocks(bv, AXIS)
    # Phase III (incremental Reduce): fold the *previous* step's chunk while
    # this step's push is still in flight (double buffer).
    win = DenseWindow(carry.table).put(carry.pending_k.reshape(-1),
                                      carry.pending_v.reshape(-1))
    # ownership transfer for overflowed records: keep them locally
    win = win.put(ofk, ofv)
    return carry._replace(table=win.table, pending_k=rk, pending_v=rv,
                          cursor=carry.cursor + 1), counts


def _coded_step(spec: JobSpec, map_fn: Callable, carry: EngineCarry, xs):
    """One step of the r-replicated coded engine (``code_rate`` r > 1).

    The scan consumes one r-wide COLUMN BLOCK per step: every member of
    an r-rank code group holds the identical block (the group's members'
    r=1 tasks at this column, ``core/coded.py``), maps all r tasks (the
    r× compute the coded trade pays), unions the emissions under the
    local-reduce dup-sum, and replaces the r-1 intra-group unicast
    bucket rows with ONE XOR-coded multicast block
    (``distributed/collectives.coded_exchange``). Exactness: each
    record folds exactly once fleet-wide — one speaker per inter-group
    destination, one designated-peer decode per intra-group destination,
    one rotating member retaining the bucket overflow — and the Combine
    dup-sum makes the result independent of where records fold, the
    same argument that covers stealing at r=1.
    """
    task, task_id, rep = xs            # (r, S), (r,), (r,)
    P, cap, r = spec.n_procs, spec.push_cap, spec.code_rate
    me = lax.axis_index(AXIS)
    # Phases I+II per replica task, then union under the dup-sum
    ks, vs = [], []
    for j in range(r):
        keys, vals = map_fn(task[j], task_id[j], rep[j])
        uk, uv = local_reduce_repeated(keys, vals, keys.shape[0], rep[j])
        ks.append(uk)
        vs.append(uv)
    uk, uv, _ = local_reduce(jnp.concatenate(ks), jnp.concatenate(vs),
                             r * spec.task_size)
    # the block's first id picks split replicas for the whole union: any
    # group-replicated choice is exact (dup-sum locality independence)
    owners = lookup_owner(carry.owner_map, carry.owner_split, uk,
                          task_id[0], P)
    bk, bv, counts, (ofk, ofv) = bucketize(uk, uv, P, cap, owners=owners)
    rk, rv = coded_exchange(bk, bv, AXIS, r)
    win = DenseWindow(carry.table).put(carry.pending_k.reshape(-1),
                                      carry.pending_v.reshape(-1))
    # overflow: all members hold the identical union overflow — exactly
    # one (cursor-rotating) member of each group folds it
    keep = (carry.cursor % r) == (me % r)
    win = win.put(jnp.where(keep, ofk, KEY_SENTINEL),
                  jnp.where(keep, ofv, 0))
    return carry._replace(table=win.table, pending_k=rk, pending_v=rv,
                          cursor=carry.cursor + 1), counts


def _drain(carry: EngineCarry) -> EngineCarry:
    """Fold the last in-flight chunk; enter STATUS_REDUCE -> done."""
    win = DenseWindow(carry.table).put(carry.pending_k.reshape(-1),
                                      carry.pending_v.reshape(-1))
    P, cap = carry.pending_k.shape
    return carry._replace(
        table=win.table,
        pending_k=jnp.full((P, cap), KEY_SENTINEL, jnp.int32),
        pending_v=jnp.zeros((P, cap), jnp.int32),
        status=jnp.int32(STATUS_REDUCE),
    )


def _steal_segment(spec: JobSpec, map_fn: Callable, carry: EngineCarry,
                   tok, tid, rep) -> EngineCarry:
    """Advance one segment with device-side work stealing (core/steal.py).

    Per scan step: (1) every rank runs the pure claim function over the
    shared cursor state, so all ranks agree on who executes which task
    slot; (2) each claimed task is *fetched by global task id* from the
    rank that holds its input — a fixed-shape ``[tokens | id | repeat]``
    all_to_all, the one-sided "get" mirroring the push shuffle; (3) the
    executed repeat lands in the carry's psum-maintained progress row,
    which is exactly the state the next step's claims read.
    """
    from repro.core import steal
    P, S = spec.n_procs, spec.task_size
    me = lax.axis_index(AXIS)
    # deques address dense [0, count) ranges: real columns first
    perm = steal.compact_columns(tid)
    tok, tid, rep = tok[perm], tid[perm], rep[perm]
    head, tail = steal.segment_cursors(tid, AXIS)
    onehot = jnp.arange(P) == me

    def step(state, _):
        carry, head, tail = state
        src_rank, src_col, head, tail = steal.claim_step(head, tail,
                                                         carry.work)
        # serve: the rank owning each claimed slot ships that task's
        # input + (global id, repeat) to its executor
        mine = src_rank == me
        cols = jnp.where(mine, src_col, 0)
        served = jnp.concatenate(
            [jnp.where(mine[:, None], tok[cols], KEY_SENTINEL),
             jnp.where(mine[:, None],
                       jnp.stack([tid[cols], rep[cols]], axis=1),
                       jnp.asarray([-1, 0], jnp.int32))], axis=1)
        got = all_to_all_blocks(served, AXIS)
        src = src_rank[me]
        row = got[jnp.maximum(src, 0)]
        live = src >= 0
        task = jnp.where(live, row[:S], KEY_SENTINEL)
        t_id = jnp.where(live, row[S], -1)
        t_rep = jnp.where(live, row[S + 1], 0)
        carry = carry._replace(
            work=carry.work + lax.psum(
                jnp.where(onehot & live, t_rep, 0), AXIS),
            stolen=carry.stolen + lax.psum(
                jnp.where(onehot & live & (src != me), 1, 0), AXIS))
        carry, _ = _step(spec, map_fn, carry,
                         (task, t_id, jnp.maximum(t_rep, 1)))
        return (carry, head, tail), None

    (carry, _, _), _ = lax.scan(step, (carry, head, tail), None,
                                length=tok.shape[0])
    return carry


def _coded_steal_segment(spec: JobSpec, map_fn: Callable,
                         carry: EngineCarry, tok, tid, rep) -> EngineCarry:
    """Work stealing over r-replicated grids: claims move whole r-wide
    column blocks between GROUPS (G = P/r super-ranks of the same pure
    claim function), so a stolen block lands on all r members of the
    claimant group and its code group stays decodable. Member m of the
    victim group serves member m of each claimant group the full
    ``(r, S+2)`` block through the same fixed-shape all_to_all get as
    the r=1 steal path.
    """
    from repro.core import steal
    P, S, r = spec.n_procs, spec.task_size, spec.code_rate
    G = P // r
    me = lax.axis_index(AXIS)
    g, m = me // r, me % r
    # block-granular views of the segment: (W, S) -> (W//r, r, S)
    n_blk = tok.shape[0] // r
    tok = tok.reshape(n_blk, r, S)
    tid = tid.reshape(n_blk, r)
    rep = rep.reshape(n_blk, r)
    # real blocks first (any live sub-task keeps a block claimable)
    blk_valid = (tid >= 0).any(axis=1)
    perm = jnp.argsort(~blk_valid)
    tok, tid, rep = tok[perm], tid[perm], rep[perm]
    # group deques: every member holds the identical grid row, so the
    # one-hot psum over groups counts each block r times — divide out
    count = blk_valid.sum().astype(jnp.int32)
    tail = lax.psum(jnp.where(jnp.arange(G) == g, count, 0), AXIS) // r
    head = jnp.zeros_like(tail)
    onehot = jnp.arange(P) == me
    e_grp = jnp.arange(P) // r
    e_mem = jnp.arange(P) % r

    def step(state, _):
        carry, head, tail = state
        # per-group work row: members of a group accrue identically
        gwork = carry.work.reshape(G, r)[:, 0]
        src_grp, src_col, head, tail = steal.claim_step(head, tail, gwork)
        mine = (src_grp[e_grp] == g) & (e_mem == m)
        cols = jnp.where(mine, src_col[e_grp], 0)
        served = jnp.concatenate(
            [jnp.where(mine[:, None], tok[cols].reshape(P, r * S),
                       KEY_SENTINEL),
             jnp.where(mine[:, None], tid[cols], -1),
             jnp.where(mine[:, None], rep[cols], 0)], axis=1)
        got = all_to_all_blocks(served, AXIS)
        src = src_grp[g]
        row = got[jnp.maximum(src * r + m, 0)]
        live = src >= 0
        task = jnp.where(live, row[:r * S], KEY_SENTINEL).reshape(r, S)
        t_id = jnp.where(live, row[r * S:r * S + r], -1)
        t_rep = jnp.where(live, row[r * S + r:], 0)
        done = jnp.where(t_id >= 0, t_rep, 0).sum()
        carry = carry._replace(
            work=carry.work + lax.psum(
                jnp.where(onehot & live, done, 0), AXIS),
            stolen=carry.stolen + lax.psum(
                jnp.where(onehot & live & (src != g), 1, 0), AXIS))
        carry, _ = _coded_step(spec, map_fn, carry,
                               (task, t_id, jnp.maximum(t_rep, 1)))
        return (carry, head, tail), None

    (carry, _, _), _ = lax.scan(step, (carry, head, tail), None,
                                length=n_blk)
    return carry


def _shard_spec():
    from jax.sharding import PartitionSpec as P
    return P(AXIS)


def _engine(spec: JobSpec, map_fn: Callable, tokens, task_ids, repeats):
    """Per-shard engine body. tokens: (1, T, S); task_ids/repeats: (1, T)."""
    tokens, task_ids, repeats = tokens[0], task_ids[0], repeats[0]
    carry = init_carry(spec)
    if spec.code_rate > 1:
        if spec.stealing:
            carry = _coded_steal_segment(spec, map_fn, carry, tokens,
                                         task_ids, repeats)
        else:
            r = spec.code_rate
            nb = task_ids.shape[0] // r
            carry, _ = lax.scan(
                partial(_coded_step, spec, map_fn), carry,
                (tokens.reshape(nb, r, -1), task_ids.reshape(nb, r),
                 repeats.reshape(nb, r)))
    elif spec.stealing:
        carry = _steal_segment(spec, map_fn, carry, tokens, task_ids,
                               repeats)
    else:
        carry, _ = lax.scan(partial(_step, spec, map_fn), carry,
                            (tokens, task_ids, repeats))
    carry = _drain(carry)
    # Combine (phase IV): sorted merge tree (run_job is the legacy
    # blocking path — the Job API's segmented fin surfaces the overflow
    # count; here an undersized combine_capacity still truncates)
    keys, vals, overflow = combine_records(carry.table, spec)
    keys, vals, _ = tree_combine(keys, vals, AXIS, spec.n_procs, overflow)
    return keys[None], vals[None]


@register_backend("1s")
class OneSidedBackend:
    """The decoupled engine behind the ``Backend`` protocol."""

    # the engine honors JobSpec.stealing (device-side work stealing,
    # core/steal.py); submit() refuses the flag on backends without this
    supports_stealing = True
    # ... and JobSpec.fused_map (the pallas-fused per-step hot path,
    # kernels/fused_map), gated by submit() the same way
    supports_fused_map = True
    # ... and JobSpec.coslots > 1 (cross-job co-scheduling — one engine
    # program executing a composite task/key space merged from several
    # program-compatible jobs, core/workdomain.py). The scheduler only
    # forms WorkDomains over backends advertising this.
    supports_coschedule = True
    # ... and JobSpec.code_rate > 1 (the r-replicated coded shuffle:
    # core/coded.py grids + the XOR multicast exchange), gated by
    # submit() like the other capability flags
    supports_coded = True

    def __init__(self):
        self._programs: dict = {}

    def run_job(self, spec: JobSpec, map_fn: Callable, mesh, tokens,
                task_ids, repeats):
        """Full job. tokens: (P, T, S) host array. Returns rank-0
        records."""
        P = _shard_spec()
        fn = memoized(
            self._programs, ("run", spec, map_fn, mesh),
            lambda: jax.jit(shard_map(
                partial(_engine, spec, map_fn), mesh=mesh,
                in_specs=(P, P, P), out_specs=(P, P))))
        keys, vals = fn(tokens, task_ids, repeats)
        return jax.device_get(keys)[0], jax.device_get(vals)[0]

    def trace_handles(self, spec: JobSpec, map_fn: Callable, mesh,
                      seg_tasks: int = 2, tag: str = ""):
        """Traceable :class:`~repro.core.registry.ProgramHandle`\\ s for
        fleetlint (repro.analysis) — the segmented triple plus the
        replication contract the steal protocol relies on."""
        from repro.core.registry import segment_program_handles
        return segment_program_handles(self, spec, map_fn, mesh,
                                       seg_tasks=seg_tasks, tag=tag)

    def make_segment_fns(self, spec: JobSpec, map_fn: Callable, mesh):
        """(init_fn, segment_fn, finish_fn) — the checkpointable path.

        ``segment_fn(carry, tokens_seg, task_ids_seg, repeats_seg)``
        advances ``segment`` tasks and returns the new carry — the host
        snapshots it between calls (async), which is exactly the paper's
        per-task window sync.
        """
        return memoized(self._programs, ("seg", spec, map_fn, mesh),
                        lambda: self._build_segment_fns(spec, map_fn, mesh))

    def _build_segment_fns(self, spec: JobSpec, map_fn: Callable, mesh):
        if spec.code_rate > 1:
            # the coded engine consumes r-wide column blocks: the feed
            # hands segments whose width is a multiple of r (submit()
            # scales the segment), re-blocked here for the scan
            if spec.stealing:
                def seg(carry, tok, tid, rep):
                    assert tok.shape[0] % spec.code_rate == 0, tok.shape
                    return _coded_steal_segment(spec, map_fn, carry, tok,
                                                tid, rep)
            else:
                def seg(carry, tok, tid, rep):
                    r = spec.code_rate
                    assert tok.shape[0] % r == 0, tok.shape
                    nb = tok.shape[0] // r
                    carry, _ = lax.scan(
                        partial(_coded_step, spec, map_fn), carry,
                        (tok.reshape(nb, r, -1), tid.reshape(nb, r),
                         rep.reshape(nb, r)))
                    return carry
        elif spec.stealing:
            seg = partial(_steal_segment, spec, map_fn)
        else:
            def seg(carry, tok, tid, rep):
                carry, _ = lax.scan(partial(_step, spec, map_fn), carry,
                                    (tok, tid, rep))
                return carry

        def fin(carry):
            carry = _drain(carry)
            keys, vals, overflow = combine_records(carry.table, spec)
            return tree_combine(keys, vals, AXIS, spec.n_procs, overflow)

        return wrap_segment_fns(mesh, spec, seg, fin)


# -- module-level aliases (pre-registry call sites) -------------------------

def run_job(spec, map_fn, mesh, tokens, task_ids, repeats):
    from repro.core.registry import get_backend
    return get_backend("1s").run_job(spec, map_fn, mesh, tokens, task_ids,
                                     repeats)


def make_segment_fns(spec, map_fn, mesh):
    from repro.core.registry import get_backend
    return get_backend("1s").make_segment_fns(spec, map_fn, mesh)
