"""MapReduce-1S — the paper's decoupled one-sided engine, TPU-native.

Structure (paper §2.1, Fig 1) and its JAX mapping:

  Map + Local Reduce   scan step t: map_fn -> local_reduce -> bucketize
  one-sided put        per-step small all_to_all pushes task t's buckets
                       into every owner's Key-Value window; XLA's async
                       collectives let the push of step t overlap the map of
                       step t+1 (the carry holds the in-flight chunk, folded
                       one step later — an explicit double buffer)
  Reduce               incremental: each received chunk is folded into the
                       dense Key-Value window immediately (no post-barrier
                       reduce spike — this is where the imbalance win lives)
  ownership transfer   bucket overflow stays local and is folded into the
                       mapper's own window (paper footnote 2); the Combine
                       dup-sum makes the result exact
  Combine              ⌈log2 P⌉-level merge tree (core/combine.py)

The same body also runs segmented (``run_segments``) so the checkpoint layer
can snapshot the windows after every segment — the paper's "window sync after
each Map task" storage-window checkpoints.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.api import JobSpec
from repro.core.combine import tree_combine
from repro.core.kv import (KEY_SENTINEL, bucketize, local_reduce,
                           local_reduce_repeated)
from repro.core.windows import (DenseWindow, STATUS_COMBINE, STATUS_MAP,
                                STATUS_REDUCE)
from repro.distributed.collectives import all_to_all_blocks

AXIS = "procs"


class EngineCarry(NamedTuple):
    table: jnp.ndarray       # dense Key-Value window (vocab,)
    pending_k: jnp.ndarray   # in-flight received chunk (P, cap)
    pending_v: jnp.ndarray
    status: jnp.ndarray      # scalar per process (STATUS_*)
    cursor: jnp.ndarray      # tasks completed (restart point)


def _init_carry(spec: JobSpec) -> EngineCarry:
    from repro.distributed.collectives import pvary
    P, cap = spec.n_procs, spec.push_cap
    return pvary(EngineCarry(
        table=jnp.zeros((spec.vocab,), jnp.int32),
        pending_k=jnp.full((P, cap), KEY_SENTINEL, jnp.int32),
        pending_v=jnp.zeros((P, cap), jnp.int32),
        status=jnp.int32(STATUS_MAP),
        cursor=jnp.int32(0),
    ), AXIS)


def _step(spec: JobSpec, map_fn: Callable, carry: EngineCarry, xs):
    task, rep = xs
    P, cap = spec.n_procs, spec.push_cap
    # Phase I: Map (+ simulated imbalance via data-dependent repeat loop)
    keys, vals = map_fn(task, rep)
    # Phase II: Local Reduce (inside Map, as in the paper). The repeat
    # factor re-computes the whole task (paper footnote 5) — per-rank
    # while-trip-counts differ, which is exactly the imbalance mechanism.
    uk, uv = local_reduce_repeated(keys, vals, keys.shape[0], rep)
    # one-sided put: bucket by owner hash and push this chunk
    bk, bv, counts, (ofk, ofv) = bucketize(uk, uv, P, cap)
    rk = all_to_all_blocks(bk, AXIS)
    rv = all_to_all_blocks(bv, AXIS)
    # Phase III (incremental Reduce): fold the *previous* step's chunk while
    # this step's push is still in flight (double buffer).
    win = DenseWindow(carry.table).put(carry.pending_k.reshape(-1),
                                       carry.pending_v.reshape(-1))
    # ownership transfer for overflowed records: keep them locally
    win = win.put(ofk, ofv)
    return EngineCarry(win.table, rk, rv, carry.status,
                       carry.cursor + 1), counts


def _drain(carry: EngineCarry) -> EngineCarry:
    """Fold the last in-flight chunk; enter STATUS_REDUCE -> done."""
    win = DenseWindow(carry.table).put(carry.pending_k.reshape(-1),
                                       carry.pending_v.reshape(-1))
    P, cap = carry.pending_k.shape
    return EngineCarry(
        win.table,
        jnp.full((P, cap), KEY_SENTINEL, jnp.int32),
        jnp.zeros((P, cap), jnp.int32),
        jnp.int32(STATUS_REDUCE),
        carry.cursor,
    )


def _shard_spec():
    from jax.sharding import PartitionSpec as P
    return P(AXIS)


def _engine(spec: JobSpec, map_fn: Callable, tokens, repeats):
    """Per-shard engine body. tokens: (1, T, S); repeats: (1, T)."""
    tokens = tokens[0]
    repeats = repeats[0]
    carry = _init_carry(spec)
    carry, _ = lax.scan(partial(_step, spec, map_fn), carry,
                        (tokens, repeats))
    carry = _drain(carry)
    # Combine (phase IV): sorted merge tree
    keys, vals = DenseWindow(carry.table).to_records(None, spec.n_procs)
    W = spec.combine_capacity
    keys, vals, _ = local_reduce(keys[:], vals[:], W) if W != keys.shape[0] \
        else (keys, vals, None)
    keys, vals = tree_combine(keys, vals, AXIS, spec.n_procs)
    return keys[None], vals[None]


def run_job(spec: JobSpec, map_fn: Callable, mesh, tokens, repeats):
    """Full job. tokens: (P, T, S) host array. Returns rank-0 records."""
    P = _shard_spec()
    fn = jax.jit(jax.shard_map(
        partial(_engine, spec, map_fn), mesh=mesh,
        in_specs=(P, P), out_specs=(P, P)))
    keys, vals = fn(tokens, repeats)
    return jax.device_get(keys)[0], jax.device_get(vals)[0]


# ---------------------------------------------------------------------------
# segmented execution (checkpointable — "MPI storage window" sync points)
# ---------------------------------------------------------------------------

def make_segment_fns(spec: JobSpec, map_fn: Callable, mesh):
    """Returns (init_fn, segment_fn, finish_fn), each jitted over the mesh.

    ``segment_fn(carry, tokens_seg, repeats_seg)`` advances ``segment`` tasks
    and returns the new carry — the host snapshots it between calls (async),
    which is exactly the paper's per-task window sync.
    """
    P = _shard_spec()

    def seg(carry, tok, rep):
        carry, _ = lax.scan(partial(_step, spec, map_fn), carry,
                            (tok[0], rep[0]))
        return carry

    def fin(carry):
        carry = _drain(carry)
        keys, vals = DenseWindow(carry.table).to_records(None, spec.n_procs)
        keys, vals = tree_combine(keys, vals, AXIS, spec.n_procs)
        return keys[None], vals[None]

    def init():
        c = _init_carry(spec)
        # broadcast per-shard carry: every leaf gains a leading shard dim
        return jax.tree.map(lambda x: x[None], c)

    carry_specs = EngineCarry(P, P, P, P, P)
    seg_sm = jax.jit(jax.shard_map(
        lambda c, t, r: jax.tree.map(
            lambda x: x[None],
            seg(jax.tree.map(lambda x: x[0], c), t, r)),
        mesh=mesh, in_specs=(carry_specs, P, P), out_specs=carry_specs))
    fin_sm = jax.jit(jax.shard_map(
        lambda c: fin(jax.tree.map(lambda x: x[0], c)),
        mesh=mesh, in_specs=(carry_specs,), out_specs=(P, P)))
    init_sm = jax.jit(jax.shard_map(
        lambda: init(), mesh=mesh, in_specs=(), out_specs=carry_specs))
    return init_sm, seg_sm, fin_sm
