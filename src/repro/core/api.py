"""Public MapReduce API — mirrors the paper's class hierarchy (Listing 1).

  * Base class  -> :class:`MapReduceJob` (Init / Run / Print / Finalize)
  * Back-end    -> ``backend="1s" | "2s"`` (core.onesided / core.twosided)
  * Use-case    -> subclass providing ``map_task`` (+ optional
                   ``reduce_local`` — the default fuses it into Map, as the
                   paper does)

Example (paper Listing 1 analogue)::

    job = WordCount(backend="1s")
    job.init(tokens, vocab=VOCAB, task_size=4096, push_cap=512, n_procs=8)
    result = job.run()
    job.print_result()
    job.finalize()
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import planner
from repro.core.kv import KEY_SENTINEL


@dataclass(frozen=True)
class JobSpec:
    """Static engine settings (paper: Init(filename, win_size, chunk_size,
    task_size, ...))."""
    vocab: int                   # dense Key-Value window size ("win_size")
    task_size: int               # elements per Map task
    push_cap: int                # records per one-sided push per owner
                                 #   ("maximum bytes per one-sided operation")
    n_procs: int
    combine_capacity: int = 0    # 0 -> vocab
    segment: int = 0             # checkpoint segment (tasks between syncs)

    def __post_init__(self):
        if not self.combine_capacity:
            object.__setattr__(self, "combine_capacity", self.vocab)


class MapReduceJob:
    """Base class: wiring between use-case, back-end and the mesh."""

    def __init__(self, backend: str = "1s"):
        assert backend in ("1s", "2s"), backend
        self.backend = backend
        self._compiled = None
        self.spec: Optional[JobSpec] = None

    # -- use-case hooks -----------------------------------------------------
    def map_task(self, task_tokens: jnp.ndarray, repeat: jnp.ndarray):
        """-> (keys, values) fixed-length arrays. Override per use case."""
        raise NotImplementedError

    # -- lifecycle ----------------------------------------------------------
    def init(self, tokens: np.ndarray, *, vocab: int, task_size: int,
             push_cap: int, n_procs: int, mesh=None, repeats=None,
             segment: int = 0):
        from repro.distributed.mesh import local_mesh
        self.spec = JobSpec(vocab=vocab, task_size=task_size,
                            push_cap=push_cap, n_procs=n_procs,
                            segment=segment)
        self.mesh = mesh if mesh is not None else local_mesh(
            (n_procs,), ("procs",))
        self.plan = planner.plan_input(len(tokens), task_size, n_procs)
        self._tokens = planner.shard_tasks(np.asarray(tokens, np.int32),
                                           self.plan)
        T = self.plan.tasks_per_proc
        if repeats is None:
            repeats = np.ones((n_procs, T), np.int32)
        self._repeats = np.asarray(repeats, np.int32).reshape(n_procs, T)
        return self

    def run(self):
        from repro.core import onesided, twosided
        runner = onesided.run_job if self.backend == "1s" else twosided.run_job
        keys, vals = runner(self.spec, self.map_task, self.mesh,
                            self._tokens, self._repeats)
        self._result = (np.asarray(keys), np.asarray(vals))
        return self._result

    def result_dict(self):
        keys, vals = self._result
        valid = keys != int(KEY_SENTINEL)
        return dict(zip(keys[valid].tolist(), vals[valid].tolist()))

    def print_result(self, top: int = 10):
        d = self.result_dict()
        for k, v in sorted(d.items(), key=lambda kv: -kv[1])[:top]:
            print(f"{k}\t{v}")

    def finalize(self):
        self._compiled = None
        self._tokens = self._repeats = None
