"""Deprecated class-based API — kept one release for migration.

The public API now lives in :mod:`repro.core.job` (``submit`` /
``JobHandle`` / ``JobResult``), :mod:`repro.core.registry` (pluggable
backends) and :mod:`repro.core.usecase` (declarative scenarios)::

    from repro.core import JobConfig, submit, WordCount
    result = submit(JobConfig(usecase=WordCount(vocab=VOCAB),
                              backend="1s", task_size=4096,
                              push_cap=1024, n_procs=8), tokens).result()
    result.records            # {key: count}
    result.imbalance          # per-rank work stats

Migration from this module's ``MapReduceJob``:

  =============================    ====================================
  old (Listing-1 style)            new (unified Job API)
  =============================    ====================================
  subclass + ``map_task``          ``UseCase.map_emit`` (declarative)
  ``job.init(tokens, ...)``        ``submit(JobConfig(...), tokens)``
  ``job.run()``                    ``handle.result()`` (structured)
  ``job.result_dict()``            ``result.records``
  ``onesided.make_segment_fns``    ``JobConfig(segment=N)`` +
                                   ``handle.step()/checkpoint()``
  ``backend="1s"|"2s"`` strings    any ``register_backend`` name
  =============================    ====================================

``MapReduceJob`` below is a thin shim over the new machinery: old
subclasses that override ``map_task(tokens, repeat)`` keep working, but
emit a ``DeprecationWarning``.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.core import planner
from repro.core.kv import KEY_SENTINEL
from repro.core.registry import JobSpec, get_backend  # re-export JobSpec


class MapReduceJob:
    """Deprecated: wiring between use-case, back-end and the mesh."""

    def __init__(self, backend: str = "1s"):
        warnings.warn(
            "MapReduceJob is deprecated; use repro.core.submit(JobConfig"
            "(usecase=..., backend=...), dataset) instead",
            DeprecationWarning, stacklevel=2)
        self.backend = backend
        self._compiled = None
        self.spec: JobSpec | None = None

    # -- use-case hooks -----------------------------------------------------
    def map_task(self, task_tokens, repeat):
        """-> (keys, values) fixed-length arrays. Override per use case."""
        raise NotImplementedError

    # -- lifecycle ----------------------------------------------------------
    def init(self, tokens: np.ndarray, *, vocab: int, task_size: int,
             push_cap: int, n_procs: int, mesh=None, repeats=None,
             segment: int = 0):
        from repro.distributed.mesh import local_mesh
        self.spec = JobSpec(vocab=vocab, task_size=task_size,
                            push_cap=push_cap, n_procs=n_procs,
                            segment=segment)
        self.mesh = mesh if mesh is not None else local_mesh(
            (n_procs,), ("procs",))
        self.plan = planner.plan_input(len(tokens), task_size, n_procs)
        self._tokens = planner.shard_tasks(np.asarray(tokens, np.int32),
                                           self.plan)
        self._task_ids = planner.shard_task_ids(self.plan)
        T = self.plan.tasks_per_proc
        if repeats is None:
            repeats = np.ones((n_procs, T), np.int32)
        self._repeats = np.asarray(repeats, np.int32).reshape(n_procs, T)
        return self

    def _map_fn(self, task_tokens, task_id, repeat):
        """Adapt the legacy map_task to the Backend protocol signature."""
        return self.map_task(task_tokens, repeat)

    def run(self):
        runner = get_backend(self.backend)
        keys, vals = runner.run_job(self.spec, self._map_fn, self.mesh,
                                    self._tokens, self._task_ids,
                                    self._repeats)
        self._result = (np.asarray(keys), np.asarray(vals))
        return self._result

    def result_dict(self):
        keys, vals = self._result
        valid = keys != int(KEY_SENTINEL)
        return dict(zip(keys[valid].tolist(), vals[valid].tolist()))

    def print_result(self, top: int = 10):
        d = self.result_dict()
        for k, v in sorted(d.items(), key=lambda kv: -kv[1])[:top]:
            print(f"{k}\t{v}")

    def finalize(self):
        self._compiled = None
        self._tokens = self._repeats = None
