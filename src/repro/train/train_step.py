"""The train step: microbatched grad accumulation, remat, AdamW.

Gradient-sync schedule (the paper's principle applied to training): with
``decoupled_grad_sync=True`` parameters are FSDP-sharded over the data axis,
so XLA emits one reduce-scatter per scanned super-block *inside* the
backward scan — partial results pushed early, overlapping the next block's
backward GEMMs (MR-1S's chunked push, verbatim). With ``False`` parameters
replicate over data and gradients all-reduce after the backward completes —
the bulk-synchronous MR-2S analogue. §Perf quantifies the difference from
the lowered collective schedules.

Cross-pod gradient compression (int8 + error feedback) optionally runs on
the pod axis only: the step is shard_mapped manually over ``pod`` (data and
model stay GSPMD-automatic), grads quantize before the cross-pod psum.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig, RunConfig, TrainConfig
from repro.models.transformer import loss_fn
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim import compress as compress_mod


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    residual: Any                # int8-compression error feedback (or None)


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, params
                     ) -> TrainState:
    res = (compress_mod.init_residuals(params)
           if tcfg.compress_cross_pod else None)
    return TrainState(params, adamw_init(params, tcfg), res)


def _accumulate_grads(cfg, tcfg, run, params, batch, *, mesh, dp_entry,
                      unroll=False):
    """Returns (grads, loss, metrics) with grad-accum scan when A > 1."""
    A = run.grad_accum_steps
    lf = partial(loss_fn, cfg, mesh=mesh, dp_entry=dp_entry,
                 remat=tcfg.remat_policy, unroll=unroll)

    if A == 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lf(p, batch), has_aux=True)(params)
        return grads, loss, metrics

    mb = run.resolved_microbatch()
    batch_r = jax.tree.map(
        lambda x: x.reshape((A, mb) + x.shape[1:]), batch)
    adt = jnp.dtype(tcfg.accum_dtype)

    def acc_step(carry, mbatch):
        gsum, lsum = carry
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lf(p, mbatch), has_aux=True)(params)
        gsum = jax.tree.map(lambda a, g: a + g.astype(adt), gsum, grads)
        return (gsum, lsum + loss), metrics

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
    if unroll:
        carry = (zeros, jnp.float32(0.0))
        for a in range(A):
            carry, metrics = acc_step(
                carry, jax.tree.map(lambda x, a=a: x[a], batch_r))
        gsum, lsum = carry
    else:
        (gsum, lsum), ms = lax.scan(acc_step, (zeros, jnp.float32(0.0)),
                                    batch_r)
        metrics = jax.tree.map(lambda m: m[-1], ms)
    grads = jax.tree.map(lambda g: (g / A).astype(jnp.float32), gsum)
    return grads, lsum / A, metrics


def make_train_step(cfg: ModelConfig, run: RunConfig, *, mesh=None,
                    dp_entry=None, unroll: bool = False):
    """train_step(state, batch) -> (state, metrics). ``batch``:
    {tokens, labels[, frontend_embeds]} at global_batch. ``unroll``
    unrolls every scan (cost-exact HLO for the dry-run roofline)."""
    tcfg = run.train

    def train_step(state: TrainState, batch: dict):
        grads, loss, metrics = _accumulate_grads(
            cfg, tcfg, run, state.params, batch, mesh=mesh,
            dp_entry=dp_entry, unroll=unroll)
        residual = state.residual
        if tcfg.compress_cross_pod and residual is not None:
            # int8 error-feedback on what crosses the (thin) pod link.
            # Grads at this point are already globally reduced by GSPMD; the
            # quantization models the wire format and keeps the estimator
            # unbiased long-run via the residual (see optim/compress.py and
            # DESIGN.md §8 — the lowering-level pod-axis split is a §Perf
            # item, the math lives here either way).
            grads, residual = compress_mod.ef_compress(grads, residual)
        new_params, new_opt, om = adamw_update(state.params, grads,
                                               state.opt, tcfg)
        metrics = dict(metrics, loss=loss, **om)
        return TrainState(new_params, new_opt, residual), metrics

    return train_step
