"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553, InternViT + InternLM2 backbone. [arXiv:2404.16821; hf]

The InternViT frontend is a STUB: ``input_specs()`` provides precomputed
(B, S_img, 6144) patch embeddings, early-fused (prepended) to the text
embeddings. Only the InternLM2-style decoder backbone is modeled.
vocab 92553 is not divisible by tp=16, so vocab TP is disabled for this arch
(the sharding layer falls back to FSDP on d_model — DESIGN.md §7).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92_553,
    frontend="vision_stub",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    n_layers=2,
    d_model=96,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=257,            # intentionally non-divisible, like the real one
    frontend="vision_stub",
)
