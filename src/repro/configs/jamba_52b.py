"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba+attention 1:7 interleave.
[arXiv:2403.19887; hf]

Period-8 super-block: attention at slot 4 (attn_offset=4), Mamba elsewhere;
MoE replaces the MLP on odd slots (every 2nd layer). The Mamba layers use
the stack's SSD (Mamba-2) block with d_state=16 — DESIGN.md records this
Mamba-1→SSD substitution as a hardware adaptation (the SSD chunked form is
the TPU-native formulation).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65_536,
    n_experts=16,
    top_k=2,
    moe_every=2,
    d_ff_expert=14336,
    dispatch_mode="1s",
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    block_pattern=8,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    n_experts=4,
    top_k=2,
    moe_every=2,
    d_ff_expert=128,
    dispatch_mode="1s",
    dispatch_groups=2,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=16,
    block_pattern=8,
)
