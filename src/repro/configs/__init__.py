"""Assigned-architecture configs. ``get_config(arch_id)`` resolves the exact
public config; ``get_smoke_config(arch_id)`` a reduced same-family variant for
CPU smoke tests; ``ARCH_IDS`` lists all ten assigned ids."""
from repro.configs.registry import (ARCH_IDS, get_config, get_smoke_config,
                                    shape_cells, runnable_cells)
