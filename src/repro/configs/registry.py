"""Arch registry + cell matrix.

``runnable_cells()`` enumerates every assigned (arch × shape) pair, applying
the brief's skip rules:
  * ``long_500k`` needs sub-quadratic attention → runs only for SSM/hybrid/
    SWA archs (mamba2, jamba, h2o-danube); skipped for the 7 pure
    full-attention archs (recorded, not silently dropped).
  * every arch here has a decode path (whisper decodes as enc-dec), so no
    decode-shape skips apply.
"""
from __future__ import annotations

import importlib

from repro.config import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "olmo-1b": "repro.configs.olmo_1b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube",
    "codeqwen1.5-7b": "repro.configs.codeqwen_7b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "jamba-v0.1-52b": "repro.configs.jamba_52b",
    "mamba2-780m": "repro.configs.mamba2_780m",
}

ARCH_IDS: list[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch_id]).SMOKE


def shape_cells() -> dict[str, ShapeConfig]:
    return dict(SHAPES)


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (skip recorded "
                       "in DESIGN.md)")
    return True, ""


def runnable_cells(include_skips: bool = False):
    """Yield (arch_id, shape_name, runnable, reason)."""
    out = []
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for sname, shape in SHAPES.items():
            ok, why = cell_status(cfg, shape)
            if ok or include_skips:
                out.append((arch_id, sname, ok, why))
    return out
