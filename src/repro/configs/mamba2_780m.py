"""mamba2-780m [ssm] — 48L d_model=1536, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]

d_inner = 2*d_model = 3072, head_dim 64 → 48 SSD heads, chunked scan 256.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    attn_type="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    attn_type="none",
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=16,
    tie_embeddings=True,
)
