"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32) d_ff=13440
vocab=92416, qwen1.5 architecture (qkv bias, 1M rope theta).
[hf:Qwen/CodeQwen1.5-7B; hf]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92_416,
    rope_theta=1_000_000.0,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    name="codeqwen-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
)
