"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352. [hf:stabilityai/stablelm-2-1_6b; hf]

StableLM-2-12B uses LayerNorm and per-head qk-norm.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100_352,
    norm_type="layernorm",
    qk_norm=True,
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    norm_type="layernorm",
    qk_norm=True,
)
