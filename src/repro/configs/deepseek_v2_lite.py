"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512, 2 shared experts, 1 leading dense layer.
[arXiv:2405.04434; hf]

Assigned header says 64e top-6 (the trailing "160 routed" note is full V2);
we follow the primary spec. Lite has no q-LoRA (q is full-rank). The assigned
d_ff=1408 is kept verbatim for both the dense layer and the experts
(DESIGN.md §Config fidelity notes the public dense-ff is 10944).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    attn_type="mla",
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    d_head=192,                # nope + rope (query head width)
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_every=1,
    first_k_dense=1,
    d_ff_expert=1408,
    dispatch_mode="1s",
    block_pattern=1,
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    family="moe",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab_size=512,
    attn_type="mla",
    kv_lora_rank=64,
    qk_rope_dim=16,
    qk_nope_dim=32,
    v_head_dim=32,
    d_head=48,
    n_experts=8,
    n_shared_experts=2,
    top_k=2,
    moe_every=1,
    first_k_dense=1,
    d_ff_expert=192,
    dispatch_mode="1s",
    dispatch_groups=2,
    block_pattern=1,
)
