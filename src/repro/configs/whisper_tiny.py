"""whisper-tiny [audio] — 4L d_model=384 6H d_ff=1536 vocab=51865, enc-dec,
conv frontend (stub). [arXiv:2212.04356; unverified]

The conv1d frame frontend is a STUB: ``input_specs()`` provides precomputed
(B, S_enc, 384) frame embeddings (S_enc = seq_len // 2, matching whisper's
2x conv downsampling). Positional encoding is RoPE here (hardware-adaptation
note in DESIGN.md: whisper's learned/sinusoidal embeddings are replaced by
the stack's uniform RoPE — structure and cost identical).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    norm_type="layernorm",
    n_enc_layers=4,
    enc_seq_factor=2,
    frontend="audio_stub",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    norm_type="layernorm",
    n_enc_layers=2,
    enc_seq_factor=2,
    frontend="audio_stub",
)
