"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Public Maverick interleaves dense/MoE 1:1 with one shared expert, which is
what makes 400B-total / 17B-active consistent with the assigned dims
(48 all-MoE layers would be ≈770B) — see DESIGN.md §Config fidelity.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    n_experts=128,
    n_shared_experts=1,
    top_k=1,
    moe_every=2,               # dense/MoE 1:1 interleave
    d_ff_expert=8192,
    dispatch_mode="1s",
    block_pattern=2,
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    family="moe",
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    n_experts=8,
    n_shared_experts=1,
    top_k=1,
    moe_every=2,
    d_ff_expert=256,
    dispatch_mode="1s",
    dispatch_groups=2,
    block_pattern=2,
)
