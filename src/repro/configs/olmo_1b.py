"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304,
non-parametric LN, tied embeddings. [arXiv:2402.00838; hf]
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50_304,
    norm_type="nonparam_ln",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="olmo-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    norm_type="nonparam_ln",
    tie_embeddings=True,
)
