"""Serving driver: batched-request generation over one model replica.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \\
        --requests 16 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import numpy as np

    from repro.configs.registry import get_config, get_smoke_config
    from repro.models.transformer import init_model
    from repro.serve.engine import ServeEngine

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    params = init_model(cfg, jax.random.key(args.seed))
    max_len = args.prompt_len + args.new_tokens + 8
    eng = ServeEngine(cfg, params, max_len=max_len)

    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    fe = None
    if cfg.frontend == "vision_stub":
        fe = rng.normal(size=(args.requests, 16, cfg.d_model)).astype(
            np.float32)
    elif cfg.n_enc_layers:
        fe = rng.normal(size=(args.requests, args.prompt_len,
                              cfg.d_model)).astype(np.float32)

    print(f"[serve] {cfg.name}: {args.requests} requests, "
          f"batch {args.batch}, prompt {args.prompt_len}, "
          f"gen {args.new_tokens}")
    t0 = time.perf_counter()
    n_out = 0
    for lo in range(0, args.requests, args.batch):
        hi = min(args.requests, lo + args.batch)
        out = eng.generate(
            prompts[lo:hi], args.new_tokens,
            frontend_embeds=None if fe is None else fe[lo:hi],
            greedy=args.greedy, seed=args.seed)
        n_out += out.size
        print(f"[serve] batch {lo}-{hi}: first row {out[0, :8].tolist()}")
    wall = time.perf_counter() - t0
    print(f"[serve] done: {n_out} tokens in {wall:.1f}s "
          f"({n_out / wall:,.0f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
