"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
the full train_step / serve_step / prefill program is lowered with explicit
in_shardings onto the production mesh and compiled; memory_analysis shows it
fits, cost_analysis + HLO collective parsing feed §Roofline.

Roofline calibration (DESIGN.md §9): XLA cost analysis counts scan bodies
once, so per cell we additionally lower *unrolled* reduced-depth variants —
(nb=1,A=1), (nb=2,A=1) and (nb=1,A=2) where nb = scanned super-blocks and
A = grad-accum steps — and extrapolate exactly (the program is affine in
both trip counts):

    cost(NB, A) = cost(1,1) + (A-1)·dA + A·(NB-1)·dL
    dL = cost(2,1) - cost(1,1);  dA = cost(1,2) - cost(1,1)

Usage:
    python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    python -m repro.launch.dryrun --arch all [--multipod] [--no-calibrate]
"""
# The VERY FIRST lines — before ANY other import — jax locks device count
# on first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import SHAPES, MeshConfig, ModelConfig, ShapeConfig
from repro.configs.registry import ARCH_IDS, cell_status, get_config
from repro.launch import specs as specs_mod
from repro.launch.hlo_stats import collective_bytes
from repro.launch.mesh import make_production_mesh, mesh_config
from repro.models.transformer import init_model, prefill
from repro.serve.engine import make_serve_step
from repro.train.train_step import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# program builders — each returns (fn, args_abstract, in_shardings)
# ---------------------------------------------------------------------------

def _params_abstract(cfg: ModelConfig):
    return jax.eval_shape(partial(init_model, cfg), jax.random.key(0))


# §Perf hillclimb variants (EXPERIMENTS.md §Perf). ``model`` overrides go
# into ModelConfig; ``remat``/``microbatch`` into the run; ``sharding``
# picks the distributed/sharding.py rule variant.
VARIANTS = {
    "base": {},
    "dots": dict(remat="dots"),
    "dots_a1": dict(remat="dots", microbatch="full"),
    "flatdp": dict(remat="dots", microbatch="full", sharding="flat_dp"),
    "disp2s": dict(remat="dots", microbatch="full",
                   model=dict(dispatch_mode="2s")),
    "disp1s": dict(remat="dots", microbatch="full",
                   model=dict(dispatch_mode="1s")),
    "serve_ep": dict(sharding="serve", model=dict(expert_tp_axis="data")),
    # remat=none is feasible once A=1 shrinks live activations (per-device
    # block boundary ~34-42 MB × n_blocks ≈ 1 GB)
    "flatdp_nr": dict(remat="none", microbatch="full", sharding="flat_dp"),
    "a1_nr": dict(remat="none", microbatch="full"),
    # pipeline across pods (multipod only): stages replace cross-pod DP —
    # DCN carries activation permutes instead of gradient all-reduce
    "pp_pod": dict(pipeline=True),
}


def build_train_pp(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   mesh_cfg: MeshConfig, *, n_microbatches: int = 8):
    """GPipe over the pod axis; flat data-FSDP inside each stage.

    Mesh: the 512 devices re-axised to (data=256, pod=2) with the physical
    pod split preserved (devices.reshape(2,256).T). Two XLA partial-manual
    partitioner workarounds, both isolated empirically (see EXPERIMENTS
    §Perf PP note): the manual axis must be minor-most, and the embedding
    table must not be vocab-sharded (the gather resharding CHECK-fails in
    spmd_partitioner_util.cc:504) — embed/lm_head are replicated instead.

    Scope note (recorded in EXPERIMENTS §Perf): at 512 devices XLA can
    partition the PP **forward+loss** program (lowered here — its
    collective schedule is the artifact of interest: cross-pod traffic
    becomes activation permutes); the backward trips a second partitioner
    CHECK ("Invalid binary instruction opcode copy"). The full PP train
    step (loss+grads+update, bit-matching the non-PP path) is validated at
    small scale in tests/test_pipeline.py.
    """
    import numpy as _np
    from jax.sharding import Mesh, NamedSharding
    from repro.distributed.pipeline import gpipe_loss_fn
    n_pods = mesh_cfg.shape[0]
    devs = _np.asarray(mesh.devices).reshape(n_pods, -1)
    n_data = devs.shape[1]
    mesh = Mesh(devs.T, ("data", "pod"))
    run = specs_mod.make_run(cfg, shape, mesh_cfg)

    def fn(params, batch):
        return gpipe_loss_fn(cfg, params, batch, mesh=mesh,
                             n_microbatches=n_microbatches, remat="dots")

    params_abs = _params_abstract(cfg)

    def _fsdp(dims, start):
        spec = [None] * len(dims)
        for i in range(start, len(dims)):
            if dims[i] % n_data == 0:
                spec[i] = "data"
                break
        return spec

    def spec_of(path, leaf):
        keys = [str(getattr(p, "key", p)) for p in path]
        if keys[-1] in ("embed_tokens", "lm_head"):
            return P(*([None] * len(leaf.shape)))
        if "blocks" in keys:
            return P("pod", *_fsdp(leaf.shape, 1)[1:])
        return P(*_fsdp(leaf.shape, 0))

    p_specs = jax.tree_util.tree_map_with_path(spec_of, params_abs)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs)
    batch_abs = specs_mod.input_specs(cfg, shape)
    batch_sh = jax.tree.map(
        lambda l: NamedSharding(mesh, P("data", *([None] *
                                                  (len(l.shape) - 1)))),
        batch_abs)
    return fn, (params_abs, batch_abs), (p_sh, batch_sh), run


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh,
                mesh_cfg: MeshConfig, *, unroll=False, microbatch=0,
                remat=None, sharding="default"):
    run = specs_mod.make_run(cfg, shape, mesh_cfg, microbatch=microbatch)
    if remat:
        run = dataclasses.replace(
            run, train=dataclasses.replace(run.train, remat_policy=remat))
    dp = specs_mod.dp_entry_for(shape, mesh_cfg, sharding)
    fn = make_train_step(cfg, run, mesh=mesh, dp_entry=dp, unroll=unroll)
    params_abs = _params_abstract(cfg)
    state_abs = jax.eval_shape(
        partial(init_train_state, cfg, run.train), params_abs)
    state_sh = specs_mod.state_shardings(cfg, mesh, mesh_cfg, state_abs,
                                         sharding)
    batch_abs = specs_mod.input_specs(cfg, shape)
    batch_sh = specs_mod.batch_shardings(cfg, shape, mesh, mesh_cfg,
                                         batch_abs, sharding)
    return fn, (state_abs, batch_abs), (state_sh, batch_sh), run


def build_prefill(cfg: ModelConfig, shape: ShapeConfig, mesh,
                  mesh_cfg: MeshConfig, *, unroll=False,
                  sharding="default", **_):
    dp = specs_mod.dp_entry_for(shape, mesh_cfg)
    fn = partial(prefill, cfg, mesh=mesh, dp_entry=dp, unroll=unroll)
    params_abs = _params_abstract(cfg)
    p_sh = specs_mod.params_shardings(cfg, mesh, mesh_cfg, params_abs,
                                      sharding)
    batch_abs = specs_mod.input_specs(cfg, shape)
    batch_sh = specs_mod.batch_shardings(cfg, shape, mesh, mesh_cfg,
                                         batch_abs)
    return fn, (params_abs, batch_abs), (p_sh, batch_sh), None


def build_decode(cfg: ModelConfig, shape: ShapeConfig, mesh,
                 mesh_cfg: MeshConfig, *, unroll=False,
                 sharding="default", **_):
    dp = specs_mod.dp_entry_for(shape, mesh_cfg)
    fn = make_serve_step(cfg, mesh=mesh, dp_entry=dp, unroll=unroll)
    params_abs = _params_abstract(cfg)
    p_sh = specs_mod.params_shardings(cfg, mesh, mesh_cfg, params_abs,
                                      sharding)
    cache_abs, tok_abs, t_abs = specs_mod.decode_input_specs(cfg, shape)
    cache_sh = specs_mod.cache_shardings(cfg, shape, mesh, mesh_cfg,
                                         cache_abs)
    tok_sh = NamedSharding(mesh, P(dp, None))
    t_sh = NamedSharding(mesh, P())
    return fn, (params_abs, cache_abs, tok_abs, t_abs), \
        (p_sh, cache_sh, tok_sh, t_sh), None


def build_cell(cfg, shape, mesh, mesh_cfg, *, unroll=False, microbatch=0,
               remat=None, sharding="default"):
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, mesh_cfg, unroll=unroll,
                           microbatch=microbatch, remat=remat,
                           sharding=sharding)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, mesh_cfg, unroll=unroll,
                             sharding=sharding)
    return build_decode(cfg, shape, mesh, mesh_cfg, unroll=unroll,
                        sharding=sharding)


# ---------------------------------------------------------------------------
# lower + compile + measure
# ---------------------------------------------------------------------------

def _numeric(d) -> dict[str, float]:
    try:
        return {k: float(v) for k, v in dict(d).items()
                if isinstance(v, (int, float))}
    except Exception:
        return {}


def lower_compile(fn, args_abs, in_sh, *, want_text=True) -> dict[str, Any]:
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=in_sh).lower(*args_abs)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec: dict[str, Any] = {
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2)}
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = _numeric(ma) if ma is not None else None
        if not rec["memory_analysis"] and ma is not None:
            rec["memory_analysis"] = {
                k: float(getattr(ma, k)) for k in dir(ma)
                if not k.startswith("_")
                and isinstance(getattr(ma, k, None), (int, float))}
    except Exception as e:           # CPU backend may not implement it
        rec["memory_analysis"] = {"error": str(e)[:200]}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):           # jax 0.4.x
            ca = ca[0] if ca else {}
        rec["cost_analysis"] = {
            k: float(v) for k, v in (ca or {}).items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" not in k)}
    except Exception as e:
        rec["cost_analysis"] = {"error": str(e)[:200]}
    if want_text:
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt)
        rec["hlo_chars"] = len(txt)
    return rec


def _reduced_cfg(cfg: ModelConfig, nb: int) -> ModelConfig:
    return dataclasses.replace(
        cfg, n_layers=cfg.first_k_dense + nb * cfg.block_pattern)


def _extrapolate(c11, c21, c12, NB: int, A: int, keys=("flops",)):
    """Affine extrapolation of numeric dicts (see module docstring)."""
    out = {}
    for k in keys:
        a = c11.get(k, 0.0)
        dL = c21.get(k, 0.0) - a
        dA = (c12.get(k, 0.0) - a) if c12 else 0.0
        out[k] = a + (A - 1) * dA + A * (NB - 1) * dL
    return out


def calibrate(cfg: ModelConfig, shape: ShapeConfig, mesh,
              mesh_cfg: MeshConfig, *, microbatch=0, remat=None,
              sharding="default") -> dict[str, Any]:
    """Unrolled reduced-depth lowerings → exact full-program roofline terms."""
    run = specs_mod.make_run(cfg, shape, mesh_cfg, microbatch=microbatch)
    mb = run.resolved_microbatch()
    A_full = run.grad_accum_steps
    NB_full = cfg.n_scan_blocks

    def one(nb: int, A: int):
        c = _reduced_cfg(cfg, nb)
        if shape.kind == "train":
            sh = dataclasses.replace(shape, global_batch=mb * A)
            fn, args, in_sh, _ = build_train(c, sh, mesh, mesh_cfg,
                                             unroll=True, microbatch=mb,
                                             remat=remat, sharding=sharding)
        else:
            fn, args, in_sh, _ = build_cell(c, shape, mesh, mesh_cfg,
                                            unroll=True, sharding=sharding)
        return lower_compile(fn, args, in_sh)

    r11 = one(1, 1)
    r21 = one(2, 1)
    r12 = one(1, 2) if (shape.kind == "train" and A_full > 1) else None

    keys = ("flops", "bytes accessed")
    c11 = r11["cost_analysis"]; c21 = r21["cost_analysis"]
    c12 = r12["cost_analysis"] if r12 else None
    cost = _extrapolate(c11, c21, c12, NB_full, A_full, keys)

    ckeys = set(r11["collectives"]) | set(r21["collectives"])
    col11 = r11["collectives"]; col21 = r21["collectives"]
    col12 = r12["collectives"] if r12 else None
    coll = _extrapolate(col11, col21, col12 or {}, NB_full, A_full,
                        tuple(ckeys))
    return {
        "microbatch": mb, "grad_accum": A_full, "scan_blocks": NB_full,
        "flops_per_device": cost.get("flops", 0.0),
        "hbm_bytes_per_device": cost.get("bytes accessed", 0.0),
        "collective_bytes_per_device": coll,
        "variants": {"nb1_a1": r11, "nb2_a1": r21,
                     **({"nb1_a2": r12} if r12 else {})},
    }


# ---------------------------------------------------------------------------
# cell driver
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             do_calibrate: bool, out_dir: str,
             variant: str = "base") -> dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    v = dict(VARIANTS[variant])
    cfg = dataclasses.replace(cfg, **v.pop("model", {}))
    mb = v.pop("microbatch", 0)
    if mb == "full":
        mb = shape.global_batch
    remat = v.pop("remat", None)
    sharding = v.pop("sharding", "default")
    pipeline = v.pop("pipeline", False)
    mesh_name = "multipod" if multi_pod else "singlepod"
    rec: dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "variant": variant}
    runnable, why = cell_status(cfg, shape)
    if not runnable:
        rec.update(status="skip", reason=why)
        return _emit(rec, out_dir)
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_cfg = mesh_config(multi_pod=multi_pod)
        if pipeline:
            assert multi_pod and shape.kind == "train", \
                "pp_pod variant: multipod train cells only"
            fn, args, in_sh, run = build_train_pp(cfg, shape, mesh,
                                                  mesh_cfg)
        else:
            fn, args, in_sh, run = build_cell(cfg, shape, mesh, mesh_cfg,
                                              microbatch=mb, remat=remat,
                                              sharding=sharding)
        rec["full"] = lower_compile(fn, args, in_sh)
        if run is not None:
            rec["microbatch"] = run.resolved_microbatch()
            rec["grad_accum"] = run.grad_accum_steps
        if do_calibrate and not multi_pod:
            rec["calibration"] = calibrate(cfg, shape, mesh, mesh_cfg,
                                           microbatch=mb, remat=remat,
                                           sharding=sharding)
        rec["status"] = "ok"
    except Exception:
        rec["status"] = "fail"
        rec["error"] = traceback.format_exc()[-4000:]
    return _emit(rec, out_dir)


def _emit(rec, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if rec.get("variant", "base") == "base" \
        else f"__{rec['variant']}"
    path = os.path.join(
        out_dir,
        f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        ca = rec["full"].get("cost_analysis", {})
        extra = (f" flops/dev={ca.get('flops', 0):.3e}"
                 f" compile={rec['full']['compile_s']}s")
    print(f"[dryrun] {rec['arch']} × {rec['shape']} × {rec['mesh']}:"
          f" {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--variant", default="base", choices=sorted(VARIANTS))
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    n_fail = 0
    for arch in archs:
        for s in shapes:
            for mp in meshes:
                rec = run_cell(arch, s, multi_pod=mp,
                               do_calibrate=not args.no_calibrate,
                               out_dir=args.out_dir, variant=args.variant)
                n_fail += rec["status"] == "fail"
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
