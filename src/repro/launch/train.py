"""Production training driver.

Wires every substrate together: config → mesh → sharded state → double-
buffered data pipeline → jitted train step (decoupled grad sync, grad-accum,
remat) → async checkpointing → straggler tracking → restart.

On real hardware this launches under `jax.distributed` with the production
mesh; on this container it runs the same code on N local host devices (set
``--devices`` — the driver re-execs itself with XLA_FLAGS before jax
initializes, keeping the no-global-512 rule).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \\
        --steps 100 --batch 8 --seq 128 --devices 8 --mesh 2x4
"""
from __future__ import annotations

import argparse
import os
import sys


def _reexec_with_devices(n: int, argv):
    if os.environ.get("_REPRO_DEVICES") == str(n):
        return
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n}")
    env["_REPRO_DEVICES"] = str(n)
    args = argv if argv is not None else sys.argv[1:]
    os.execve(sys.executable, [sys.executable, "-m", "repro.launch.train",
                               *args], env)


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--mesh", default="",
                    help="DxM data×model, e.g. 2x4 (default: devices×1)")
    ap.add_argument("--dispatch", choices=["1s", "2s"], default="1s")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--vocab", type=int, default=0,
                    help="override vocab (synth data); 0 = config vocab")
    ap.add_argument("--tokens", type=int, default=2_000_000)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices > 1:
        _reexec_with_devices(args.devices, argv)

    import dataclasses
    import time

    import jax
    import numpy as np

    from repro.ckpt.checkpoint import CheckpointManager
    from repro.config import MeshConfig, ShapeConfig, TrainConfig
    from repro.configs.registry import get_config, get_smoke_config
    from repro.data.corpus import lm_token_stream
    from repro.data.pipeline import DoubleBufferedLoader, lm_batches
    from repro.distributed.mesh import local_mesh
    from repro.ft.straggler import ThroughputTracker
    from repro.launch import specs as sp
    from repro.models.transformer import init_model
    from repro.train.train_step import init_train_state, make_train_step

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = dataclasses.replace(cfg, dispatch_mode=args.dispatch)
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab_size=args.vocab)

    if args.mesh:
        d, m = map(int, args.mesh.split("x"))
    else:
        d, m = args.devices, 1
    assert d * m == args.devices, (d, m, args.devices)
    mesh_cfg = MeshConfig((d, m), ("data", "model"))
    mesh = local_mesh((d, m), ("data", "model"))

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = sp.make_run(cfg, shape, mesh_cfg, microbatch=args.microbatch)
    run = dataclasses.replace(run, train=TrainConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 1),
        total_steps=args.steps, seed=args.seed))
    dp = sp.dp_entry_for(shape, mesh_cfg)

    n_params_analytic = cfg.param_count()
    print(f"[train] {cfg.name}: {n_params_analytic/1e6:.1f}M params, "
          f"mesh {d}x{m}, batch {args.batch}x{args.seq}, "
          f"accum {run.grad_accum_steps}, dispatch {cfg.dispatch_mode}")

    params = init_model(cfg, jax.random.key(args.seed))
    state = init_train_state(cfg, run.train, params)
    state_sh = sp.state_shardings(cfg, mesh, mesh_cfg,
                                  jax.eval_shape(lambda: state))
    state = jax.device_put(state, state_sh)

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        if args.resume and mgr.latest_step() is not None:
            s, state, extra = mgr.restore(jax.eval_shape(lambda: state),
                                          shardings=state_sh)
            start_step = extra.get("next_step", s + 1)
            print(f"[train] resumed from step {s} -> starting {start_step}")

    toks = lm_token_stream(args.tokens, cfg.vocab_size, seed=args.seed)
    batch_sh = None
    it = lm_batches(toks, args.batch, args.seq, seed=args.seed,
                    skip=start_step)
    loader = DoubleBufferedLoader(it)

    step_fn = jax.jit(make_train_step(cfg, run, mesh=mesh, dp_entry=dp),
                      in_shardings=(state_sh, batch_sh),
                      donate_argnums=(0,))
    tracker = ThroughputTracker(n_procs=1)

    t_start = time.perf_counter()
    tokens_per_step = args.batch * args.seq
    losses = []
    for step, batch in zip(range(start_step, args.steps), loader):
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        tracker.update(np.asarray([dt]))
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"ce {float(metrics['ce']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{tokens_per_step/dt:,.0f} tok/s")
        if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save_async(step, state, extra={"next_step": step + 1})
    if mgr:
        mgr.save(args.steps - 1, state,
                 extra={"next_step": args.steps})
        mgr.wait()
    wall = time.perf_counter() - t_start
    n_done = args.steps - start_step
    print(f"[train] done: {n_done} steps in {wall:.1f}s "
          f"({n_done*tokens_per_step/wall:,.0f} tok/s), "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return losses


if __name__ == "__main__":
    main()
