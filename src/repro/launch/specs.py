"""Input specs + sharding assembly for every (arch × shape × mesh) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation. The sharding builders
map each abstract tree onto the mesh via distributed/sharding.py rules.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import (MeshConfig, ModelConfig, RunConfig, ShapeConfig,
                          TrainConfig)
from repro.distributed import sharding as shd
from repro.models import transformer as tf


# ---------------------------------------------------------------------------
# frontend geometry
# ---------------------------------------------------------------------------

def vlm_prefix_len(seq_len: int) -> int:
    return min(1024, seq_len // 4)


def frontend_geometry(cfg: ModelConfig, shape: ShapeConfig
                      ) -> tuple[int, int, int]:
    """(text_len, frontend_len, enc_len). seq_len budgets the full context
    (image prefix + text for VLM; decoder length for audio)."""
    S = shape.seq_len
    if cfg.frontend == "vision_stub":
        f = vlm_prefix_len(S)
        return S - f, f, 0
    if cfg.n_enc_layers:
        enc = S // max(cfg.enc_seq_factor, 1)
        return S, enc, enc
    return S, 0, 0


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Batch stand-ins for train/prefill; decode uses decode_input_specs."""
    B = shape.global_batch
    S_text, S_f, _ = frontend_geometry(cfg, shape)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32)}
    if shape.is_train:
        batch["labels"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
    if S_f:
        batch["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, S_f, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache, tokens_t, t) stand-ins for one serve_step at context
    seq_len."""
    B = shape.global_batch
    S_ctx, _, enc_len = frontend_geometry(cfg, shape)
    S_max = shape.seq_len
    cache = jax.eval_shape(
        lambda: tf.init_cache(cfg, B, S_max, enc_len=enc_len))
    tokens_t = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens_t, t


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def dp_entry_for(shape: ShapeConfig, mesh_cfg: MeshConfig,
                 variant: str = "default"):
    B = shape.global_batch
    if variant == "flat_dp" and B % mesh_cfg.n_devices == 0:
        return tuple(mesh_cfg.axes)        # batch over the whole mesh
    if B % mesh_cfg.dp_size == 0:
        axes = mesh_cfg.dp_axes
        return axes[0] if len(axes) == 1 else tuple(axes)
    for ax, sz in zip(mesh_cfg.axes, mesh_cfg.shape):
        if ax == "data" and B % sz == 0:
            return "data"
    return None


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    mesh_cfg: MeshConfig, batch_struct,
                    variant: str = "default"):
    dp = dp_entry_for(shape, mesh_cfg, variant)

    def spec(path_leaf):
        nd = len(path_leaf.shape)
        return NamedSharding(mesh, P(dp, *([None] * (nd - 1))))

    return jax.tree.map(spec, batch_struct)


def params_shardings(cfg: ModelConfig, mesh, mesh_cfg: MeshConfig,
                     abstract_params, variant: str = "default"):
    specs = shd.param_specs(abstract_params, cfg, mesh_cfg, variant)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def state_shardings(cfg: ModelConfig, mesh, mesh_cfg: MeshConfig,
                    abstract_state, variant: str = "default"):
    """TrainState(params, AdamWState(step, mu, nu), residual)."""
    p_sh = params_shardings(cfg, mesh, mesh_cfg, abstract_state.params,
                            variant)
    from repro.train.train_step import TrainState
    from repro.optim.adamw import AdamWState
    step_sh = NamedSharding(mesh, P())
    res = abstract_state.residual
    return TrainState(
        params=p_sh,
        opt=AdamWState(step=step_sh, mu=p_sh, nu=p_sh),
        residual=None if res is None else p_sh,
    )


def _cache_leaf_spec(name: str, shape: tuple[int, ...], cfg: ModelConfig,
                     mesh_cfg: MeshConfig, dp) -> P:
    tp = mesh_cfg.tp_size
    if name in ("k", "v", "cross_k", "cross_v"):     # (B, S, KV, hd)
        seq_ok = shape[1] % tp == 0
        return P(dp, "model" if seq_ok else None, None, None)
    if name == "ckv":                                 # (B, S, lora+rope)
        seq_ok = shape[1] % tp == 0
        return P(dp, "model" if seq_ok else None, None)
    if name == "state":                               # (B, H, P, N)
        return P(dp, "model" if shape[1] % tp == 0 else None, None, None)
    if name.startswith("conv_"):                      # (B, K-1, C)
        return P(dp, None, "model" if shape[2] % tp == 0 else None)
    return P(dp, *([None] * (len(shape) - 1)))


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    mesh_cfg: MeshConfig, cache_struct):
    dp = dp_entry_for(shape, mesh_cfg)

    def visit(path, leaf):
        keys = [getattr(p, "key", str(p)) for p in path]
        name = keys[-1]
        stacked = "blocks" in keys
        shp = leaf.shape[1:] if stacked else leaf.shape
        spec = _cache_leaf_spec(name, shp, cfg, mesh_cfg, dp)
        if stacked:
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(visit, cache_struct)


# ---------------------------------------------------------------------------
# per-arch training config (memory-driven numerics)
# ---------------------------------------------------------------------------

def train_config_for(cfg: ModelConfig) -> TrainConfig:
    big = cfg.param_count() > 100e9
    return TrainConfig(
        moment_dtype="bfloat16" if big else "float32",
        accum_dtype="bfloat16" if big else "float32",
        remat_policy="full",
    )


def make_run(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
             **kw) -> RunConfig:
    return RunConfig(model=cfg, shape=shape, mesh=mesh_cfg,
                     train=train_config_for(cfg), **kw)
