"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls ``make_production_mesh``.
"""
from __future__ import annotations

import jax

from repro.config import MULTI_POD, SINGLE_POD, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD
