"""Collective-byte accounting from compiled (post-SPMD-partitioning) HLO.

``cost_analysis`` does not expose collective traffic, so we parse the
per-device HLO text. Operand types are %refs in HLO text, so we read each
collective's **result** shape (inline on the defining line) plus its
``replica_groups`` size, and convert to per-device **wire bytes** with ring
factors:

    all-gather         result · (g-1)/g        (result = gathered tensor)
    reduce-scatter     result · (g-1)          (result = scattered shard)
    all-reduce         result · 2(g-1)/g
    all-to-all         result · (g-1)/g
    collective-permute result                  (point-to-point)

Shapes in the partitioned module are per-device, so totals are per-device
bytes over the busiest link under a ring schedule — the roofline layer
divides by per-link bandwidth directly.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# defining line: "%name = <result-type> <kind>[-start|-done](..."
_LINE_RE = re.compile(
    r"=\s+(\([^=]*?\)|\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")

# replica_groups=[n_groups,group_size]<=...   (iota form)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# replica_groups={{0,1,2},{...}}              (explicit form)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")

_WIRE = {
    "all-gather": lambda b, g: b * (g - 1) / g,
    "reduce-scatter": lambda b, g: b * (g - 1),
    "all-reduce": lambda b, g: b * 2 * (g - 1) / g,
    "all-to-all": lambda b, g: b * (g - 1) / g,
    "collective-permute": lambda b, g: float(b),
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x != ""]), 1)
    return 2  # collective-permute / unknown: factor cancels anyway


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device wire bytes + op counts per collective kind.

    Returns {kind: bytes, ..., "total": bytes, "n_<kind>": count}.
    Async pairs are counted at -start (last tuple element = output buffer);
    -done lines are skipped.
    """
    out: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        result_type, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        shapes = _SHAPE_RE.findall(result_type)
        if not shapes:
            continue
        if suffix == "-start":
            shapes = shapes[-1:]          # (operand, result) tuple: output
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _group_size(line)
        out[kind] += _WIRE[kind](b, g)
        out[f"{kind}_result_bytes"] += b
        counts[kind] += 1
    rec = {k: v for k, v in out.items()}
    rec["total"] = sum(v for k, v in out.items()
                       if not k.endswith("_result_bytes"))
    for k, c in counts.items():
        rec[f"n_{k}"] = c
    return rec
