"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Chunked SSD algorithm: within a chunk the recurrence is computed as a masked
(attention-like) quadratic form; across chunks a linear state pass carries
(H, P, N) states. This is itself a decoupled producer/consumer pipeline —
intra-chunk compute overlaps the inter-chunk state pass on TPU (DESIGN.md).

Shapes (SSD convention):
  x   (B, S, H, P)   P = head dim
  dt  (B, S, H)      softplus-activated step sizes
  A   (H,)           negative decay rate (from A_log)
  B,C (B, S, G, N)   G groups (=1 here), N = ssm_state
  y   (B, S, H, P)

The Pallas kernel (kernels/ssd_scan) implements the same chunked algorithm;
``ssd_ref`` here is its oracle and the dry-run path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.layers import _init, rms_over


# ---------------------------------------------------------------------------
# core SSD math (oracle shared with kernels/ssd_scan/ref.py)
# ---------------------------------------------------------------------------

def ssd_ref(x, dt, A, B, C, *, chunk: int = 256, init_state=None):
    """Chunked SSD. Returns (y, final_state (B,H,P,N)).

    S need not divide the chunk: inputs are zero-padded (dt=0 ⇒ identity
    decay, zero update — padding is exactly a no-op on the recurrence)."""
    Bb, S, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    assert H % G == 0
    chunk = min(chunk, S)
    S_orig = S
    if S % chunk:
        pad = chunk - S % chunk
        padf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = padf(x), padf(dt), padf(B), padf(C)
        S = S + pad
    nc = S // chunk

    xc = x.reshape(Bb, nc, chunk, H, Pd)
    dtc = dt.reshape(Bb, nc, chunk, H)
    Bc = B.reshape(Bb, nc, chunk, G, N)
    Cc = C.reshape(Bb, nc, chunk, G, N)
    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=3)                   # (B,nc,c,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]                  # (B,nc,c,H) negative
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum

    # --- intra-chunk (quadratic, causal-masked) ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,i,j,H)
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores (z = chunk index, i/j = positions, s = state dim)
    s = jnp.einsum("bzihs,bzjhs->bzijh", Ch, Bh,
                   preferred_element_type=jnp.float32)     # (B,nc,i,j,H)
    s = s * L
    xdt = xc * dtc[..., None]                               # dt-weighted input
    y_intra = jnp.einsum("bzijh,bzjhp->bzihp", s, xdt,
                         preferred_element_type=jnp.float32)

    # --- chunk states: state_n = sum_j exp(cum_last - cum_j) dt_j B_j x_j ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,c,H)
    states = jnp.einsum("bzchs,bzchp,bzch->bzhps", Bh, xdt, decay_to_end,
                        preferred_element_type=jnp.float32)

    # --- inter-chunk recurrence over nc (the decoupled state pass) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    def pass_state(carry, inp):
        st, dec = inp                                       # (B,H,P,N),(B,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                   # emit incoming

    init = (jnp.zeros((Bb, H, Pd, N), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final, prev_states = lax.scan(
        pass_state, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (B,nc,H,P,N)

    # --- contribution of carried-in state to each position ---
    decay_from_start = jnp.exp(cum)                         # (B,nc,c,H)
    y_inter = jnp.einsum("bzchs,bzhps,bzch->bzchp", Ch, prev_states,
                         decay_from_start,
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(Bb, S, H, Pd)[:, :S_orig]
    return y.astype(x.dtype), final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """Single-token recurrence. state: (B,H,P,N); x_t: (B,H,P);
    dt_t: (B,H); B_t/C_t: (B,G,N)."""
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t, rep, axis=1)                       # (B,H,N)
    Ch = jnp.repeat(C_t, rep, axis=1)
    dA = jnp.exp(dt_t * A[None, :])[..., None, None]        # (B,H,1,1)
    upd = (dt_t[..., None] * x_t)[..., None] * Bh[:, :, None, :]
    state = state * dA + upd                                # (B,H,P,N)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch,
                   preferred_element_type=jnp.float32)
    return y.astype(x_t.dtype), state


# ---------------------------------------------------------------------------
# the full block (projections, conv, gating)
# ---------------------------------------------------------------------------

def init_ssm(cfg: ModelConfig, key) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    G, N, K = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv
    H = cfg.n_ssm_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "w_z": _init(ks[0], (d, di), s, dt),
        "w_x": _init(ks[1], (d, di), s, dt),
        "w_B": _init(ks[2], (d, G * N), s, dt),
        "w_C": _init(ks[3], (d, G * N), s, dt),
        "w_dt": _init(ks[4], (d, H), s, dt),
        "dt_bias": jnp.zeros((H,), dt),
        "conv_x": _init(ks[5], (K, di), K ** -0.5, dt),
        "conv_B": _init(ks[6], (K, G * N), K ** -0.5, dt),
        "conv_C": _init(ks[7], (K, G * N), K ** -0.5, dt),
        "A_log": jnp.zeros((H,), dt),        # A = -exp(A_log) = -1
        "D_skip": jnp.ones((H,), dt),
        "gate_norm": jnp.ones((di,), dt),
        "w_out": _init(jax.random.fold_in(key, 9), (di, d), di ** -0.5, dt),
    }


def _causal_conv(u, w, carry=None):
    """Depthwise causal conv. u: (B, S, C); w: (K, C). carry: (B, K-1, C)."""
    K = w.shape[0]
    if carry is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = carry.astype(u.dtype)
    up = jnp.concatenate([pad, u], 1)
    out = sum(up[:, i:i + u.shape[1]] * w[i][None, None] for i in range(K))
    new_carry = up[:, -(K - 1):] if K > 1 else pad
    return jax.nn.silu(out), new_carry


def ssm_forward(cfg: ModelConfig, p: dict, x, *, use_pallas=False,
                init_state=None, conv_carry=None):
    """x: (B, S, D) -> (B, S, D), cache {"state","conv_x","conv_B","conv_C"}."""
    B_, S, _ = x.shape
    H, Pd = cfg.n_ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    z = x @ p["w_z"]
    u = x @ p["w_x"]
    Bp = x @ p["w_B"]
    Cp = x @ p["w_C"]
    dt = jax.nn.softplus((x @ p["w_dt"]) + p["dt_bias"])
    cc = conv_carry or {}
    u, cx = _causal_conv(u, p["conv_x"], cc.get("conv_x"))
    Bp, cb = _causal_conv(Bp, p["conv_B"], cc.get("conv_B"))
    Cp, cC = _causal_conv(Cp, p["conv_C"], cc.get("conv_C"))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = u.reshape(B_, S, H, Pd)
    if use_pallas:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, state = ssd_ops.ssd(xh, dt, A, Bp.reshape(B_, S, G, N),
                               Cp.reshape(B_, S, G, N), chunk=cfg.ssm_chunk,
                               init_state=init_state)
    else:
        y, state = ssd_ref(xh, dt, A, Bp.reshape(B_, S, G, N),
                           Cp.reshape(B_, S, G, N), chunk=cfg.ssm_chunk,
                           init_state=init_state)
    y = y + xh * p["D_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B_, S, cfg.d_inner)
    y = rms_over(y * jax.nn.silu(z), p["gate_norm"])
    cache = {"state": state, "conv_x": cx, "conv_B": cb, "conv_C": cC}
    return y @ p["w_out"], cache


def ssm_decode(cfg: ModelConfig, p: dict, x, cache: dict):
    """One-token step. x: (B, 1, D)."""
    B_ = x.shape[0]
    H, Pd = cfg.n_ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    z = x @ p["w_z"]
    u = x @ p["w_x"]
    Bp = x @ p["w_B"]
    Cp = x @ p["w_C"]
    dt = jax.nn.softplus((x @ p["w_dt"]) + p["dt_bias"])
    u, cx = _causal_conv(u, p["conv_x"], cache["conv_x"])
    Bp, cb = _causal_conv(Bp, p["conv_B"], cache["conv_B"])
    Cp, cC = _causal_conv(Cp, p["conv_C"], cache["conv_C"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, state = ssd_decode_step(cache["state"], u[:, 0].reshape(B_, H, Pd),
                               dt[:, 0], A, Bp[:, 0].reshape(B_, G, N),
                               Cp[:, 0].reshape(B_, G, N))
    y = y + u[:, 0].reshape(B_, H, Pd) * p["D_skip"][None, :, None].astype(
        y.dtype)
    y = y.reshape(B_, 1, cfg.d_inner)
    y = rms_over(y * jax.nn.silu(z), p["gate_norm"])
    cache = {"state": state, "conv_x": cx, "conv_B": cb, "conv_C": cC}
    return y @ p["w_out"], cache
