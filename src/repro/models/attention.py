"""Attention: GQA / MHA / sliding-window / MLA, with train, prefill and
decode paths.

Layout contracts
  activations      (B, S, D)
  q/k/v            (B, S, H|KV, hd)
  GQA/SWA cache    {"k","v"}: (B, S_max, KV, hd)   — seq-sharded over "model"
  SWA cache        ring buffer, S_max = window      — replicated (small)
  MLA cache        {"ckv"}: (B, S_max, lora+rope)   — seq-sharded over "model"

Decode uses a flash-decode scheme: every model shard computes online-softmax
partials over its *sequence slice* of the cache for all heads, then the
partials combine with a max-stabilized psum. This is the uniform layout that
fits 32k–512k caches for every kv_heads count (DESIGN.md §7).

The chunked reference attention here doubles as the Pallas flash kernel's
oracle (kernels/flash_attention/ref.py re-exports it).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.collectives import shard_map
from repro.config import ModelConfig
from repro.models.layers import _init, apply_rope, rms_over

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    p = {
        "wq": _init(ks[0], (d, H * hd), s, dt),
        "wk": _init(ks[1], (d, KV * hd), s, dt),
        "wv": _init(ks[2], (d, KV * hd), s, dt),
        "wo": _init(ks[3], (H * hd, d), (H * hd) ** -0.5, dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def init_mla(cfg: ModelConfig, key) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope_d, v_d = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": _init(ks[0], (d, H * (nope + rope_d)), s, dt),
        "w_kv_a": _init(ks[1], (d, lora + rope_d), s, dt),
        "w_kv_b": _init(ks[2], (lora, H * (nope + v_d)), lora ** -0.5, dt),
        "wo": _init(ks[3], (H * v_d, d), (H * v_d) ** -0.5, dt),
        "kv_norm": jnp.ones((lora,), dt),
    }


# ---------------------------------------------------------------------------
# chunked reference attention (flash oracle)
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        q_offset: int = 0):
    """Online-softmax chunked attention.

    q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd) with H % KV == 0.
    ``window > 0``: sliding-window (banded) — only the KV band that can be
    seen by each q chunk is touched, so cost is O(Sq * window).
    ``q_offset``: absolute position of q[0] (cross-chunk prefill).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = math.ceil(Sq / q_chunk)
    scale = hd ** -0.5

    # pad both sequence axes to chunk multiples; padded kv is masked via
    # ``kv_pos < Skv``, padded q rows are sliced off at the end
    Sq_pad = nq * q_chunk
    Skv_pad = math.ceil(Skv / kv_chunk) * kv_chunk
    if Sq_pad != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_pad - Sq), (0, 0), (0, 0)))
    if Skv_pad != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_pad - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_pad - Skv), (0, 0), (0, 0)))

    qg = q.reshape(B, Sq_pad, KV, G, hd)

    if window > 0:
        band = int(min(Skv_pad,
                       (math.ceil((window + q_chunk) / kv_chunk) + 1)
                       * kv_chunk))
    else:
        band = Skv_pad
    nkv = band // kv_chunk

    def q_step(i):
        q_i = lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1)
        q_i = (q_i * scale).astype(q.dtype)
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        if window > 0:
            start = jnp.clip(q_offset + (i + 1) * q_chunk - band, 0,
                             Skv_pad - band)
        else:
            start = 0
        k_b = lax.dynamic_slice_in_dim(k, start, band, 1)
        v_b = lax.dynamic_slice_in_dim(v, start, band, 1)

        def kv_step(carry, j):
            m, l, acc = carry
            k_j = lax.dynamic_slice_in_dim(k_b, j * kv_chunk, kv_chunk, 1)
            v_j = lax.dynamic_slice_in_dim(v_b, j * kv_chunk, kv_chunk, 1)
            kv_pos = start + j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqkgh,bckh->bkgqc", q_i, k_j,
                           preferred_element_type=jnp.float32)
            mask = jnp.broadcast_to(kv_pos[None, :] < Skv,
                                    (q_chunk, kv_chunk))
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            p = jnp.exp(s - m_new[..., None])
            # rows fully masked so far have m_new == NEG_INF and would get
            # p = exp(0) = 1 on masked entries — zero them explicitly
            p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, -1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckh->bkgqh", p.astype(v.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, G, q_chunk, hd) -> (B, q_chunk, H, hd)
        return jnp.moveaxis(o, 3, 1).reshape(B, q_chunk, H, hd).astype(q.dtype)

    outs = lax.map(q_step, jnp.arange(nq))            # (nq, B, qc, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, hd)[:, :Sq]


def flash_attention_costexact(q, k, v, *, causal: bool = True,
                              window: int = 0, n_q_chunks: int = 8,
                              q_offset: int = 0):
    """Unrolled, tile-skipping attention — the dry-run cost instrument.

    Python-loops over q chunks (so HLO carries every tile and
    ``cost_analysis`` counts them all — scans are counted once, see
    DESIGN.md §9) and slabs the kv range each q chunk can actually see
    (causal triangle / SWA band), mirroring the Pallas kernel's pl.when
    tile skipping. FLOPs in the lowered HLO == FLOPs the TPU kernel
    executes, at chunk granularity.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    c = max(128, -(-Sq // n_q_chunks))
    nq = -(-Sq // c)
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)
    outs = []
    for i in range(nq):
        lo_q = i * c
        hi_q = min(Sq, lo_q + c)
        cq = hi_q - lo_q
        q_i = (qg[:, lo_q:hi_q] * scale).astype(q.dtype)
        abs_hi = q_offset + hi_q
        hi_kv = min(Skv, abs_hi) if causal else Skv
        lo_kv = max(0, q_offset + lo_q - window + 1) if window > 0 else 0
        k_s = k[:, lo_kv:hi_kv]
        v_s = v[:, lo_kv:hi_kv]
        s = jnp.einsum("bqkgh,bckh->bkgqc", q_i, k_s,
                       preferred_element_type=jnp.float32)
        q_pos = q_offset + lo_q + jnp.arange(cq)
        kv_pos = lo_kv + jnp.arange(hi_kv - lo_kv)
        mask = jnp.ones((cq, hi_kv - lo_kv), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, -1)
        o = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v.dtype), v_s,
                       preferred_element_type=jnp.float32)
        outs.append(jnp.moveaxis(o, 3, 1).reshape(B, cq, H, hd)
                    .astype(q.dtype))
    return jnp.concatenate(outs, axis=1)


def attention_dense_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """O(S^2)-memory oracle for tests."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k).astype(jnp.float32)
    s *= hd ** -0.5
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos[None] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(v.dtype), v)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# flash-decode core (seq-sharded cache)
# ---------------------------------------------------------------------------

def _decode_partials(q, k, v, kv_pos, t):
    """Per-shard online softmax over a cache slice.

    q: (B, H, hd); k/v: (B, S_loc, KV, hd); kv_pos: (S_loc,) absolute
    positions; t: current length (positions >= t are invalid).
    Returns (o_partial, l, m) for max-stabilized combining.
    """
    B, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k,
                   preferred_element_type=jnp.float32)
    valid = ((kv_pos >= 0) & (kv_pos < t))[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, -1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid, p, 0.0)
    l = jnp.sum(p, -1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, l, m


def combine_partials(o, l, m, axis: str | None):
    """Combine (o, l, m) partials across ``axis`` (None -> single shard)."""
    if axis is None:
        return (o / jnp.maximum(l, 1e-30)[..., None])
    m_glob = lax.pmax(m, axis)
    corr = jnp.exp(m - m_glob)
    l_glob = lax.psum(l * corr, axis)
    o_glob = lax.psum(o * corr[..., None], axis)
    return o_glob / jnp.maximum(l_glob, 1e-30)[..., None]


def decode_attention_sharded(q, cache_k, cache_v, t, *, mesh, dp_entry,
                             seq_axis: str = "model"):
    """Flash-decode with the cache sequence-sharded over ``seq_axis``.

    q: (B, H, hd) replicated over model; cache: (B, S_max, KV, hd) sharded
    P(dp, model). New k/v must already be written (see update_cache_sharded).
    """
    from jax.sharding import PartitionSpec as P

    B, H, hd = q.shape
    S_max = cache_k.shape[1]

    def inner(q_b, k_b, v_b, t_b):
        S_loc = k_b.shape[1]
        idx = lax.axis_index(seq_axis)
        kv_pos = idx * S_loc + jnp.arange(S_loc)
        o, l, m = _decode_partials(q_b, k_b, v_b, kv_pos, t_b)
        o = combine_partials(o, l, m, seq_axis)
        B_, KV, G, _ = o.shape
        return o.reshape(B_, KV * G, hd).astype(q.dtype)

    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp_entry, None, None), P(dp_entry, seq_axis, None, None),
                  P(dp_entry, seq_axis, None, None), P()),
        out_specs=P(dp_entry, None, None),
    )(q, cache_k, cache_v, t)


def update_cache_sharded(cache, new, t, *, mesh, dp_entry,
                         seq_axis: str = "model"):
    """Write one token's k/v (B, KV, hd) at absolute position t into a
    seq-sharded cache (B, S_max, KV, hd). Only the owning shard writes."""
    from jax.sharding import PartitionSpec as P

    def inner(c, n, t_b):
        S_loc = c.shape[1]
        idx = lax.axis_index(seq_axis)
        local = t_b - idx * S_loc
        in_range = (local >= 0) & (local < S_loc)
        pos = jnp.clip(local, 0, S_loc - 1)
        updated = lax.dynamic_update_slice_in_dim(c, n[:, None], pos, 1)
        return jnp.where(in_range, updated, c)

    return shard_map(
        inner, mesh=mesh,
        in_specs=(P(dp_entry, seq_axis, None, None),
                  P(dp_entry, None, None), P()),
        out_specs=P(dp_entry, seq_axis, None, None),
    )(cache, new, t)


# ---------------------------------------------------------------------------
# full attention layer (projections + modes)
# ---------------------------------------------------------------------------

def _qkv(cfg: ModelConfig, p: dict, x, kv_x=None):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kv_x = x if kv_x is None else kv_x
    Skv = kv_x.shape[1]
    q = x @ p["wq"]
    k = kv_x @ p["wk"]
    v = kv_x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, Skv, KV, hd)
    v = v.reshape(B, Skv, KV, hd)
    if "q_norm" in p:
        q = rms_over(q, p["q_norm"])
        k = rms_over(k, p["k_norm"])
    return q, k, v


def attention_forward(cfg: ModelConfig, p: dict, x, positions, *,
                      causal=True, use_pallas=False, unroll=False):
    """Train / prefill pass. Returns (out, (k, v)) — k/v feed the cache."""
    q, k, v = _qkv(cfg, p, x)
    q = _rope_bshd(q, positions, cfg.rope_theta)
    k = _rope_bshd(k, positions, cfg.rope_theta)
    window = cfg.sliding_window if cfg.attn_type == "swa" else 0
    if use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    elif unroll:
        o = flash_attention_costexact(q, k, v, causal=causal, window=window)
    else:
        o = flash_attention_ref(q, k, v, causal=causal, window=window)
    B, S, H, hd = q.shape
    return o.reshape(B, S, H * hd) @ p["wo"], (k, v)


def _rope_bshd(x, positions, theta):
    """RoPE on (B, S, N, hd) with positions (B, S)."""
    xt = x.swapaxes(1, 2)                    # (B, N, S, hd)
    xt = apply_rope(xt, positions[:, None, :], theta)
    return xt.swapaxes(1, 2)


def attention_decode(cfg: ModelConfig, p: dict, x, cache: dict, t, *,
                     mesh=None, dp_entry=None):
    """One-token decode. x: (B, 1, D); cache {"k","v"}: (B, S_max, KV, hd)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q, k, v = _qkv(cfg, p, x)
    pos = jnp.full((B, 1), t, jnp.int32)
    q = _rope_bshd(q, pos, cfg.rope_theta)
    k = _rope_bshd(k, pos, cfg.rope_theta)
    q1, k1, v1 = q[:, 0], k[:, 0], v[:, 0]

    if cfg.attn_type == "swa":
        # ring-buffer cache of size window — replicated (small)
        W = cache["k"].shape[1]
        slot = t % W
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
        kv_pos = t - ((slot - jnp.arange(W)) % W)     # absolute position/slot
        o, l, m = _decode_partials(q1, ck, cv, kv_pos, t + 1)
        o = combine_partials(o, l, m, None)
        o = o.reshape(B, H, hd)
        new_cache = {"k": ck, "v": cv}
    elif mesh is not None:
        ck = update_cache_sharded(cache["k"], k1, t, mesh=mesh,
                                  dp_entry=dp_entry)
        cv = update_cache_sharded(cache["v"], v1, t, mesh=mesh,
                                  dp_entry=dp_entry)
        o = decode_attention_sharded(q1, ck, cv, t + 1, mesh=mesh,
                                     dp_entry=dp_entry)
        new_cache = {"k": ck, "v": cv}
    else:
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k, t, 1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v, t, 1)
        kv_pos = jnp.arange(ck.shape[1])
        o, l, m = _decode_partials(q1, ck, cv, kv_pos, t + 1)
        o = combine_partials(o, l, m, None).reshape(B, H, hd)
        new_cache = {"k": ck, "v": cv}

    o = o.reshape(B, 1, H * hd).astype(x.dtype)
    return o @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek) — prefill materializes k/v; decode runs absorbed over the
# compressed cache.
# ---------------------------------------------------------------------------

def _mla_expand(cfg, p, ckv):
    """ckv: (B, S, lora) -> k_nope, v: (B, S, H, nope|v)."""
    B, S, _ = ckv.shape
    H, nope, v_d = cfg.n_heads, cfg.qk_nope_dim, cfg.v_head_dim
    kv = ckv @ p["w_kv_b"]
    kv = kv.reshape(B, S, H, nope + v_d)
    return kv[..., :nope], kv[..., nope:]


def mla_forward(cfg: ModelConfig, p: dict, x, positions, *, use_pallas=False,
                unroll=False):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d, v_d = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = _rope_bshd(q_rope, positions, cfg.rope_theta)
    a = x @ p["w_kv_a"]                                 # (B,S,lora+rope)
    ckv = rms_over(a[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = _rope_bshd(a[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)                 # (B,S,1,rope)
    k_nope, v = _mla_expand(cfg, p, ckv)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate([k_nope,
                              jnp.broadcast_to(k_rope,
                                               k_nope.shape[:-1] + (rope_d,))],
                             -1)
    # pad v to the qk head dim so the shared flash path applies, then slice
    fa = flash_attention_costexact if unroll else flash_attention_ref
    o = fa(q_full, k_full,
           jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                       (0, nope + rope_d - v_d))),
           causal=True)[..., :v_d]
    o = o.reshape(B, S, H * v_d)
    cache = {"ckv": jnp.concatenate([ckv, k_rope[:, :, 0]], -1)}
    return o @ p["wo"], cache


def mla_decode(cfg: ModelConfig, p: dict, x, cache: dict, t, *,
               mesh=None, dp_entry=None):
    """Absorbed MLA decode over the compressed cache (B, S_max, lora+rope)."""
    from jax.sharding import PartitionSpec as P
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope_d, v_d = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    pos = jnp.full((B, 1), t, jnp.int32)
    q = (x @ p["wq"]).reshape(B, 1, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = _rope_bshd(q_rope, pos, cfg.rope_theta)[:, 0]     # (B,H,rope)
    a = (x @ p["w_kv_a"])[:, 0]                                # (B,lora+rope)
    ckv_new = rms_over(a[..., :lora], p["kv_norm"])
    kr_new = apply_rope(a[:, None, lora:], pos, cfg.rope_theta)[:, 0]
    entry = jnp.concatenate([ckv_new, kr_new], -1)             # (B,lora+rope)

    # absorb W_kv_b's key half into q:  q_lora = q_nope @ W_b_k^T per head
    w_b = p["w_kv_b"].reshape(lora, H, nope + v_d)
    w_b_k = w_b[..., :nope]                                    # (lora,H,nope)
    w_b_v = w_b[..., nope:]                                    # (lora,H,v)
    q_lora = jnp.einsum("bhn,lhn->bhl", q_nope[:, 0], w_b_k)   # (B,H,lora)
    qq = jnp.concatenate([q_lora, q_rope], -1)                 # (B,H,lora+rope)

    def inner(qq_b, cache_b, entry_b, t_b):
        S_loc = cache_b.shape[1]
        if mesh is not None:
            idx = lax.axis_index("model")
        else:
            idx = 0
        local = t_b - idx * S_loc
        in_range = (local >= 0) & (local < S_loc)
        posi = jnp.clip(local, 0, S_loc - 1)
        upd = lax.dynamic_update_slice_in_dim(cache_b, entry_b[:, None],
                                              posi, 1)
        cache_b = jnp.where(in_range, upd, cache_b)
        kv_pos = idx * S_loc + jnp.arange(S_loc)
        s = jnp.einsum("bhl,bsl->bhs", qq_b, cache_b,
                       preferred_element_type=jnp.float32)
        s *= (nope + rope_d) ** -0.5
        valid = (kv_pos < t_b + 1)[None, None]
        s = jnp.where(valid, s, NEG_INF)
        m = jnp.max(s, -1)
        pr = jnp.where(valid, jnp.exp(s - m[..., None]), 0.0)
        l = jnp.sum(pr, -1)
        o_l = jnp.einsum("bhs,bsl->bhl", pr.astype(cache_b.dtype),
                         cache_b[..., :lora],
                         preferred_element_type=jnp.float32)
        if mesh is not None:
            m_g = lax.pmax(m, "model")
            corr = jnp.exp(m - m_g)
            l_g = lax.psum(l * corr, "model")
            o_l = lax.psum(o_l * corr[..., None], "model")
        else:
            l_g = l
        o_l = o_l / jnp.maximum(l_g, 1e-30)[..., None]
        return o_l.astype(x.dtype), cache_b

    if mesh is not None:
        o_l, new_cache = shard_map(
            inner, mesh=mesh,
            in_specs=(P(dp_entry, None, None),
                      P(dp_entry, "model", None), P(dp_entry, None), P()),
            out_specs=(P(dp_entry, None, None), P(dp_entry, "model", None)),
        )(qq, cache["ckv"], entry, t)
    else:
        o_l, new_cache = inner(qq, cache["ckv"], entry, t)
    # un-absorb the value half:  o = o_lora @ W_b_v per head
    o = jnp.einsum("bhl,lhv->bhv", o_l.astype(jnp.float32),
                   w_b_v.astype(jnp.float32))
    o = o.reshape(B, 1, H * v_d).astype(x.dtype)
    return o @ p["wo"], {"ckv": new_cache}
