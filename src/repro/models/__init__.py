from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_model, loss_fn, prefill)
