"""Mixture-of-Experts with the paper's decoupled dispatch as a first-class
feature.

``router(token) -> expert`` is exactly the paper's ``hash(key) -> owner``:
tokens are key-value records, experts their owners, and expert parallelism's
all_to_all is the shuffle. Token routing is *structurally imbalanced* (hot
experts), which is the paper's target regime. Two dispatch schedules:

  "2s"  — bulk-synchronous (baseline): route all local tokens, one big
          all_to_all out, expert GEMMs, one big all_to_all back.
          (MPI_Alltoallv after the Map barrier.)
  "1s"  — decoupled (the paper): tokens stream in ``dispatch_groups`` chunks
          through a software-pipelined scan. Step g pushes group g's buckets
          while the expert GEMM of group g-1 and the return push of g-1 run —
          the explicit double buffer from core/onesided.py. Same bytes,
          overlapped schedule; bucket buffers shrink by G (paper Fig 6).

Both run inside one shard_map over the whole mesh: activations enter
sequence-sharded over "model" (each shard owns T_loc tokens), experts are
sharded over "model" (EP), batch over the data axes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import axis_size, shard_map
from repro.config import ModelConfig
from repro.models.layers import _init

EP_AXIS = "model"


def init_moe(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    ffe = cfg.d_ff_expert or cfg.d_ff
    E = cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    s_in, s_out = d ** -0.5, ffe ** -0.5
    p = {
        "router": _init(ks[0], (d, E), 0.02, jnp.float32),
        "we_gate": _init(ks[1], (E, d, ffe), s_in, dt),
        "we_in": _init(ks[2], (E, d, ffe), s_in, dt),
        "we_out": _init(ks[3], (E, ffe, d), s_out, dt),
    }
    if cfg.n_shared_experts:
        ffs = ffe * cfg.n_shared_experts
        p["ws_gate"] = _init(ks[4], (d, ffs), s_in, dt)
        p["ws_in"] = _init(ks[5], (d, ffs), s_in, dt)
        p["ws_out"] = _init(ks[6], (ffs, d), s_out, dt)
    return p


# ---------------------------------------------------------------------------
# routing + bucketing (sender side) — the hash->owner of the paper
# ---------------------------------------------------------------------------

def _route(cfg: ModelConfig, router_w, x_flat):
    """x_flat: (T, D) -> (expert_ids (T,k), gates (T,k), probs (T,E))."""
    logits = (x_flat.astype(jnp.float32) @ router_w)
    probs = jax.nn.softmax(logits, -1)
    gates, ids = lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return ids.astype(jnp.int32), gates, probs


def _aux_loss(cfg: ModelConfig, probs, ids, sum_axes=()):
    """Switch-style load-balancing loss.

    ``sum_axes``: mesh axes the tokens are *sharded* over — per-shard counts
    and prob sums psum across them so the sharded loss equals the
    unpartitioned one exactly (not a mean-of-means approximation)."""
    E = cfg.n_experts
    T = probs.shape[0]
    counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    sum_probs = jnp.sum(probs.astype(jnp.float32), 0)
    n_shards = 1
    for ax in sum_axes:
        counts = lax.psum(counts, ax)
        sum_probs = lax.psum(sum_probs, ax)
        n_shards *= axis_size(ax)
    T_tot = T * n_shards
    frac_tokens = counts / max(T_tot * cfg.top_k, 1)
    frac_probs = sum_probs / max(T_tot, 1)
    return E * jnp.sum(frac_tokens * frac_probs)


def _bucket_indices(shard_ids, valid, tp: int, cap: int):
    """Slot each record into (tp, cap) peer buckets (sender side).

    Returns flat gather indices (tp*cap,) into the record axis, -1 = empty.
    Overflow records are dropped (capacity-factor semantics — the MoE
    equivalent of the paper's ownership transfer is the residual connection:
    dropped tokens simply keep their residual value).
    """
    Tk = shard_ids.shape[0]
    sid = jnp.where(valid, shard_ids, tp)
    order = jnp.argsort(sid, stable=True)
    s_sorted = sid[order]
    start = jnp.searchsorted(s_sorted, jnp.arange(tp + 1))
    pos = jnp.arange(Tk) - start[jnp.clip(s_sorted, 0, tp)]
    ok = (pos < cap) & (s_sorted < tp)
    flat = jnp.where(ok, s_sorted * cap + pos, tp * cap)
    idx = jnp.full((tp * cap + 1,), -1, jnp.int32).at[flat].set(
        jnp.where(ok, order, -1).astype(jnp.int32))[:-1]
    return idx                                             # (tp*cap,)


def _gather_records(x, idx):
    """x: (T, D); idx: (M,) with -1 invalid -> (M, D) zeros for invalid."""
    safe = jnp.clip(idx, 0, x.shape[0] - 1)
    out = x[safe]
    return jnp.where((idx >= 0)[:, None], out, 0)


def _expert_gemm(cfg, p, toks, eids, valid):
    """toks: (M, D) received records; eids: (M,) local expert ids.

    Groups records into per-local-expert capacity buffers, runs the SwiGLU
    expert GEMMs batched over E_loc, and scatters results back to the
    record slots.
    """
    M, D = toks.shape
    E_loc = p["we_gate"].shape[0]
    cap_e = -(-M // E_loc)  # ceil — worst case all records on one expert is
    cap_e = min(M, int(cap_e * 4))  # 4x headroom for grouping skew
    eid = jnp.where(valid, eids, E_loc)
    order = jnp.argsort(eid, stable=True)
    es = eid[order]
    start = jnp.searchsorted(es, jnp.arange(E_loc + 1))
    pos = jnp.arange(M) - start[jnp.clip(es, 0, E_loc)]
    ok = (pos < cap_e) & (es < E_loc)
    flat = jnp.where(ok, es * cap_e + pos, E_loc * cap_e)
    slot_of_record = jnp.full((E_loc * cap_e + 1,), -1, jnp.int32).at[
        flat].set(jnp.where(ok, order, -1).astype(jnp.int32))[:-1]
    grouped = _gather_records(toks, slot_of_record)        # (E_loc*cap_e, D)
    grouped = grouped.reshape(E_loc, cap_e, D)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", grouped, p["we_gate"]))
    h = jnp.einsum("ecd,edf->ecf", grouped, p["we_in"])
    out = jnp.einsum("ecf,efd->ecd", g * h, p["we_out"])
    out = out.reshape(E_loc * cap_e, D)
    # scatter back to record slots
    res = jnp.zeros((M + 1, D), toks.dtype).at[
        jnp.where(slot_of_record >= 0, slot_of_record, M)
    ].add(out, mode="drop")[:M]
    return res


# ---------------------------------------------------------------------------
# dispatch schedules
# ---------------------------------------------------------------------------

def _a2a(x, axis):
    """all_to_all that degrades to identity when unpartitioned (axis None)."""
    if axis is None:
        return x
    return lax.all_to_all(x, axis, 0, 0)


def _dispatch_2s(cfg, p, x_flat, ids, gates, tp, E_loc, axis, vma_axes=(),
                 unroll: bool = False):
    """Bulk-synchronous EP dispatch (baseline)."""
    T, D = x_flat.shape
    k = cfg.top_k
    Tk = T * k
    cap = int(cfg.capacity_factor * Tk / tp) + 1
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    shard = flat_ids // E_loc
    idx = _bucket_indices(shard, jnp.ones((Tk,), bool), tp, cap)
    send_tok = _gather_records(x_flat, jnp.where(idx >= 0, tok_of[
        jnp.clip(idx, 0, Tk - 1)], -1))
    send_eloc = jnp.where(idx >= 0, flat_ids[jnp.clip(idx, 0, Tk - 1)] % E_loc,
                          -1).astype(jnp.int32)
    send_tok = send_tok.reshape(tp, cap, D)
    send_eloc = send_eloc.reshape(tp, cap)
    recv_tok = _a2a(send_tok, axis)
    recv_eloc = _a2a(send_eloc, axis)
    out = _expert_gemm(cfg, p, recv_tok.reshape(-1, D),
                       recv_eloc.reshape(-1), recv_eloc.reshape(-1) >= 0)
    back = _a2a(out.reshape(tp, cap, D), axis)
    back = back.reshape(tp * cap, D)
    # weighted scatter-add into token outputs
    rec = jnp.clip(idx, 0, Tk - 1)
    w = jnp.where(idx >= 0, flat_gates[rec], 0.0)
    tgt = jnp.where(idx >= 0, tok_of[rec], T)
    y = jnp.zeros((T + 1, D), x_flat.dtype).at[tgt].add(
        back * w[:, None].astype(back.dtype), mode="drop")[:T]
    return y


def _dispatch_1s(cfg, p, x_flat, ids, gates, tp, E_loc, axis, vma_axes=(),
                 unroll: bool = False):
    """Decoupled pipelined dispatch — the paper's technique.

    scan step g:   push buckets(g)            [all_to_all, async]
                   GEMM recv(g-1)             [overlaps the push]
                   push-back out(g-1)         [all_to_all, async]
                   scatter back(g-1) into y
    """
    T, D = x_flat.shape
    k = cfg.top_k
    G = max(1, min(cfg.dispatch_groups, T))
    assert T % G == 0, (T, G)
    Tg = T // G
    Tkg = Tg * k
    cap = int(cfg.capacity_factor * Tkg / tp) + 1

    tok_of = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)  # constant

    def bucket_group(g):
        off = g * Tg
        x_g = lax.dynamic_slice_in_dim(x_flat, off, Tg, 0)
        ids_g = lax.dynamic_slice_in_dim(ids, off, Tg, 0).reshape(-1)
        gates_g = lax.dynamic_slice_in_dim(gates, off, Tg, 0).reshape(-1)
        shard = ids_g // E_loc
        idx = _bucket_indices(shard, jnp.ones((Tkg,), bool), tp, cap)
        rec = jnp.clip(idx, 0, Tkg - 1)
        send_tok = _gather_records(x_g, jnp.where(idx >= 0, tok_of[rec], -1))
        send_eloc = jnp.where(idx >= 0, ids_g[rec] % E_loc, -1).astype(
            jnp.int32)
        return (send_tok.reshape(tp, cap, D), send_eloc.reshape(tp, cap),
                idx, gates_g)

    def step(carry, g):
        y, recv_tok, recv_eloc, idx_p, gates_p = carry
        # (1) push group g buckets (skipped past the last group: zero work,
        #     but scan needs uniform structure — we mask with validity)
        send_tok, send_eloc, idx, gates_g = bucket_group(
            jnp.minimum(g, G - 1))
        r_tok = _a2a(send_tok, axis)
        r_eloc = _a2a(send_eloc, axis)
        # (2) expert GEMM of the previous group's received records
        out = _expert_gemm(cfg, p, recv_tok.reshape(-1, D),
                           recv_eloc.reshape(-1), recv_eloc.reshape(-1) >= 0)
        # (3) return push
        back = _a2a(out.reshape(tp, cap, D), axis)
        back = back.reshape(tp * cap, D)
        # (4) weighted scatter into the previous group's slice of y
        g_p = jnp.clip(g - 1, 0, G - 1)   # previous group's base offset
        rec_p = jnp.clip(idx_p, 0, Tkg - 1)
        w = jnp.where(idx_p >= 0, gates_p[rec_p], 0.0)
        tgt = jnp.where(idx_p >= 0, tok_of[rec_p] + g_p * Tg, T)
        y = y.at[tgt].add(back * w[:, None].astype(back.dtype), mode="drop")
        return (y, r_tok, r_eloc, idx, gates_g), None

    y0 = jnp.zeros((T + 1, D), x_flat.dtype)
    z_tok = jnp.zeros((tp, cap, D), x_flat.dtype)
    z_eloc = jnp.full((tp, cap), -1, jnp.int32)
    z_idx = jnp.full((tp * cap,), -1, jnp.int32)
    z_gates = jnp.zeros((Tkg,), jnp.float32)
    carry = (y0, z_tok, z_eloc, z_idx, z_gates)
    if vma_axes and hasattr(lax, "pcast"):
        carry = jax.tree.map(
            lambda a: lax.pcast(a, vma_axes, to="varying"), carry)
    # G pushes + 1 drain step for the in-flight group
    if unroll:
        for g in range(G + 1):     # cost-exact HLO for the dry-run variants
            carry, _ = step(carry, jnp.int32(g))
    else:
        carry, _ = lax.scan(step, carry, jnp.arange(G + 1))
    return carry[0][:T]


def _dispatch_replicated(cfg, p, x_flat, ids, gates, E_loc, axis):
    """Decode-time EP: tokens replicated over the model axis (S=1 cannot be
    sequence-sharded). Every shard runs its local experts on the tokens
    routed to them and the outputs psum over the axis — no all_to_all, the
    right schedule when tokens-per-step is tiny.

    With ``cfg.expert_tp_axis`` (serve sharding, §Perf): each expert's d_ff
    is additionally TP-sharded over that axis; expert outputs are partial
    sums, so the final psum also reduces over it — no weight gather ever."""
    T, D = x_flat.shape
    k = cfg.top_k
    Tk = T * k
    shard = lax.axis_index(axis) if axis is not None else 0
    flat_ids = ids.reshape(-1)
    flat_gates = gates.reshape(-1)
    tok_of = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    mine = (flat_ids // E_loc) == shard
    toks = x_flat[tok_of]
    out = _expert_gemm(cfg, p, toks, flat_ids % E_loc, mine)
    w = jnp.where(mine, flat_gates, 0.0)
    y = jnp.zeros((T, D), x_flat.dtype).at[tok_of].add(
        out * w[:, None].astype(out.dtype))
    if axis is not None:
        axes = (axis,)
        if cfg.expert_tp_axis:
            axes = axes + (cfg.expert_tp_axis,)
        y = lax.psum(y, axes)
    return y


# ---------------------------------------------------------------------------
# the MoE layer
# ---------------------------------------------------------------------------

def moe_forward(cfg: ModelConfig, p: dict, x, *, mesh=None, dp_entry=None,
                unroll: bool = False):
    """x: (B, S, D). Returns (y, aux_loss). When ``mesh`` is None the layer
    runs unpartitioned (smoke tests); otherwise inside a mesh-wide shard_map
    with tokens sequence-sharded over "model" and experts EP-sharded. When S
    is not divisible by tp (decode: S=1), tokens replicate over "model" and
    the replicated dispatch runs instead. ``unroll`` unrolls the 1s dispatch
    scan (cost-exact HLO for the dry-run roofline variants)."""
    B, S, D = x.shape
    tp_size = 1
    if mesh is not None:
        tp_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(
            EP_AXIS, 1)
    seq_shardable = S % max(tp_size, 1) == 0

    def body(x_blk, *expert_leaves):
        p_blk = dict(zip(expert_keys, expert_leaves))
        p_blk["router"] = p["router"]
        tp = axis_size(EP_AXIS) if mesh is not None else 1
        axis = EP_AXIS if mesh is not None else None
        vma = tuple(mesh.axis_names) if mesh is not None else ()
        E_loc = p_blk["we_gate"].shape[0]
        Bl, Sl, _ = x_blk.shape
        x_flat = x_blk.reshape(-1, D)
        T_loc = x_flat.shape[0]
        gathered = (mesh is not None and not seq_shardable
                    and cfg.expert_tp_axis)
        if gathered:
            # serve sharding: every shard sees all tokens so the
            # ffe-partial expert outputs can sum across the TP axis
            x_use = lax.all_gather(x_flat, cfg.expert_tp_axis, axis=0,
                                   tiled=True)
        else:
            x_use = x_flat
        ids, gates, probs = _route(cfg, p_blk["router"], x_use)
        # axes the tokens are actually sharded over: the dp entry (batch)
        # plus the model axis when the sequence is sharded over it
        sum_axes = ()
        if mesh is not None and not gathered:
            dp_axes = (dp_entry if isinstance(dp_entry, tuple)
                       else (dp_entry,) if dp_entry else ())
            sum_axes = tuple(dp_axes) + (
                (EP_AXIS,) if seq_shardable else ())
        aux = _aux_loss(cfg, probs, ids, sum_axes)
        if mesh is not None:
            for ax in mesh.axis_names:          # replicate the scalar
                aux = lax.pmean(aux, ax)
        if mesh is not None and not seq_shardable:
            y = _dispatch_replicated(cfg, p_blk, x_use, ids, gates,
                                     E_loc, axis)
            if gathered:
                i = lax.axis_index(cfg.expert_tp_axis)
                y = lax.dynamic_slice_in_dim(y, i * T_loc, T_loc, 0)
        else:
            fn = _dispatch_1s if cfg.dispatch_mode == "1s" else _dispatch_2s
            y = fn(cfg, p_blk, x_flat, ids, gates, tp, E_loc, axis, vma,
                   unroll=unroll)
        return y.reshape(Bl, Sl, D), aux

    expert_keys = ["we_gate", "we_in", "we_out"]
    if mesh is None:
        y, aux = body(x, *[p[k] for k in expert_keys])
    else:
        seq_entry = EP_AXIS if seq_shardable else None
        et = cfg.expert_tp_axis or None
        w_specs = [P(EP_AXIS, None, et), P(EP_AXIS, None, et),
                   P(EP_AXIS, et, None)]
        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(P(dp_entry, seq_entry, None), *w_specs),
            out_specs=(P(dp_entry, seq_entry, None), P()),
        )(x, *[p[k] for k in expert_keys])

    # shared experts (dense, TP-sharded like a normal MLP)
    if cfg.n_shared_experts:
        g = jax.nn.silu(x @ p["ws_gate"])
        h = x @ p["ws_in"]
        y = y + (g * h) @ p["ws_out"]
    return y, aux
