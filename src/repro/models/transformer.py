"""The model stack: every assigned architecture as one composable definition.

A model is a stack of *super-blocks* scanned over ``cfg.n_scan_blocks``; each
super-block holds ``cfg.block_pattern`` layers whose types repeat with the
arch's period (llama4: dense+MoE pairs; jamba: 1 attention + 7 Mamba layers
with MoE on odd slots; dense archs: a single layer). ``first_k_dense``
leading layers (deepseek) sit outside the scan. Whisper adds a scanned
encoder stack + cross-attention in every decoder layer. VLM/audio frontends
are stubs: ``batch["frontend_embeds"]`` carries precomputed patch/frame
embeddings (early fusion for VLM, encoder input for audio).

Three entry points (the launcher lowers exactly these):
  ``loss_fn``      train forward + CE (+ MoE aux)            [train shapes]
  ``prefill``      full-sequence forward, returns caches      [prefill shapes]
  ``decode_step``  one token against seq_len-sized caches     [decode shapes]

``mesh=None`` runs everything unpartitioned (CPU smoke tests); with a mesh,
MoE dispatch and flash-decode run in shard_map sub-regions while the rest is
GSPMD-sharded by the in/out shardings the launcher supplies.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, cross_entropy,
                                 embed_tokens, init_embed, init_mlp,
                                 init_norm, unembed)


# ---------------------------------------------------------------------------
# layer typing — which sublayers layer i carries
# ---------------------------------------------------------------------------

def layer_kind(cfg: ModelConfig, i: int) -> tuple[str, str]:
    """(mixer, ff) for absolute layer index i.

    mixer: "attn" | "mla" | "ssm";  ff: "mlp" | "moe" | "none"
    """
    if cfg.family == "ssm":
        return "ssm", "none"
    if cfg.family == "hybrid" and not cfg.is_attn_layer(i):
        mixer = "ssm"
    elif cfg.attn_type == "mla":
        mixer = "mla"
    else:
        mixer = "attn"
    ff = "moe" if cfg.is_moe_layer(i) else "mlp"
    return mixer, ff


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ModelConfig, key, i: int, cross: bool = False) -> dict:
    mixer, ff = layer_kind(cfg, i)
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": init_norm(cfg, ks[0])}
    if mixer == "attn":
        p["attn"] = attn.init_attention(cfg, ks[1])
    elif mixer == "mla":
        p["attn"] = attn.init_mla(cfg, ks[1])
    else:
        p["ssm"] = ssm_mod.init_ssm(cfg, ks[1])
    if cross:
        p["norm_x"] = init_norm(cfg, ks[4])
        p["cross"] = attn.init_attention(cfg, ks[5], cross=True)
    if ff != "none":
        p["norm2"] = init_norm(cfg, ks[2])
        if ff == "moe":
            p["moe"] = moe_mod.init_moe(cfg, ks[3])
        else:
            p["mlp"] = init_mlp(cfg, ks[3])
    return p


def _init_superblock(cfg: ModelConfig, key, first_layer: int,
                     cross: bool = False) -> dict:
    ks = jax.random.split(key, cfg.block_pattern)
    return {f"layer{j}": _init_layer(cfg, ks[j], first_layer + j, cross)
            for j in range(cfg.block_pattern)}


def init_model(cfg: ModelConfig, key) -> dict:
    """Full parameter pytree. ``blocks``/``enc_blocks`` subtrees are stacked
    (leading scan dim) — the sharding layer treats them specially."""
    k_emb, k_blocks, k_head, k_dense, k_enc = jax.random.split(key, 5)
    params: dict[str, Any] = init_embed(cfg, k_emb)

    # leading dense layers (outside the scan)
    if cfg.first_k_dense:
        dk = jax.random.split(k_dense, cfg.first_k_dense)
        params["dense_layers"] = {
            f"layer{i}": _init_layer(cfg, dk[i], i)
            for i in range(cfg.first_k_dense)
        }

    nb = cfg.n_scan_blocks
    bkeys = jax.random.split(k_blocks, nb)
    first = cfg.first_k_dense
    cross = cfg.n_enc_layers > 0
    blocks = [
        _init_superblock(cfg, bkeys[b], first + b * cfg.block_pattern, cross)
        for b in range(nb)
    ]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)

    if cfg.n_enc_layers:
        enc_cfg = dataclasses.replace(cfg, attn_type="gqa", n_experts=0,
                                      family="dense", block_pattern=1)
        ekeys = jax.random.split(k_enc, cfg.n_enc_layers)
        enc = [_init_superblock(enc_cfg, ekeys[i], i)
               for i in range(cfg.n_enc_layers)]
        params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        params["enc_norm"] = init_norm(cfg, jax.random.fold_in(k_enc, 1))

    params["final_norm"] = init_norm(cfg, k_head)
    return params


# ---------------------------------------------------------------------------
# single layer forward (train/prefill)
# ---------------------------------------------------------------------------

def _layer_forward(cfg: ModelConfig, p: dict, x, positions, i: int, *,
                   causal: bool, enc_out=None, mesh=None, dp_entry=None,
                   use_pallas: bool = False, unroll: bool = False):
    """Returns (x, cache_dict, aux_loss)."""
    mixer, ff = layer_kind(cfg, i)
    aux = jnp.float32(0.0)
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "ssm":
        out, cache = ssm_mod.ssm_forward(cfg, p["ssm"], h,
                                         use_pallas=use_pallas)
    elif mixer == "mla":
        out, cache = attn.mla_forward(cfg, p["attn"], h, positions,
                                      use_pallas=use_pallas, unroll=unroll)
    else:
        out, kv = attn.attention_forward(cfg, p["attn"], h, positions,
                                         causal=causal,
                                         use_pallas=use_pallas,
                                         unroll=unroll)
        cache = {"k": kv[0], "v": kv[1]}
    x = x + out

    if enc_out is not None and "cross" in p:
        h = apply_norm(cfg, p["norm_x"], x)
        q, k, v = attn._qkv(cfg, p["cross"], h, enc_out)
        fa = (attn.flash_attention_costexact if unroll
              else attn.flash_attention_ref)
        o = fa(q, k, v, causal=False)
        B, S, H, hd = q.shape
        x = x + o.reshape(B, S, H * hd) @ p["cross"]["wo"]
        cache["cross_k"], cache["cross_v"] = k, v

    if ff != "none":
        h = apply_norm(cfg, p["norm2"], x)
        if ff == "moe":
            y, aux = moe_mod.moe_forward(cfg, p["moe"], h, mesh=mesh,
                                         dp_entry=dp_entry, unroll=unroll)
        else:
            y = apply_mlp(p["mlp"], h)
        x = x + y
    return x, cache, aux


def _superblock_forward(cfg: ModelConfig, p: dict, x, positions,
                        first_layer: int, *, causal=True, enc_out=None,
                        mesh=None, dp_entry=None, use_pallas=False,
                        want_cache=False, unroll=False):
    caches = {}
    aux_total = jnp.float32(0.0)
    for j in range(cfg.block_pattern):
        x, cache, aux = _layer_forward(
            cfg, p[f"layer{j}"], x, positions, first_layer + j,
            causal=causal, enc_out=enc_out, mesh=mesh, dp_entry=dp_entry,
            use_pallas=use_pallas, unroll=unroll)
        aux_total = aux_total + aux
        if want_cache:
            caches[f"layer{j}"] = cache
    return x, caches, aux_total


# ---------------------------------------------------------------------------
# whole-stack forward
# ---------------------------------------------------------------------------

def _remat_policy(name: str):
    import jax.ad_checkpoint as adc
    if name == "none":
        return None
    if name == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable  # "full"


def _encoder_forward(cfg: ModelConfig, params, frames, *, use_pallas=False,
                     remat="none", unroll=False):
    """frames: (B, S_enc, D) stub frame embeddings -> (B, S_enc, D)."""
    enc_cfg = dataclasses.replace(cfg, attn_type="gqa", n_experts=0,
                                  family="dense", block_pattern=1)
    B, S_enc, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(S_enc, dtype=jnp.int32), (B, S_enc))

    def body(x, bp):
        x, _, _ = _superblock_forward(enc_cfg, bp, x, pos, 0, causal=False,
                                      use_pallas=use_pallas, unroll=unroll)
        return x, None

    body = jax.checkpoint(body, policy=_remat_policy(remat))
    if unroll:
        x = frames
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda a, i=i: a[i],
                                        params["enc_blocks"]))
    else:
        x, _ = lax.scan(body, frames, params["enc_blocks"])
    return apply_norm(cfg, params["enc_norm"], x)


def forward(cfg: ModelConfig, params, batch: dict, *, mesh=None,
            dp_entry=None, use_pallas=False, remat="none",
            want_cache: bool = False, unroll: bool = False):
    """Train / prefill forward.

    batch: tokens (B, S_text); labels optional; frontend_embeds optional
    (VLM: (B, S_img, D) early-fused prefix; audio: (B, S_enc, D) encoder
    input). Returns (logits, aux_loss[, caches]) — ``caches`` holds the raw
    per-layer prefill caches (k/v at sequence length) when requested;
    serve/engine.py converts them to decode layout.
    """
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    x = embed_tokens(cfg, params, tokens)

    enc_out = None
    if cfg.frontend == "vision_stub" and "frontend_embeds" in batch:
        x = jnp.concatenate(
            [batch["frontend_embeds"].astype(x.dtype), x], axis=1)
    elif cfg.n_enc_layers and "frontend_embeds" in batch:
        enc_out = _encoder_forward(cfg, params, batch["frontend_embeds"],
                                   use_pallas=use_pallas, remat=remat,
                                   unroll=unroll)

    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    aux_total = jnp.float32(0.0)
    caches: dict[str, Any] = {}
    first = cfg.first_k_dense
    if first:
        dense_caches = {}
        for i in range(first):
            x, c, aux = _layer_forward(
                cfg, params["dense_layers"][f"layer{i}"], x, positions, i,
                causal=True, mesh=mesh, dp_entry=dp_entry,
                use_pallas=use_pallas, unroll=unroll)
            aux_total += aux
            dense_caches[f"layer{i}"] = c
        caches["dense_layers"] = dense_caches

    def body(carry, bp):
        x, aux = carry
        x, c, a = _superblock_forward(
            cfg, bp, x, positions, first, causal=True, enc_out=enc_out,
            mesh=mesh, dp_entry=dp_entry, use_pallas=use_pallas,
            want_cache=want_cache, unroll=unroll)
        return (x, aux + a), (c if want_cache else None)

    body = jax.checkpoint(body, policy=_remat_policy(remat))
    if unroll:
        nb = cfg.n_scan_blocks
        ys = []
        carry = (x, aux_total)
        for b in range(nb):
            carry, y = body(carry, jax.tree.map(lambda a, b=b: a[b],
                                                params["blocks"]))
            ys.append(y)
        (x, aux_total) = carry
        block_caches = (jax.tree.map(lambda *a: jnp.stack(a), *ys)
                        if want_cache else None)
    else:
        (x, aux_total), block_caches = lax.scan(body, (x, aux_total),
                                                params["blocks"])

    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params, x)
    if want_cache:
        caches["blocks"] = block_caches
        return logits, aux_total, caches
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params, batch: dict, *, mesh=None,
            dp_entry=None, use_pallas=False, remat="none",
            unroll: bool = False):
    logits, aux = forward(cfg, params, batch, mesh=mesh, dp_entry=dp_entry,
                          use_pallas=use_pallas, remat=remat, unroll=unroll)
    labels = batch["labels"]
    S_text = labels.shape[1]
    # frontends prepend S_img positions; only text positions carry loss
    logits_text = logits[:, -S_text:]
    mask = batch.get("loss_mask")
    ce = cross_entropy(logits_text, labels, mask)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# caches / decode
# ---------------------------------------------------------------------------

def _layer_cache_shape(cfg: ModelConfig, i: int, B: int, S_max: int,
                       enc_len: int = 0) -> dict:
    """abstract zero cache for one layer (decode path)."""
    mixer, _ = layer_kind(cfg, i)
    dt = jnp.dtype(cfg.dtype)
    if mixer == "ssm":
        di, K = cfg.d_inner, cfg.ssm_conv
        G, N = cfg.ssm_groups, cfg.ssm_state
        return {
            "state": jnp.zeros((B, cfg.n_ssm_heads, cfg.ssm_head_dim, N),
                               jnp.float32),
            "conv_x": jnp.zeros((B, K - 1, di), dt),
            "conv_B": jnp.zeros((B, K - 1, G * N), dt),
            "conv_C": jnp.zeros((B, K - 1, G * N), dt),
        }
    if mixer == "mla":
        width = cfg.kv_lora_rank + cfg.qk_rope_dim
        return {"ckv": jnp.zeros((B, S_max, width), dt)}
    KV, hd = cfg.n_kv_heads, cfg.d_head
    S_cache = min(cfg.sliding_window, S_max) if cfg.attn_type == "swa" \
        else S_max
    c = {"k": jnp.zeros((B, S_cache, KV, hd), dt),
         "v": jnp.zeros((B, S_cache, KV, hd), dt)}
    if enc_len:
        c["cross_k"] = jnp.zeros((B, enc_len, KV, hd), dt)
        c["cross_v"] = jnp.zeros((B, enc_len, KV, hd), dt)
    return c


def init_cache(cfg: ModelConfig, B: int, S_max: int, enc_len: int = 0):
    """Stacked decode caches: blocks subtree gains a leading scan dim."""
    first = cfg.first_k_dense
    cache: dict[str, Any] = {}
    if first:
        cache["dense_layers"] = {
            f"layer{i}": _layer_cache_shape(cfg, i, B, S_max, enc_len)
            for i in range(first)
        }
    per_block = [
        {f"layer{j}": _layer_cache_shape(cfg, first + b * cfg.block_pattern
                                         + j, B, S_max, enc_len)
         for j in range(cfg.block_pattern)}
        for b in range(cfg.n_scan_blocks)
    ]
    cache["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_block)
    return cache


def _layer_decode(cfg: ModelConfig, p: dict, x, cache: dict, t, i: int, *,
                  mesh=None, dp_entry=None):
    mixer, ff = layer_kind(cfg, i)
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "ssm":
        out, new_cache = ssm_mod.ssm_decode(cfg, p["ssm"], h, cache)
    elif mixer == "mla":
        out, new_cache = attn.mla_decode(cfg, p["attn"], h, cache, t,
                                         mesh=mesh, dp_entry=dp_entry)
    else:
        out, new_cache = attn.attention_decode(cfg, p["attn"], h, cache, t,
                                               mesh=mesh, dp_entry=dp_entry)
        if "cross_k" in cache:
            new_cache["cross_k"] = cache["cross_k"]
            new_cache["cross_v"] = cache["cross_v"]
    x = x + out

    if "cross" in p and "cross_k" in cache:
        h = apply_norm(cfg, p["norm_x"], x)
        B = h.shape[0]
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
        q = (h @ p["cross"]["wq"]).reshape(B, H, hd)
        enc_len = cache["cross_k"].shape[1]
        o, l, m = attn._decode_partials(
            q, cache["cross_k"], cache["cross_v"],
            jnp.arange(enc_len), enc_len)
        o = attn.combine_partials(o, l, m, None).reshape(B, 1, H * hd)
        x = x + o.astype(x.dtype) @ p["cross"]["wo"]

    if ff != "none":
        h = apply_norm(cfg, p["norm2"], x)
        if ff == "moe":
            y, _ = moe_mod.moe_forward(cfg, p["moe"], h, mesh=mesh,
                                       dp_entry=dp_entry)
        else:
            y = apply_mlp(p["mlp"], h)
        x = x + y
    return x, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens_t, t, *, mesh=None,
                dp_entry=None, unroll: bool = False):
    """One decode step. tokens_t: (B, 1); t: scalar current length.
    Returns (logits (B, 1, V), new_cache)."""
    x = embed_tokens(cfg, params, tokens_t)
    first = cfg.first_k_dense
    if first:
        new_dense = {}
        for i in range(first):
            x, nc = _layer_decode(cfg, params["dense_layers"][f"layer{i}"],
                                  x, cache["dense_layers"][f"layer{i}"],
                                  t, i, mesh=mesh, dp_entry=dp_entry)
            new_dense[f"layer{i}"] = nc
    else:
        new_dense = None

    def body(x, block):
        bp, bc = block
        new_c = {}
        xx = x
        for j in range(cfg.block_pattern):
            xx, nc = _layer_decode(cfg, bp[f"layer{j}"], xx, bc[f"layer{j}"],
                                   t, first + j, mesh=mesh,
                                   dp_entry=dp_entry)
            new_c[f"layer{j}"] = nc
        return xx, new_c

    if unroll:
        ys = []
        for b in range(cfg.n_scan_blocks):
            x, y = body(x, jax.tree.map(lambda a, b=b: a[b],
                                        (params["blocks"],
                                         cache["blocks"])))
            ys.append(y)
        new_blocks = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        x, new_blocks = lax.scan(body, x,
                                 (params["blocks"], cache["blocks"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = unembed(cfg, params, x)
    new_cache = {"blocks": new_blocks}
    if new_dense is not None:
        new_cache["dense_layers"] = new_dense
    return logits, new_cache


def prefill(cfg: ModelConfig, params, batch: dict, *, mesh=None,
            dp_entry=None, use_pallas=False, unroll: bool = False):
    """Full-sequence forward returning last-token logits. (Cache assembly for
    prefill→decode handoff lives in serve/engine.py; the dry-run's prefill
    cell lowers exactly this program.)"""
    logits, _ = forward(cfg, params, batch, mesh=mesh, dp_entry=dp_entry,
                        use_pallas=use_pallas, remat="none", unroll=unroll)
    return logits[:, -1:]
