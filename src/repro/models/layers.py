"""Shared layer primitives: norms, RoPE, SwiGLU MLP, embeddings.

Functional style: ``init_*`` builds param dicts (leaf names are the sharding
contract — see distributed/sharding.py), ``apply_*`` consumes them.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, key) -> dict:
    if cfg.norm_type == "nonparam_ln":      # OLMo: no scale/bias
        return {}
    return {"scale": jnp.ones((cfg.d_model,), dtype_of(cfg))}


def apply_norm(cfg: ModelConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm" or cfg.norm_type == "nonparam_ln":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    else:                                    # rmsnorm
        ms = jnp.mean(xf * xf, -1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
    if "scale" in p:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_over(x, scale, eps=1e-5):
    """RMS norm over the last dim with an explicit scale vector (qk-norm,
    mamba gate norm)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., S, dim); positions: (..., S) int32."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                      # (dim/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dim/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: int = 0) -> dict:
    d, ff = cfg.d_model, (d_ff or cfg.d_ff)
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    return {
        "w_gate": _init(k1, (d, ff), s_in, dt),
        "w_in": _init(k2, (d, ff), s_in, dt),
        "w_out": _init(k3, (ff, d), s_out, dt),
    }


def apply_mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(x @ p["w_gate"])
    h = x @ p["w_in"]
    return (g * h) @ p["w_out"]


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key) -> dict:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p = {"embed_tokens": _init(k1, (cfg.vocab_size, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = _init(k2, (cfg.d_model, cfg.vocab_size),
                             cfg.d_model ** -0.5, dt)
    return p


def embed_tokens(cfg: ModelConfig, p: dict, tokens: jnp.ndarray):
    return p["embed_tokens"][tokens]


def unembed(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    if cfg.tie_embeddings:
        return x @ p["embed_tokens"].T
    return x @ p["lm_head"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray = None):
    """Token-mean CE; logits may be vocab-sharded (GSPMD reduces)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
