from repro.ft.elastic import (fold_windows, rebucketize_tasks, remesh_fleet,
                              remesh_plan)
from repro.ft.straggler import ThroughputTracker, rebalance_tasks
