from repro.ft.elastic import remesh_plan, fold_windows
from repro.ft.straggler import ThroughputTracker, rebalance_tasks
