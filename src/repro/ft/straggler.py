"""Straggler mitigation: throughput-aware task re-planning.

The 1S engine itself is the first line of defense (a slow rank's reduce
work spreads across the map timeline instead of gating a barrier). This
module adds the second line: the host tracks per-rank segment throughput
and re-plans the *remaining* tasks proportionally at every segment
boundary. Re-planning (not re-issuing in-flight work) keeps exactly-once
semantics — no dedup machinery needed, results stay exact.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np


@dataclass
class ThroughputTracker:
    n_procs: int
    alpha: float = 0.5                       # EWMA smoothing
    rate: np.ndarray = field(default=None)

    def __post_init__(self):
        if self.rate is None:
            self.rate = np.ones((self.n_procs,), np.float64)

    def update(self, seg_seconds: np.ndarray):
        """seg_seconds: wall time each rank spent on the last segment
        (same task count each) — lower is faster."""
        seg_seconds = np.maximum(np.asarray(seg_seconds, np.float64), 1e-9)
        inst = 1.0 / seg_seconds
        self.rate = self.alpha * inst + (1 - self.alpha) * self.rate

    def is_straggler(self, threshold: float = 0.5) -> np.ndarray:
        """Ranks slower than ``threshold`` × median throughput."""
        med = np.median(self.rate)
        return self.rate < threshold * med


def rebalance_tasks(task_ids: List[int], rate: np.ndarray,
                    tasks_per_segment: int) -> np.ndarray:
    """Assign the next segment's tasks proportional to throughput.

    Returns (n_procs, tasks_per_proc) of task ids, -1 padded (a -1 task is
    a no-op in the engine). Every task appears exactly once — exactness is
    preserved by construction."""
    n_procs = len(rate)
    quota = rate / rate.sum() * min(len(task_ids), tasks_per_segment)
    counts = np.floor(quota).astype(int)
    # distribute the remainder to the fastest ranks
    rem = min(len(task_ids), tasks_per_segment) - counts.sum()
    order = np.argsort(-rate)
    for i in range(rem):
        counts[order[i % n_procs]] += 1
    width = max(counts.max(initial=1), 1)
    out = -np.ones((n_procs, width), np.int32)
    cursor = 0
    for r in range(n_procs):
        take = counts[r]
        out[r, :take] = task_ids[cursor: cursor + take]
        cursor += take
    return out
