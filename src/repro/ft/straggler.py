"""Straggler mitigation: throughput-aware task re-planning.

The imbalance defenses now form three layers, finest to coarsest:

  1. the 1S engine itself — a slow rank's reduce work spreads across the
     map timeline instead of gating a barrier;
  2. **in-scan work stealing** (``JobConfig(stealing=True)``,
     :mod:`repro.core.steal`) — every scan step, ranks that ran ahead
     claim tasks from the most loaded rank's unstarted range, absorbing
     per-task skew the host can never see in time;
  3. this module — the *coarse outer loop*: the host tracks per-rank
     segment throughput and re-plans the **remaining** tasks
     proportionally at segment boundaries. Re-planning (not re-issuing
     in-flight work) keeps exactly-once semantics — no dedup machinery
     needed, results stay exact.

With the unified Job API the natural integration point is a segmented
``JobHandle``: call :func:`plan_next_segment` between ``handle.step()``
calls to redistribute ``handle.remaining_task_ids()``, and seed the
tracker from a completed job's per-rank work stats via
:func:`tracker_from_result`. When the handle also runs with stealing,
use :func:`outer_rebalance` instead of :func:`replan_handle`: it only
re-plans on *persistent* drift (a genuinely slow host, a shrunk rank),
leaving transient skew to the in-scan layer.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class ThroughputTracker:
    n_procs: int
    alpha: float = 0.5                       # EWMA smoothing
    rate: np.ndarray = field(default=None)

    def __post_init__(self):
        if self.rate is None:
            self.rate = np.ones((self.n_procs,), np.float64)

    def update(self, seg_seconds: np.ndarray):
        """seg_seconds: wall time each rank spent on the last segment
        (same task count each) — lower is faster."""
        seg_seconds = np.maximum(np.asarray(seg_seconds, np.float64), 1e-9)
        inst = 1.0 / seg_seconds
        self.rate = self.alpha * inst + (1 - self.alpha) * self.rate

    def is_straggler(self, threshold: float = 0.5) -> np.ndarray:
        """Ranks slower than ``threshold`` × median throughput."""
        med = np.median(self.rate)
        return self.rate < threshold * med

    def update_work(self, work_per_rank: np.ndarray, seconds: float):
        """EWMA update from *work executed per rank over one slice* —
        the observation a scheduler actually has between time slices
        (``update`` wants per-rank seconds at equal work; a slice gives
        the transpose: equal wall time, per-rank work).

        A rank that was *assigned* nothing this slice (zero work — e.g.
        a -1-padded tail of a previous re-plan) carries no throughput
        signal, so its estimate is left untouched. Folding zeros in
        would ratchet: rate decays → next re-plan assigns it even less
        → permanent starvation of a rank that was never actually slow."""
        work = np.asarray(work_per_rank, np.float64)
        inst = work / max(float(seconds), 1e-9)
        observed = work > 0
        self.rate = np.where(observed,
                             self.alpha * inst
                             + (1 - self.alpha) * self.rate,
                             self.rate)


def rebalance_tasks(task_ids: list[int], rate: np.ndarray,
                    tasks_per_segment: int) -> np.ndarray:
    """Assign the next segment's tasks proportional to throughput.

    Returns (n_procs, tasks_per_proc) of task ids, -1 padded (a -1 task is
    a no-op in the engine). Every task appears exactly once — exactness is
    preserved by construction."""
    n_procs = len(rate)
    quota = rate / rate.sum() * min(len(task_ids), tasks_per_segment)
    counts = np.floor(quota).astype(int)
    # distribute the remainder to the fastest ranks
    rem = min(len(task_ids), tasks_per_segment) - counts.sum()
    order = np.argsort(-rate)
    for i in range(rem):
        counts[order[i % n_procs]] += 1
    width = max(counts.max(initial=1), 1)
    out = -np.ones((n_procs, width), np.int32)
    cursor = 0
    for r in range(n_procs):
        take = counts[r]
        out[r, :take] = task_ids[cursor: cursor + take]
        cursor += take
    return out


# ---------------------------------------------------------------------------
# unified Job API integration
# ---------------------------------------------------------------------------

def tracker_from_result(result, alpha: float = 0.5) -> ThroughputTracker:
    """Seed a tracker from a completed job's per-rank work stats
    (``JobResult.work_per_rank``): ranks that carried more compute-repeats
    in the same wall time were proportionally faster."""
    work = np.asarray(result.work_per_rank, np.float64)
    tr = ThroughputTracker(n_procs=len(work), alpha=alpha)
    tr.rate = np.maximum(work, 1e-9) / max(result.wall_time, 1e-9)
    return tr


def plan_next_segment(handle, tracker: ThroughputTracker,
                      tasks_per_segment: int = 0) -> np.ndarray:
    """Re-plan a segmented ``JobHandle``'s remaining tasks proportional to
    tracked throughput. Returns the (n_procs, width) task-id grid for the
    next segment (-1 padded); every remaining task appears exactly once."""
    remaining = handle.remaining_task_ids()
    per_seg = tasks_per_segment or len(remaining)
    return rebalance_tasks(remaining.tolist(), tracker.rate, per_seg)


def replan_handle(handle, tracker: ThroughputTracker) -> np.ndarray:
    """Re-route the handle's *unread* tasks through its SegmentFeed,
    proportional to tracked throughput — the streaming composition of
    :func:`plan_next_segment`: the feed discards any in-flight prefetch
    of the old assignment and starts reading the new one (reads are
    pure, so nothing is double-executed). Each task keeps its
    compute-repeat factor; exactness is preserved by construction.
    Returns the installed (n_procs, width) grid."""
    assignment = plan_next_segment(handle, tracker)
    handle.replan(assignment)
    return assignment


def outer_rebalance(handle, tracker: ThroughputTracker,
                    drift_threshold: float = 0.0):
    """Coarse outer loop over the fine-grained in-scan stealing.

    Re-plans the handle's unread tasks only when the tracked throughput
    *drift* (fastest/slowest rank ratio) exceeds ``drift_threshold`` —
    persistent imbalance the device-side claims cannot absorb because it
    follows the rank, not the task. Below the threshold the segment
    boundary is left untouched (with ``stealing=True`` the engine is
    already rebalancing every scan step; a host re-plan would only
    discard a good prefetch). ``drift_threshold=0.0`` picks a default:
    2.0 for stealing handles, 1.0 (always re-plan, the legacy behavior)
    otherwise. Returns the installed grid, or ``None`` when skipped."""
    if not drift_threshold:
        drift_threshold = 2.0 if handle.config.stealing else 1.0
    drift = float(tracker.rate.max() / max(tracker.rate.min(), 1e-9))
    if drift < drift_threshold:
        return None
    return replan_handle(handle, tracker)


def rebalance_hook(alpha: float = 0.5, drift_threshold: float = 0.0):
    """Per-job slice hook for ``repro.core.scheduler.JobScheduler`` —
    :func:`outer_rebalance` as the between-slices callback the scheduler
    invokes for the job: ``scheduler.submit(cfg, ds, on_slice=
    rebalance_hook())``.

    The returned callable has the scheduler's hook signature
    ``hook(handle, slice_stats)`` (``slice_stats.seconds`` +
    ``slice_stats.work_per_rank``); it maintains one
    :class:`ThroughputTracker` per handle, folds each slice's realized
    per-rank work into it, and re-plans the handle's unread tasks only
    on persistent drift — exactly the coarse outer loop, now driven by
    the scheduler instead of a hand-written step loop. One hook instance
    may be shared across jobs (trackers are per-handle, weakly keyed —
    a finished handle's tracker is dropped with it, and a recycled
    object address can never inherit a stale tracker)."""
    import weakref
    trackers = weakref.WeakKeyDictionary()

    def hook(handle, slice_stats):
        tr = trackers.get(handle)
        if tr is None:
            trackers[handle] = tr = ThroughputTracker(
                n_procs=handle.config.n_procs, alpha=alpha)
        tr.update_work(slice_stats.work_per_rank, slice_stats.seconds)
        if handle.feed.exhausted:
            return None             # nothing left to re-route
        return outer_rebalance(handle, tr, drift_threshold)

    return hook
