"""Elastic re-meshing after node failure.

What makes this *cheap* in this framework is the paper's own design:

  * the task planner is decentralized (rank-indexed round-robin, no master),
    so reassigning a dead rank's remaining tasks is pure arithmetic
    (``rebucketize_tasks``);
  * the Combine tree dup-sums records by key across *all* ranks, so window
    ownership does not have to be preserved across a re-mesh — any
    distribution of the surviving window state onto the new mesh yields the
    exact result (``fold_windows``). This is the ownership-transfer
    semantics of paper footnote 2, promoted to a fault-tolerance mechanism.

These helpers are the host-side half of the elastic path; the live
subsystem that drives them — fault injection, failure detection, the
re-mesh of a whole scheduled fleet — is :mod:`repro.fleet` (the device
fold program lives in :mod:`repro.fleet.remesh`).

For the LM trainer the analogue is checkpoint restore onto the surviving
mesh: ``CheckpointManager.restore(shardings=new)`` re-shards every leaf;
``remesh_plan`` picks the new 2-D mesh shape, ``remesh_fleet`` the
engine fleet's 1-D one.
"""
from __future__ import annotations


import numpy as np

from repro.config import MeshConfig

I32_MIN = int(np.iinfo(np.int32).min)
I32_MAX = int(np.iinfo(np.int32).max)      # == repro.core.combine.SAT_MAX


def remesh_plan(n_surviving: int, prefer_model: int = 16) -> MeshConfig:
    """Largest (data, model) mesh fitting the surviving device count.

    Keeps the model axis as close to ``prefer_model`` as divides, shrinking
    data parallelism first (the cheap direction: batch shrinks, params
    re-shard; TP degree changes force a re-layout of every weight)."""
    model = prefer_model
    while model > 1 and n_surviving % model:
        model //= 2
    data = n_surviving // model
    if data * model == 0:
        raise ValueError(f"no mesh for {n_surviving} devices")
    return MeshConfig((data, model), ("data", "model"))


def remesh_fleet(n_surviving: int) -> MeshConfig:
    """The engine fleet's mesh over the survivors — always the 1-D
    ``("procs",)`` layout the MapReduce engines run on (the trainer's
    2-D re-layout logic in :func:`remesh_plan` does not apply: there is
    no model axis to preserve, only the process count changes)."""
    if n_surviving < 1:
        raise ValueError(f"no mesh for {n_surviving} surviving device(s)")
    return MeshConfig((int(n_surviving),), ("procs",))


def fold_windows(tables: np.ndarray, n_new: int) -> np.ndarray:
    """Redistribute per-rank dense Key-Value windows (P_old, vocab) onto
    P_new ranks by summing old tables round-robin (``out[r % n_new] +=
    tables[r]``). Exact because Combine dup-sums by key across ranks.
    Growing (``n_new > P_old``) leaves the extra ranks' windows zero.

    Integer windows saturate at INT32_MAX instead of wrapping — the
    numpy twin of ``repro.core.combine.sat_add_i32`` (counts are
    non-negative, so accumulating in int64 and clipping is equivalent to
    the device's pairwise saturating adds): folding P_old near-full
    count tables onto fewer ranks used to overflow silently, turning
    huge counts into garbage that the exactness checks downstream could
    not attribute."""
    tables = np.asarray(tables)
    P_old, vocab = tables.shape
    if tables.dtype.kind not in "iu" or tables.dtype.itemsize > 4:
        # float (trainer state) or already-wide windows: plain fold
        out = np.zeros((n_new, vocab), tables.dtype)
        for r in range(P_old):
            out[r % n_new] += tables[r]
        return out
    acc = np.zeros((n_new, vocab), np.int64)
    for r in range(P_old):
        acc[r % n_new] += tables[r].astype(np.int64)
    return np.clip(acc, I32_MIN, I32_MAX).astype(tables.dtype)


def surviving_ranks(n_procs: int, failed: list[int]) -> list[int]:
    return [r for r in range(n_procs) if r not in set(failed)]


def rebucketize_tasks(task_ids: np.ndarray, repeats: np.ndarray,
                      cursor: int, n_new: int
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Re-plan the not-yet-consumed tasks of a ``(P_old, T)`` assignment
    onto ``n_new`` ranks: the columns past ``cursor`` are flattened
    (padding ``-1`` slots dropped), sorted by global task id, and dealt
    round-robin into a fresh ``(n_new, W)`` grid with ``W =
    ceil(remaining / n_new)``. Each task keeps its compute-repeat
    factor, so a re-meshed resume stays exact by construction — the
    decentralized-planner arithmetic the module docstring promises.

    Returns ``(ids, reps)`` ready for ``SegmentFeed.seek(0, ids, reps)``.
    """
    ids = np.asarray(task_ids, np.int32)
    reps = np.asarray(repeats, np.int32)
    assert ids.shape == reps.shape, "task/repeat grids must align"
    mask = ids[:, cursor:] >= 0
    flat_ids = ids[:, cursor:][mask]
    flat_reps = reps[:, cursor:][mask]
    order = np.argsort(flat_ids, kind="stable")
    flat_ids, flat_reps = flat_ids[order], flat_reps[order]
    n = len(flat_ids)
    W = -(-n // n_new) if n else 0
    grid = np.full((n_new, W), -1, np.int32)
    greps = np.ones((n_new, W), np.int32)
    idx = np.arange(n)
    grid[idx % n_new, idx // n_new] = flat_ids
    greps[idx % n_new, idx // n_new] = flat_reps
    return grid, greps


def fold_job_windows(handle, n_new: int) -> np.ndarray:
    """Redistribute a mid-job segmented ``JobHandle``'s per-rank dense
    Key-Value windows onto ``n_new`` surviving ranks. The folded tables
    seed a re-submitted job on the smaller mesh; exactness is guaranteed
    by the Combine dup-sum (see :func:`fold_windows`)."""
    return fold_windows(handle.windows(), n_new)
