"""Elastic re-meshing after node failure.

What makes this *cheap* in this framework is the paper's own design:

  * the task planner is decentralized (rank-indexed round-robin, no master),
    so reassigning a dead rank's remaining tasks is pure arithmetic;
  * the Combine tree dup-sums records by key across *all* ranks, so window
    ownership does not have to be preserved across a re-mesh — any
    distribution of the surviving window state onto the new mesh yields the
    exact result (``fold_windows``). This is the ownership-transfer
    semantics of paper footnote 2, promoted to a fault-tolerance mechanism.

For the LM trainer the analogue is checkpoint restore onto the surviving
mesh: ``CheckpointManager.restore(shardings=new)`` re-shards every leaf;
``remesh_plan`` picks the new mesh shape.
"""
from __future__ import annotations


import numpy as np

from repro.config import MeshConfig


def remesh_plan(n_surviving: int, prefer_model: int = 16) -> MeshConfig:
    """Largest (data, model) mesh fitting the surviving device count.

    Keeps the model axis as close to ``prefer_model`` as divides, shrinking
    data parallelism first (the cheap direction: batch shrinks, params
    re-shard; TP degree changes force a re-layout of every weight)."""
    model = prefer_model
    while model > 1 and n_surviving % model:
        model //= 2
    data = n_surviving // model
    if data * model == 0:
        raise ValueError(f"no mesh for {n_surviving} devices")
    return MeshConfig((data, model), ("data", "model"))


def fold_windows(tables: np.ndarray, n_new: int) -> np.ndarray:
    """Redistribute per-rank dense Key-Value windows (P_old, vocab) onto
    P_new ranks by summing old tables round-robin. Exact because Combine
    dup-sums by key across ranks."""
    P_old, vocab = tables.shape
    out = np.zeros((n_new, vocab), tables.dtype)
    for r in range(P_old):
        out[r % n_new] += tables[r]
    return out


def surviving_ranks(n_procs: int, failed: list[int]) -> list[int]:
    return [r for r in range(n_procs) if r not in set(failed)]


def fold_job_windows(handle, n_new: int) -> np.ndarray:
    """Redistribute a mid-job segmented ``JobHandle``'s per-rank dense
    Key-Value windows onto ``n_new`` surviving ranks. The folded tables
    seed a re-submitted job on the smaller mesh; exactness is guaranteed
    by the Combine dup-sum (see :func:`fold_windows`)."""
    return fold_windows(handle.windows(), n_new)
