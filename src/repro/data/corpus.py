"""Synthetic PUMA-like corpus.

The paper evaluates on PUMA-Wikipedia Dataset3 (~300GB of Wikipedia text).
Offline we synthesize the statistically relevant property — a Zipf word-law
token stream — with controllable size, plus the paper's imbalance model
(footnote 5: a task is *computed* r times while its input is read once).
"""
from __future__ import annotations

import numpy as np


def zipf_tokens(n: int, vocab: int, a: float = 1.3, seed: int = 0,
                dtype=np.int32) -> np.ndarray:
    """Zipf-distributed token ids in [0, vocab). a≈1.3 matches natural text."""
    rng = np.random.default_rng(seed)
    return (rng.zipf(a, size=n) % vocab).astype(dtype)


def synth_corpus(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    return zipf_tokens(n_tokens, vocab, seed=seed)


def imbalance_repeats(n_procs: int, tasks_per_proc: int, *,
                      mode: str = "balanced", hot_factor: int = 8,
                      hot_fraction: float = 0.125,
                      seed: int = 0) -> np.ndarray:
    """Per-(rank, task) compute-repeat factors — the paper's workload knob.

    balanced:    every task runs once.
    unbalanced:  a ``hot_fraction`` of ranks runs each task ``hot_factor``
                 times (the paper's "same task computed multiple times, input
                 read once").
    random:      per-task repeat ~ U{1, hot_factor} — irregular datasets.
    """
    reps = np.ones((n_procs, tasks_per_proc), np.int32)
    if mode == "balanced":
        return reps
    if mode == "unbalanced":
        n_hot = max(1, int(round(n_procs * hot_fraction)))
        reps[:n_hot] = hot_factor
        return reps
    if mode == "random":
        rng = np.random.default_rng(seed)
        return rng.integers(1, hot_factor + 1,
                            size=(n_procs, tasks_per_proc)).astype(np.int32)
    raise ValueError(mode)


def zipf_skew_repeats(n_procs: int, tasks_per_proc: int, s: float, *,
                      mean_rep: int = 4, seed: int = 0) -> np.ndarray:
    """Key-distribution-skew workload (Fan et al., arXiv:1401.0355): a
    compute budget of roughly ``n_procs * tasks_per_proc * mean_rep``
    repeat units concentrated over ranks by a Zipf law with exponent
    ``s`` — the hash-partitioned analogue of hot keys landing on few
    owners.

    ``s=0`` is balanced up to jitter; growing ``s`` piles the work onto
    ever fewer ranks (every task of a hot rank is hot — partitioning
    skew follows the *rank*). A deterministic per-task jitter of 0 or
    +1 repeat keeps tasks within a rank from being bit-identical (note
    it sits at the steal engine's hysteresis margin, so ``s=0`` still
    sees benign steal churn), and the ``>= 1`` floor per task inflates
    the nominal budget somewhat at high ``s`` — treat the budget as
    approximate, not exact, across ``s``.
    """
    assert s >= 0.0
    weights = (np.arange(1, n_procs + 1, dtype=np.float64)) ** (-s)
    weights /= weights.sum()
    budget = float(n_procs * tasks_per_proc * mean_rep)
    per_rank = np.maximum(1.0, budget * weights / tasks_per_proc)
    rng = np.random.default_rng(seed)
    jitter = rng.integers(0, 2, size=(n_procs, tasks_per_proc))
    reps = np.round(per_rank[:, None]).astype(np.int64) + jitter
    return np.maximum(reps, 1).astype(np.int32)


def lm_token_stream(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Token stream for LM training examples (markov-flavoured Zipf so the
    model has something learnable)."""
    rng = np.random.default_rng(seed)
    base = zipf_tokens(n_tokens, vocab, seed=seed)
    # inject local structure: with p=0.3, repeat the previous token + 1
    mask = rng.random(n_tokens) < 0.3
    shifted = np.roll(base, 1) + 1
    return np.where(mask, shifted % vocab, base).astype(np.int32)
