"""Host-side tokenizer — where variable-length keys live (DESIGN.md §2.1).

The paper encodes variable-length ``<h|key|value>`` records on the wire; the
TPU engine wants fixed-width lanes. The split: this module turns arbitrary
byte strings into dense int32 ids on the host (exactly the role of a
production ingest tokenizer), and everything device-side is fixed-width.

``Vocab`` can be *built by the MapReduce engine itself* (wordcount over a
corpus → top-k words), which is how the LM examples tie the paper's engine
into the training stack as the first-class ingest stage.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from collections.abc import Iterable

import numpy as np

_WORD = re.compile(rb"[A-Za-z0-9']+")

UNK = 0


def words_of(data: bytes) -> list[bytes]:
    return _WORD.findall(data)


@dataclass
class Vocab:
    """word <-> id mapping. id 0 is <unk>."""
    words: list[bytes] = field(default_factory=list)

    def __post_init__(self):
        self._index: dict[bytes, int] = {
            w: i + 1 for i, w in enumerate(self.words)}

    @property
    def size(self) -> int:
        return len(self.words) + 1

    def id_of(self, word: bytes) -> int:
        return self._index.get(word, UNK)

    def word_of(self, i: int) -> bytes:
        return b"<unk>" if i == 0 else self.words[i - 1]

    @staticmethod
    def from_counts(counts: dict[bytes, int], max_size: int) -> Vocab:
        top = sorted(counts.items(), key=lambda kv: (-kv[1], kv[0]))
        return Vocab([w for w, _ in top[: max_size - 1]])


class HashTokenizer:
    """Stateless fallback: word -> (hash % vocab). No vocab build needed;
    used by synthetic-corpus flows where exact inversion is irrelevant."""

    def __init__(self, vocab_size: int):
        self.vocab_size = vocab_size

    def encode_words(self, ws: Iterable[bytes]) -> np.ndarray:
        out = [(hash(w) & 0x7FFFFFFF) % self.vocab_size for w in ws]
        return np.asarray(out, np.int32)

    def encode(self, data: bytes) -> np.ndarray:
        return self.encode_words(words_of(data))


def encode_with_vocab(data: bytes, vocab: Vocab) -> np.ndarray:
    return np.asarray([vocab.id_of(w) for w in words_of(data)], np.int32)
