"""SegmentFeed — the paper's non-blocking I/O, feeding the engines.

"Each process asynchronously retrieves the input for the next Map task
while computing the current one" (§2.1): a background thread reads
segment t+1's tasks from a :class:`~repro.data.source.DataSource` by
``plan.file_offset`` and dispatches the host→device transfer
(``jax.device_put`` is async) while the device executes segment t —
generalizing :class:`repro.data.pipeline.DoubleBufferedLoader` from LM
batches to engine segments.

The feed owns the *assignment state* of a streaming job: the per-rank
task-id / compute-repeat grids and the column cursor. That makes it the
natural seam for

  * checkpoint restore — ``seek(cursor, ...)`` repositions the stream
    without replaying any read;
  * straggler mitigation — ``replan(...)`` swaps the not-yet-read
    columns for a throughput-proportional reassignment (the unread
    tasks are re-routed; reads are pure, so a discarded prefetch is
    just dropped).

Segments are padded to a fixed ``segment`` column width with no-op
tasks (id -1, all-sentinel tokens), so every call of the engines'
``segment_fn`` shares one compiled program regardless of tail segments
or re-planned widths.

Peak host residency is O(segment): the feed holds at most the segment
being consumed plus the one in flight (``stats.max_live_bytes`` is the
evidence the memory-bound tests pin).

With many jobs live at once (``repro.core.scheduler.JobScheduler``),
N feeds prefetch concurrently; a shared :class:`FeedBudget` arbiter
bounds their *combined* in-flight bytes so tenant prefetch cannot OOM
the host. A denied reservation only skips the background read — the
segment is built synchronously at consume time instead — so the budget
can never deadlock a job, it only serializes its I/O.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np


@dataclass
class FeedStats:
    """Observability counters (host side, not device memory)."""
    bytes_read: int = 0          # total bytes materialized from the source
    segments_built: int = 0
    prefetch_hits: int = 0       # segments served from the background read
    prefetch_misses: int = 0     # segments built synchronously
    max_live_bytes: int = 0      # high-water mark of feed-held host bytes
    sample_tasks_read: int = 0   # tasks read by a partitioner pre-pass
                                 #   (core/partition.py) — their bytes are
                                 #   included in bytes_read
    budget_denials: int = 0      # prefetches skipped because the shared
                                 #   FeedBudget was exhausted (the segment
                                 #   was built synchronously instead)
    _live: dict = field(default_factory=dict, repr=False)

    def _track(self, key, nbytes: int):
        self._live[key] = nbytes
        self.max_live_bytes = max(self.max_live_bytes,
                                  sum(self._live.values()))

    def _release(self, key):
        self._live.pop(key, None)


class FeedBudget:
    """Shared in-flight-bytes arbiter across many live SegmentFeeds.

    One scheduler-owned instance is passed to every feed it creates
    (``submit(..., feed_budget=...)``); a feed must reserve the estimated
    segment bytes before scheduling a *background* read. When the
    combined reservations would exceed ``max_live_bytes`` the prefetch is
    denied (counted in the feed's ``stats.budget_denials``) and the
    segment is built synchronously at consume time — bounded host
    memory, never a stalled job.

    One reservation is always granted when nothing is held, so a single
    oversized segment degrades to serialized prefetch instead of
    disabling prefetch fleet-wide.
    """

    def __init__(self, max_live_bytes: int):
        assert max_live_bytes > 0, "budget must be positive bytes"
        self.max_live_bytes = int(max_live_bytes)
        self._held: dict = {}
        self._lock = threading.Lock()
        self.denials = 0             # fleet-wide (per-feed copies in stats)

    @property
    def live_bytes(self) -> int:
        with self._lock:
            return sum(self._held.values())

    def try_reserve(self, key, nbytes: int) -> bool:
        with self._lock:
            if (self._held
                    and sum(self._held.values()) + nbytes
                    > self.max_live_bytes):
                self.denials += 1
                return False
            self._held[key] = int(nbytes)
            return True

    def release(self, key):
        with self._lock:
            self._held.pop(key, None)


class SegmentFeed:
    """Pull-based segment stream over a DataSource for one job.

    ``next_segment()`` returns ``(tokens, task_ids, repeats)`` host/device
    blocks of shape ``(P, segment, S)`` / ``(P, segment)`` and schedules
    the following segment's read+transfer in the background.
    """

    def __init__(self, source, plan, task_ids: np.ndarray,
                 repeats: np.ndarray, segment: int,
                 *, sharding=None, prefetch: bool = True,
                 budget: FeedBudget | None = None):
        self.source = source
        self.plan = plan
        self.segment = int(segment)
        assert self.segment > 0, "segment width must be positive"
        self._ids = np.array(task_ids, np.int32)       # (P, T)
        self._reps = np.array(repeats, np.int32)       # (P, T)
        self._cursor = 0                               # columns consumed
        self._sharding = sharding
        self._prefetch = prefetch
        self._budget = budget
        self._budget_key = None                        # held reservation
        self._gen = 0                                  # seek/replan epoch
        self._pending: tuple[int, int, Future] | None = None
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="segment-feed")
        self._closed = False
        self._lock = threading.Lock()
        self._stats_lock = threading.Lock()   # feed thread vs seek/replan
        self.stats = FeedStats()

    # -- assignment state ---------------------------------------------------

    @property
    def cursor(self) -> int:
        return self._cursor

    @property
    def total_columns(self) -> int:
        return self._ids.shape[1]

    @property
    def task_ids_grid(self) -> np.ndarray:
        """The full (P, T) assignment, consumed prefix included."""
        return self._ids

    @property
    def repeats_grid(self) -> np.ndarray:
        return self._reps

    @property
    def exhausted(self) -> bool:
        return self._cursor >= self.total_columns

    def remaining_task_ids(self) -> np.ndarray:
        """Global ids of the not-yet-consumed tasks, sorted."""
        ids = self._ids[:, self._cursor:]
        return np.sort(ids[ids >= 0])

    def consumed_task_ids(self) -> np.ndarray:
        """Global ids of the already-executed tasks (columns before the
        cursor), sorted. Over a composite fleet grid these are (job,
        task) ids — how a :class:`~repro.core.workdomain.WorkDomain`
        detects that one member job fully drained mid-co-schedule and
        can be finalized while its siblings keep running."""
        ids = self._ids[:, : self._cursor]
        return np.sort(ids[ids >= 0])

    def read_tasks(self, task_ids) -> np.ndarray:
        """Serve arbitrary tasks by *global id*, independent of the
        assignment grids or cursor — the host-side twin of the engine's
        steal fetch. Over a :class:`~repro.data.source.FleetSource` the
        global id is a composite (job, task) id, so one feed serves task
        reads across job boundaries — the cross-job steal fetch and a
        domain checkpoint restore address members through this same
        path. Reads are pure, so serving a task to a rank other
        than its original assignee replays nothing and disturbs no
        stream position; the bytes still count into ``stats``."""
        from repro.core.planner import read_tasks
        tokens = read_tasks(self.source, self.plan, task_ids)
        with self._stats_lock:
            self.stats.bytes_read += tokens.nbytes
        return tokens

    def sample_tasks(self, task_ids) -> np.ndarray:
        """:meth:`read_tasks` for a partitioner's sampling pre-pass —
        same pure by-global-id read, separately accounted so a job's
        stats show what the skew sample cost."""
        tokens = self.read_tasks(task_ids)
        with self._stats_lock:
            self.stats.sample_tasks_read += int(np.asarray(task_ids).size)
        return tokens

    # -- segment construction ----------------------------------------------

    def _build(self, start: int, gen: int):
        """Read one segment's tasks by file offset and dispatch the
        device transfer — the body that runs in the feed thread."""
        end = min(start + self.segment, self.total_columns)
        P = self._ids.shape[0]
        ids = np.full((P, self.segment), -1, np.int32)
        reps = np.ones((P, self.segment), np.int32)
        ids[:, : end - start] = self._ids[:, start:end]
        reps[:, : end - start] = self._reps[:, start:end]
        from repro.core.planner import gather_segment  # lazy: no cycle
        tokens = gather_segment(self.source, self.plan, ids)
        with self._stats_lock:
            self.stats.bytes_read += tokens.nbytes
            self.stats.segments_built += 1
            if gen == self._gen:    # stale prefetch after seek/replan:
                self.stats._track((gen, start), tokens.nbytes)  # don't leak
        if self._sharding is not None:
            import jax
            tokens = jax.device_put(tokens, self._sharding)  # async
        return tokens, ids, reps

    def _schedule(self, start: int):
        if (self._closed or not self._prefetch
                or start >= self.total_columns):
            self._pending = None
            return
        gen = self._gen
        if self._budget is not None:
            # reserve the estimated segment bytes before the background
            # read; a denial is not an error — next_segment just builds
            # the segment synchronously when it gets there
            est = (self._ids.shape[0] * self.segment
                   * self.plan.task_size * 4)
            key = (id(self), gen, start)
            if not self._budget.try_reserve(key, est):
                with self._stats_lock:
                    self.stats.budget_denials += 1
                self._pending = None
                return
            self._budget_key = key
        self._pending = (gen, start,
                         self._pool.submit(self._build, start, gen))

    def _drop_budget(self):
        if self._budget is not None and self._budget_key is not None:
            self._budget.release(self._budget_key)
            self._budget_key = None

    # -- the streaming contract --------------------------------------------

    def next_segment(self):
        """Return the next ``(tokens, task_ids, repeats)`` segment and
        kick off the background read of the one after; ``None`` when the
        stream is exhausted."""
        with self._lock:
            if self.exhausted:
                return None
            start, gen = self._cursor, self._gen
            if (self._pending is not None
                    and self._pending[:2] == (gen, start)):
                seg = self._pending[2].result()
                self.stats.prefetch_hits += 1
            else:
                seg = self._build(start, gen)
                self.stats.prefetch_misses += 1
            with self._stats_lock:
                self.stats._release((gen, start))
            self._drop_budget()
            self._cursor = min(start + self.segment, self.total_columns)
            self._schedule(self._cursor)
            return seg

    def ready(self) -> bool:
        """True when :meth:`next_segment` would not block on input I/O:
        the stream is exhausted (returns None immediately), or the
        background read of the segment at the cursor has completed. A
        scheduler polls this to time-slice the job whose data is already
        on its way to the device (``JobHandle.ready``)."""
        with self._lock:
            if self.exhausted or self._closed:
                return True
            p = self._pending
            return (p is not None and p[:2] == (self._gen, self._cursor)
                    and p[2].done())

    def prime(self):
        """Kick off the background read of the segment at the cursor
        without consuming anything — so a freshly admitted job's first
        segment prefetches while *other* jobs run their slices.
        Idempotent; a no-op when a prefetch is already pending (or the
        shared budget denies the reservation)."""
        with self._lock:
            if self._pending is None:
                self._schedule(self._cursor)

    def seek(self, cursor: int, task_ids=None, repeats=None):
        """Reposition the stream (checkpoint restore): install the saved
        assignment grids and cursor. No segment before ``cursor`` is ever
        re-read — restore seeks, it does not replay."""
        with self._lock:
            if task_ids is not None:
                self._ids = np.array(task_ids, np.int32)
            if repeats is not None:
                self._reps = np.array(repeats, np.int32)
            self._cursor = int(cursor)
            self._invalidate()
        return self

    def replan(self, task_ids: np.ndarray, repeats: np.ndarray):
        """Re-route the *unread* tasks (straggler mitigation): columns
        before the cursor keep their history; columns from the cursor on
        are replaced by the new (P, W) assignment. Any in-flight prefetch
        of the old assignment is discarded."""
        task_ids = np.asarray(task_ids, np.int32)
        repeats = np.asarray(repeats, np.int32)
        assert task_ids.shape == repeats.shape
        assert task_ids.shape[0] == self._ids.shape[0], "rank count fixed"
        with self._lock:
            done = self._ids[:, : self._cursor]
            old = set(self.remaining_task_ids().tolist())
            new = task_ids[task_ids >= 0].tolist()
            assert sorted(new) == sorted(old), (
                "replan must cover exactly the unread tasks once "
                f"(unread={sorted(old)}, got={sorted(new)})")
            self._ids = np.concatenate([done, task_ids], axis=1)
            self._reps = np.concatenate(
                [self._reps[:, : self._cursor], repeats], axis=1)
            self._invalidate()
        return self

    def _invalidate(self):
        with self._stats_lock:
            self._gen += 1
            self.stats._live.clear()
        if self._pending is not None:
            self._pending[2].cancel()
            self._pending = None
        self._drop_budget()
        self._schedule(self._cursor)

    def close(self):
        """Stop the prefetch thread. Idempotent; a closed feed can still
        be consumed (reads fall back to the caller's thread)."""
        with self._lock:
            if not self._closed:
                self._closed = True
                self._pending = None
                self._drop_budget()
                self._pool.shutdown(wait=False)
