"""Double-buffered host→device pipeline — the non-blocking-I/O analogue.

The paper overlaps each Map task's compute with the *asynchronous retrieval
of the next task's input* (non-blocking MPI I/O). On TPU the same role is
played by dispatching ``jax.device_put`` for batch t+1 while batch t's step
is still executing (JAX dispatch is async; the host thread runs ahead).
``DoubleBufferedLoader`` keeps exactly one batch in flight.

For MapReduce jobs this pattern is generalized by
``repro.data.feed.SegmentFeed``, which prefetches engine *segments* from
any offset-addressable ``repro.data.source.DataSource`` (and owns the
seek/replan bookkeeping a streaming job needs); this module remains the
LM-training batch pipeline.
"""
from __future__ import annotations

from collections.abc import Iterator

import jax
import numpy as np


class DoubleBufferedLoader:
    """Wraps a host batch iterator; keeps the next device batch in flight."""

    def __init__(self, host_iter: Iterator, sharding=None):
        self._it = iter(host_iter)
        self._sharding = sharding
        self._next = self._put(next(self._it, None))

    def _put(self, host_batch):
        if host_batch is None:
            return None
        if self._sharding is not None:
            return jax.tree.map(
                lambda x, s: jax.device_put(x, s), host_batch,
                self._sharding)
        return jax.tree.map(jax.device_put, host_batch)

    def __iter__(self):
        return self

    def __next__(self):
        if self._next is None:
            raise StopIteration
        out = self._next
        # schedule the following transfer before the caller blocks on `out`
        self._next = self._put(next(self._it, None))
        return out


def lm_batches(tokens: np.ndarray, batch: int, seq: int, *,
               n_steps: int | None = None, seed: int = 0,
               skip: int = 0):
    """Yield {tokens, labels} LM batches from a flat token stream.

    ``skip`` fast-forwards the sampling RNG — restart-deterministic data
    order (the restore path replays the exact batch sequence)."""
    n_per = batch * (seq + 1)
    rng = np.random.default_rng(seed)
    for _ in range(skip):
        rng.integers(0, max(1, len(tokens) - n_per - 1))
    step = 0
    while n_steps is None or step < n_steps:
        start = int(rng.integers(0, max(1, len(tokens) - n_per - 1)))
        window = tokens[start: start + n_per]
        if len(window) < n_per:
            window = np.pad(window, (0, n_per - len(window)))
        grid = window.reshape(batch, seq + 1)
        yield {"tokens": grid[:, :-1].astype(np.int32),
               "labels": grid[:, 1:].astype(np.int32)}
        step += 1
