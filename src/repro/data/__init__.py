from repro.data.corpus import synth_corpus, zipf_tokens
from repro.data.tokenizer import HashTokenizer, Vocab
from repro.data.pipeline import DoubleBufferedLoader, lm_batches
from repro.data.source import (ArraySource, ConcatSource, DataSource,
                               MmapTokenSource, ZipfSource, as_source,
                               read_all)
from repro.data.feed import FeedStats, SegmentFeed
