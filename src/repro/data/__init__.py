from repro.data.corpus import synth_corpus, zipf_tokens
from repro.data.tokenizer import HashTokenizer, Vocab
from repro.data.pipeline import DoubleBufferedLoader, lm_batches
