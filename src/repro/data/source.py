"""DataSource — the streaming dataset side of the Job API.

The paper's decoupled strategy pairs one-sided communication with
*non-blocking I/O*: each process asynchronously retrieves the next Map
task's input (by file offset) while computing the current one (§2.1).
That requires the dataset to be addressable by offset, not materialized
up front — ``submit`` used to demand a fully resident 1-D array, capping
dataset size at host RAM and making the I/O half of the paper
structurally impossible.

A :class:`DataSource` is the minimal offset-addressable contract:

  * ``len_elements()``        — total int32 elements in the stream;
  * ``read(offset, size)``    — up to ``size`` elements starting at
                                ``offset`` (short reads at EOF). Reads
                                are pure: any offset may be read at any
                                time, in any order, from any thread —
                                which is what lets the prefetcher
                                (:class:`repro.data.feed.SegmentFeed`)
                                run ahead and a restored job seek
                                instead of replaying.

Implementations:

  * :class:`ArraySource`     — resident numpy array (back-compat;
                               ``submit`` auto-wraps raw arrays);
  * :class:`MmapTokenSource` — memory-mapped token file: datasets far
                               larger than host RAM, pages touched only
                               as tasks read them;
  * :class:`ZipfSource`      — lazy synthetic PUMA-like corpus,
                               generated per fixed-size block on read
                               (offset-deterministic, zero bytes stored);
  * :class:`ConcatSource`    — concatenation of sources (sharded corpora
                               on disk presented as one stream);
  * :class:`FleetSource`     — K member sources laid out at a fixed
                               element stride, so a composite (job, task)
                               id addresses any member's task through one
                               unmodified ``TaskPlan`` — the read path of
                               cross-job co-scheduling
                               (``repro.core.workdomain``).
"""
from __future__ import annotations

import os
from collections.abc import Sequence
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class DataSource(Protocol):
    """Offset-addressable int32 element stream."""

    def len_elements(self) -> int:
        ...

    def read(self, offset: int, size: int) -> np.ndarray:
        """Return elements ``[offset, offset+size)`` as int32; short at
        EOF, empty past it. Must be pure and thread-safe."""
        ...


def as_source(dataset) -> DataSource:
    """``submit``'s auto-wrap: pass through a DataSource, wrap anything
    array-like (list, tuple, np.ndarray) in an :class:`ArraySource`."""
    if isinstance(dataset, DataSource) and not isinstance(dataset,
                                                          np.ndarray):
        return dataset
    return ArraySource(dataset)


def read_all(source: DataSource, block: int = 1 << 20) -> np.ndarray:
    """Materialize a source (oracle/debug helper — O(dataset) host RAM,
    exactly what the streaming path avoids)."""
    n = source.len_elements()
    out = np.empty((n,), np.int32)
    filled = 0
    while filled < n:
        chunk = source.read(filled, min(block, n - filled))
        out[filled: filled + len(chunk)] = chunk
        filled += len(chunk)
    return out


class ArraySource:
    """A resident in-memory array behind the DataSource contract."""

    def __init__(self, array):
        self._array = np.asarray(array, np.int32).reshape(-1)

    def len_elements(self) -> int:
        return len(self._array)

    def read(self, offset: int, size: int) -> np.ndarray:
        return self._array[offset: offset + size]


class MmapTokenSource:
    """Memory-mapped flat token file — datasets ≫ host RAM.

    The file is raw little-endian tokens of ``dtype`` (default int32,
    the engines' element type). ``read`` copies just the requested slice
    out of the map, so peak host residency is O(read), not O(file).
    """

    def __init__(self, path: str, dtype=np.int32):
        self.path = path
        self._dtype = np.dtype(dtype)
        self._n = os.path.getsize(path) // self._dtype.itemsize
        self._mm = np.memmap(path, dtype=self._dtype, mode="r",
                             shape=(self._n,))

    def len_elements(self) -> int:
        return self._n

    def read(self, offset: int, size: int) -> np.ndarray:
        return np.asarray(self._mm[offset: offset + size], np.int32)


class ZipfSource:
    """Lazy synthetic Zipf corpus (the PUMA stand-in, repro.data.corpus)
    generated per-read — an arbitrarily large dataset that stores zero
    bytes.

    Generation is blocked: element i belongs to block ``i // block``,
    and each block is produced by its own counter-keyed RNG, so
    ``read(offset, size)`` is deterministic regardless of read order or
    segmentation — the property the streamed-equals-resident tests pin.
    """

    def __init__(self, n: int, vocab: int, a: float = 1.3, seed: int = 0,
                 block: int = 65536):
        self.n, self.vocab, self.a, self.seed = n, vocab, a, seed
        self.block = block
        self._cache = (-1, None)    # last generated (block, tokens):
        # sequential task reads hit the same block ~block/task_size times

    def len_elements(self) -> int:
        return self.n

    def _gen_block(self, b: int) -> np.ndarray:
        cached_b, cached = self._cache      # atomic tuple read: benign
        if cached_b == b:                   # regeneration on a race
            return cached
        rng = np.random.default_rng([self.seed, b])
        size = min(self.block, self.n - b * self.block)
        blk = (rng.zipf(self.a, size=size) % self.vocab).astype(np.int32)
        self._cache = (b, blk)
        return blk

    def read(self, offset: int, size: int) -> np.ndarray:
        end = min(offset + size, self.n)
        if end <= offset:
            return np.empty((0,), np.int32)
        out = np.empty((end - offset,), np.int32)
        for b in range(offset // self.block, (end - 1) // self.block + 1):
            blk = self._gen_block(b)
            lo = max(offset, b * self.block)
            hi = min(end, b * self.block + len(blk))
            out[lo - offset: hi - offset] = blk[lo - b * self.block:
                                                hi - b * self.block]
        return out


class FleetSource:
    """K member sources at a fixed per-member element stride.

    Member ``j`` occupies the element window ``[j * stride, (j + 1) *
    stride)``; within it, the member's own elements come first and the
    remainder is an empty *pad region* (reads there return nothing, so
    the planner's sentinel padding matches a solo run bit-for-bit).
    With ``stride = member_tasks_ceiling * task_size``, the composite
    task id ``slot * costride + local`` of a
    :class:`~repro.core.workdomain.WorkDomain` lands on exactly the
    bytes the member's solo plan would read — ``plan.file_offset`` is
    reused unchanged, which is what makes cross-job task reads (and the
    engine's cross-job steal fetch) exact by construction.

    A read never crosses a member boundary: it is truncated at the end
    of its member window (the DataSource short-read contract, applied
    per member).
    """

    def __init__(self, sources: Sequence[DataSource], stride: int):
        self._sources = [as_source(s) for s in sources]
        self.stride = int(stride)
        for j, s in enumerate(self._sources):
            if s.len_elements() > self.stride:
                raise ValueError(
                    f"member {j} holds {s.len_elements()} elements — more "
                    f"than the fleet stride {self.stride}")

    def len_elements(self) -> int:
        return self.stride * len(self._sources)

    def read(self, offset: int, size: int) -> np.ndarray:
        j = offset // self.stride
        if not 0 <= j < len(self._sources):
            return np.empty((0,), np.int32)
        local = offset - j * self.stride
        take = min(size, self.stride - local)   # stop at the boundary
        return self._sources[j].read(local, take)


class ConcatSource:
    """Concatenation of sources — e.g. a sharded on-disk corpus
    (`part-*.bin`) presented as one contiguous stream."""

    def __init__(self, sources: Sequence[DataSource]):
        self._sources = list(sources)
        self._starts = np.cumsum([0] + [s.len_elements()
                                        for s in self._sources])

    def len_elements(self) -> int:
        return int(self._starts[-1])

    def read(self, offset: int, size: int) -> np.ndarray:
        end = min(offset + size, self.len_elements())
        if end <= offset:
            return np.empty((0,), np.int32)
        parts = []
        # first child whose end is past `offset`
        i = int(np.searchsorted(self._starts[1:], offset, side="right"))
        while offset < end:
            lo = offset - int(self._starts[i])
            take = min(end, int(self._starts[i + 1])) - offset
            parts.append(self._sources[i].read(lo, take))
            offset += take
            i += 1
        return np.concatenate(parts) if len(parts) > 1 else parts[0]
