"""Oracle: the decode partials path in models/attention."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import _decode_partials, combine_partials


def flash_decode_ref(q, k, v, t):
    """q: (B, H, hd); k/v: (B, S, KV, hd); t: current length."""
    S = k.shape[1]
    o, l, m = _decode_partials(q, k, v, jnp.arange(S), t)
    out = combine_partials(o, l, m, None)
    B, KV, G, hd = out.shape
    return out.reshape(B, KV * G, hd).astype(q.dtype)
