"""Flash-decode: one query token against a long KV cache.

Decode is memory-bound (arithmetic intensity ~2 FLOPs/byte: every cached
key/value byte is read once per step), so the kernel's only job is to
stream the cache through VMEM at full HBM bandwidth while the VPU keeps up.
Per grid step: a (block_kv × hd) K tile + V tile and the per-KV-head query
group (G × hd) — the G query heads of a KV head ride along in one program
so K/V bytes are read once per *group*, not once per head (the GQA
bandwidth saving is the whole point of grouped queries at decode).

Grid: (B*KV, kv_blocks) — kv sequential with (m, l, acc) carry. The current
length ``t`` arrives via scalar prefetch (SMEM) and masks the tail block;
with paging upstream (serve/engine.py) blocks past t are never scheduled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params as kernels_compat_params

NEG_INF = -1e30


def _fd_kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
               scale: float, block_kv: int):
    ik = pl.program_id(1)
    n_kv = pl.num_programs(1)
    t = t_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    k_start = ik * block_kv

    @pl.when(k_start < t)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale        # (G, hd)
        k = k_ref[0].astype(jnp.float32)                # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (G, bkv)
        kv_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kv_pos < t, s, NEG_INF)
        m_prev = m_s[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(kv_pos < t, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[:, 0] = l_s[:, 0] * corr + jnp.sum(p, axis=1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[:, 0] = m_new

    @pl.when(ik == n_kv - 1)
    def _fin():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_decode_pallas(q, k, v, t, *, block_kv: int = 1024,
                        interpret: bool = True):
    """q: (BKV, G, hd) query groups; k/v: (BKV, S, hd); t: scalar int32
    current length. Returns (BKV, G, hd)."""
    BKV, G, hd = q.shape
    _, S, _ = k.shape
    block_kv = min(block_kv, S)
    n_kv = -(-S // block_kv)
    pad = n_kv * block_kv - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_fd_kernel, scale=hd ** -0.5,
                               block_kv=block_kv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(BKV, n_kv),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, ik, t_ref: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, ik, t_ref: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, ik, t_ref: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, ik, t_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BKV, G, hd), q.dtype),
        compiler_params=kernels_compat_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray([t], jnp.int32) if jnp.ndim(t) == 0 else t, q, k, v)
