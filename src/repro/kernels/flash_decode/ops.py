from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import flash_decode_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("block_kv", "interpret"))
def flash_decode(q, k, v, t, *, block_kv: int = 1024,
                 interpret: bool | None = None):
    """q: (B, H, hd); k/v: (B, S, KV, hd); t: scalar current length.
    Returns (B, H, hd)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, hd = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    o = flash_decode_pallas(qg, kf, vf, t, block_kv=block_kv,
                            interpret=interpret)
    return o.reshape(B, KV * G, hd)
