from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.backend import default_interpret
from repro.kernels.flash_decode.kernel import flash_decode_pallas


@partial(jax.jit, static_argnames=("block_kv", "interpret"))
def flash_decode(q, k, v, t, *, block_kv: int = 1024,
                 interpret: bool | None = None):
    """q: (B, H, hd); k/v: (B, S, KV, hd); t: scalar current length.
    Returns (B, H, hd)."""
    interpret = default_interpret(interpret)
    B, H, hd = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, KV, G, hd).reshape(B * KV, G, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    o = flash_decode_pallas(qg, kf, vf, t, block_kv=block_kv,
                            interpret=interpret)
    return o.reshape(B, KV * G, hd)
