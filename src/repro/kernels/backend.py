"""One interpret-mode policy for every pallas kernel wrapper.

Every ``kernels/*/ops.py`` used to carry its own ``_on_tpu()`` copy; the
static analyzer (repro.analysis, rule PAL003) reasons about interpret-mode
fallbacks, which only works if there is exactly one policy to reason
about. The contract: wrappers take ``interpret: bool | None = None`` and
resolve it through :func:`default_interpret` — compiled on TPU hardware,
interpreter everywhere else, explicit values always win.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    """True when the default jax backend is real TPU hardware."""
    return jax.default_backend() == "tpu"


def default_interpret(interpret: bool | None) -> bool:
    """Resolve a wrapper's ``interpret`` argument against the policy."""
    return not on_tpu() if interpret is None else interpret
