"""jit wrapper: (B, S, H, hd) layout in, GQA head-group mapping, padding."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.backend import default_interpret
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@partial(jax.jit, static_argnames=("causal", "window", "interpret",
                                   "block_q", "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool | None = None):
    """q: (B, S, H, hd); k/v: (B, Skv, KV, hd). Returns (B, S, H, hd)."""
    interpret = default_interpret(interpret)
    B, S, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    # batch-major flatten so kv row = q row // group
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    o = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
