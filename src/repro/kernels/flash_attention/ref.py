"""Oracle: the chunked reference in models/attention (itself validated
against the O(S^2) dense form)."""
from repro.models.attention import attention_dense_ref, flash_attention_ref
