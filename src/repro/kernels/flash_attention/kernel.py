"""Blocked online-softmax attention (flash) for prefill/train.

VMEM tiling: per grid step the kernel holds one (block_q × hd) query tile,
one (block_kv × hd) key/value tile and fp32 running (m, l, acc) scratch —
with block_q = block_kv = 512 and hd = 128 that is ~1.4 MB, well inside the
~16 MB v5e VMEM even double-buffered. Matmul dims are multiples of 128 so
the MXU runs dense. GQA never materializes repeated KV heads: the k/v
BlockSpec index-maps H query-head programs onto their KV head
(``bh // group``), so KV reads are shared.

Grid: (B*H, q_blocks, kv_blocks) — kv innermost, sequential (running
softmax carry); q and batch-head parallel. Causal + sliding-window masks
applied per tile; fully-masked tiles are skipped with pl.when (upper
triangle costs nothing, the SWA band skips both sides).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params as kernels_compat_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
               scale: float, causal: bool, window: int,
               block_q: int, block_kv: int, s_valid: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_start = iq * block_q
    k_start = ik * block_kv

    # tile-level visibility: any (q, k) pair in this tile unmasked?
    vis = True
    if causal:
        vis = (k_start <= q_start + block_q - 1)
    if window > 0:
        # SWA band: k > q - window  for some pair in tile
        vis = vis & (k_start + block_kv - 1 > q_start - window)

    @pl.when(vis)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale       # (bq, hd)
        k = k_ref[0].astype(jnp.float32)               # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (bq, bkv)
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = k_pos < s_valid                          # padded tail
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_s[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[:, 0] = l_s[:, 0] * corr + jnp.sum(p, axis=1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[:, 0] = m_new

    @pl.when(ik == n_kv - 1)
    def _fin():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[:, 0], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 512, block_kv: int = 512,
                           interpret: bool = True):
    """q: (BH, Sq, hd); k/v: (BKV, Skv, hd); H = G * KV with BH = B*H,
    BKV = B*KV — caller lays out batch-major so ``bh // group`` finds the
    KV row. Returns (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    BKV, Skv, _ = k.shape
    group = BH // BKV
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    n_q = -(-Sq // block_q)
    n_kv = -(-Skv // block_kv)
    q_pad = n_q * block_q - Sq
    kv_pad = n_kv * block_kv - Skv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0)))

    kernel = functools.partial(
        _fa_kernel, scale=hd ** -0.5, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, s_valid=Skv)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((BH, n_q * block_q, hd), q.dtype),
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, iq, ik: (bh, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=kernels_compat_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
