"""Fused 1S engine step: local-reduce -> owner lookup -> bucketize -> fold.

The unfused hot path (core/onesided.py::_step) materializes the (vocab,)
dense window **twice per task** — once folding the in-flight chunk, once
folding the overflow records — plus three argsort passes (local_reduce and
bucketize). This kernel streams the window through VMEM exactly once per
step and keeps every record-domain intermediate on-chip, which is the
whole win: at engine scale the table traffic dominates, so fusing the two
folds into one pass halves the hot loop's bytes moved (fig12 states this
as achieved fraction of memory bandwidth, not just a relative speedup).

Structure (one sequential grid over vocab tiles, wordcount_hash's
revisited-block idiom rotated into the record domain):

  grid step 0   the record pass: dup-sum the task's records with an
                S x S first-occurrence compare (the compare-reduce idiom
                of kernels/wordcount_hash, applied record-vs-record
                instead of record-vs-vocab — O(S^2), vocab-independent),
                rank unique keys ascending so the layout is bit-identical
                to kv.local_reduce, re-run the whole reduction under the
                footnote-5 repeat loop, look owners up in the carried
                owner_map/owner_split (split keys pick a replica by mixed
                task id, exactly partition.lookup_owner), place records
                into per-owner push buckets with kv.bucketize's capacity
                rule, and stash the overflow in VMEM scratch. The scratch
                persists across the sequential grid (flash_decode's m/l/acc
                pattern), so overflow is *carried*, never re-read from HBM.
  every step j  fold the previous step's pending chunk and the scratch
                overflow into table tile j (on-chip read-modify-write,
                one HBM read + one write per tile).

Exactness contract: every output — folded table, (P, cap) buckets,
per-owner counts — is **bit-identical** to ref.fused_step_ref, i.e. to
the unfused composition, for all int32 inputs (summation order is free
mod 2^32; bucket layout matches because key-ascending rank order equals
local_reduce's sorted layout and bucketize's stable owner sort preserves
it). Overflow records are counted into the window fold, never dropped —
the PR 6 saturating-combine accounting downstream is untouched.

The in-kernel scatters (bucket placement, tile fold) are XLA scatters in
interpret mode; on a real TPU target at these block sizes they lower to
one-hot selects, same as the compare matrices. The record pass is O(S^2),
so the fused path targets moderate task sizes (S <= 1024); the unfused
path stays the default and the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.kv import KEY_SENTINEL, mix32
from repro.kernels import compiler_params as kernels_compat_params


def _dup_sum(keys, vals, out_cap: int):
    """First-occurrence dup-sum with key-ascending ranks — value-identical
    to kv.local_reduce(keys, vals, out_cap) for n_unique <= out_cap."""
    L = keys.shape[0]
    valid = keys != KEY_SENTINEL
    eq = ((keys[:, None] == keys[None, :])
          & valid[:, None] & valid[None, :])
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    first = valid & (jnp.sum((eq & (jj < ii)).astype(jnp.int32),
                             axis=1) == 0)
    sums = jnp.sum(jnp.where(eq, vals[None, :], 0), axis=1)
    # rank = number of distinct keys strictly smaller -> sorted layout
    less = first[None, :] & (keys[None, :] < keys[:, None])
    rank = jnp.sum(less.astype(jnp.int32), axis=1)
    slot = jnp.where(first, rank, out_cap)          # ghost slot out_cap
    uk = jnp.full((out_cap + 1,), KEY_SENTINEL, jnp.int32).at[slot].set(
        jnp.where(first, keys, KEY_SENTINEL))[:out_cap]
    uv = jnp.zeros((out_cap + 1,), jnp.int32).at[slot].set(
        jnp.where(first, sums, 0))[:out_cap]
    return uk, uv


def _fused_kernel(s_ref, om_ref, os_ref, keys_ref, vals_ref,
                  pk_ref, pv_ref, tin_ref,
                  tout_ref, bk_ref, bv_ref, cnt_ref,
                  ofk_s, ofv_s, *,
                  block_voc: int, n_procs: int, cap: int, vocab: int):
    j = pl.program_id(0)
    P = n_procs

    @pl.when(j == 0)
    def _record_pass():
        keys = keys_ref[...]
        vals = vals_ref[...]
        rep = s_ref[0]
        task_id = s_ref[1]
        S = keys.shape[0]

        # Local reduce + footnote-5 repeat: each extra repetition re-runs
        # the full reduction seeded with a value-preserving dependency on
        # the previous one (kv.local_reduce_repeated's exact recurrence,
        # so even wrap-negative sums replay identically).
        def body(_, carry):
            uk, uv = carry
            k_dep = jnp.where(uv < 0, uk, KEY_SENTINEL)
            v_dep = jnp.where(uv < 0, uv, 0)
            return _dup_sum(jnp.concatenate([keys, k_dep]),
                            jnp.concatenate([vals, v_dep]), S)

        uk, uv = jax.lax.fori_loop(1, jnp.maximum(rep, 1), body,
                                   _dup_sum(keys, vals, S))

        # Owner lookup against the carried partition maps (prefetched
        # once per step, never re-fetched per vocab tile) —
        # partition.lookup_owner verbatim.
        valid_u = (uk != KEY_SENTINEL) & (uk >= 0) & (uk < vocab)
        idx = jnp.where(valid_u, uk, 0)
        base = om_ref[...][idx]
        ksplit = jnp.maximum(os_ref[...][idx], 1)
        pick = (mix32(task_id.astype(jnp.uint32))
                % ksplit.astype(jnp.uint32)).astype(jnp.int32)
        owner = (base + jnp.where(ksplit > 1, pick, 0)) % jnp.int32(P)
        owner = jnp.where(valid_u, owner, jnp.int32(P))

        # Bucketize: slots are already owner-stable in key order, so the
        # position of a record in its owner's bucket is the count of
        # earlier same-owner slots — one more S x S compare-reduce.
        si = jax.lax.broadcasted_iota(jnp.int32, (S, S), 0)
        sj = jax.lax.broadcasted_iota(jnp.int32, (S, S), 1)
        same = (owner[None, :] == owner[:, None]) & (sj < si)
        pos = jnp.sum(same.astype(jnp.int32), axis=1)
        ranks = jax.lax.broadcasted_iota(jnp.int32, (P, S), 0)
        tot = jnp.sum((owner[None, :] == ranks).astype(jnp.int32), axis=1)
        cnt_ref[...] = jnp.minimum(tot, cap)
        in_cap = (pos < cap) & (owner < P)
        flat = jnp.where(in_cap, owner * cap + pos, P * cap)
        bk_ref[...] = jnp.full((P * cap + 1,), KEY_SENTINEL,
                               jnp.int32).at[flat].set(
            jnp.where(in_cap, uk, KEY_SENTINEL))[:-1].reshape(P, cap)
        bv_ref[...] = jnp.zeros((P * cap + 1,), jnp.int32).at[flat].set(
            jnp.where(in_cap, uv, 0))[:-1].reshape(P, cap)
        # overflow -> scratch; folded locally below (ownership transfer)
        of = in_cap | (owner >= P)
        ofk_s[...] = jnp.where(of, KEY_SENTINEL, uk)
        ofv_s[...] = jnp.where(of, 0, uv)

    # Fold the in-flight chunk + overflow into this vocab tile: the one
    # table pass of the fused step (the unfused path makes two).
    base_key = j * block_voc
    tile = tin_ref[...]

    def fold(tile, fk, fv):
        local = fk - base_key
        hit = (fk != KEY_SENTINEL) & (local >= 0) & (local < block_voc)
        return tile.at[jnp.where(hit, local, 0)].add(
            jnp.where(hit, fv, 0))

    tile = fold(tile, pk_ref[...].reshape(-1), pv_ref[...].reshape(-1))
    tile = fold(tile, ofk_s[...], ofv_s[...])
    tout_ref[...] = tile


def fused_map_pallas(keys, vals, rep, task_id, owner_map, owner_split,
                     pending_k, pending_v, table, *, n_procs: int,
                     cap: int, block_voc: int = 0,
                     interpret: bool = True):
    """One fused 1S engine step. keys/vals: (S,) mapped records; rep,
    task_id: int32 scalars; owner_map/owner_split: (vocab,) carried
    partition maps; pending_k/pending_v: (P, cap) in-flight chunk;
    table: (vocab,) dense window. Returns (table, bk, bv, counts),
    bit-identical to ref.fused_step_ref.

    The partition maps ride the scalar-prefetch lane (flash_decode's
    ``t`` / paged-attention's block-table idiom): they are *routing
    tables* consulted by gather, not streamed data, so they must not be
    re-fetched per vocab tile — this is what keeps the fused step's HBM
    traffic at one table pass. ``block_voc=0`` (default) folds the whole
    padded vocab as one tile — right off-TPU and for VMEM-resident
    windows; set a real tile size for larger-than-VMEM windows.
    """
    S = keys.shape[0]
    V = owner_map.shape[0]
    P = n_procs
    block_voc = min(block_voc, V) if block_voc else V
    n_tiles = -(-V // block_voc)
    pad = n_tiles * block_voc - V
    tbl = jnp.pad(table, (0, pad)) if pad else table
    scalars = jnp.stack([jnp.asarray(rep, jnp.int32).reshape(()),
                         jnp.asarray(task_id, jnp.int32).reshape(())])

    kernel = functools.partial(_fused_kernel, block_voc=block_voc,
                               n_procs=P, cap=cap, vocab=V)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # [rep, task_id], owner_map, split
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((S,), lambda j, *s: (0,)),           # keys
            pl.BlockSpec((S,), lambda j, *s: (0,)),           # vals
            pl.BlockSpec((P, cap), lambda j, *s: (0, 0)),     # pending_k
            pl.BlockSpec((P, cap), lambda j, *s: (0, 0)),     # pending_v
            pl.BlockSpec((block_voc,), lambda j, *s: (j,)),   # table tile
        ],
        out_specs=[
            pl.BlockSpec((block_voc,), lambda j, *s: (j,)),   # table tile
            pl.BlockSpec((P, cap), lambda j, *s: (0, 0)),     # bk
            pl.BlockSpec((P, cap), lambda j, *s: (0, 0)),     # bv
            pl.BlockSpec((P,), lambda j, *s: (0,)),           # counts
        ],
        scratch_shapes=[pltpu.VMEM((S,), jnp.int32),          # overflow k
                        pltpu.VMEM((S,), jnp.int32)],         # overflow v
    )
    out_table, bk, bv, counts = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles * block_voc,), jnp.int32),
            jax.ShapeDtypeStruct((P, cap), jnp.int32),
            jax.ShapeDtypeStruct((P, cap), jnp.int32),
            jax.ShapeDtypeStruct((P,), jnp.int32),
        ],
        compiler_params=kernels_compat_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(scalars, owner_map, owner_split, keys, vals,
      pending_k, pending_v, tbl)
    return out_table[:V], bk, bv, counts
