"""Jitted entry point for the fused 1S step kernel.

Shares the repo-wide interpret policy (kernels/backend.py): interpret on
CPU CI, compiled on a real TPU, overridable per call.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.backend import default_interpret
from repro.kernels.fused_map.kernel import fused_map_pallas


@partial(jax.jit,
         static_argnames=("n_procs", "cap", "block_voc", "interpret"))
def fused_map_step(keys, vals, rep, task_id, owner_map, owner_split,
                   pending_k, pending_v, table, *, n_procs: int, cap: int,
                   block_voc: int = 0, interpret: bool | None = None):
    """One fused engine step (see kernel.py). Returns
    ``(table, bk, bv, counts)`` bit-identical to ref.fused_step_ref."""
    return fused_map_pallas(keys, vals, rep, task_id, owner_map,
                            owner_split, pending_k, pending_v, table,
                            n_procs=n_procs, cap=cap, block_voc=block_voc,
                            interpret=default_interpret(interpret))
