from repro.kernels.fused_map.ops import fused_map_step

__all__ = ["fused_map_step"]
