"""Pure-jnp oracle for the fused 1S step kernel.

This is literally the unfused hot path of :func:`repro.core.onesided._step`
between ``map_fn`` and the all_to_all push, re-packaged as one function:
local reduce (with the footnote-5 repeat loop) -> owner lookup against the
carried partition maps -> bucketize into per-owner push buckets -> fold the
previous step's in-flight chunk plus this step's overflow (ownership
transfer) into the dense window. The kernel must match it **bit-exactly**
on every output — all arithmetic is int32, so summation order is free
(associative mod 2^32) and the contract is testable with
``assert_array_equal`` rather than tolerances.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.kv import bucketize, local_reduce_repeated
from repro.core.partition import lookup_owner
from repro.core.windows import DenseWindow


def fused_step_ref(keys, vals, rep, task_id, owner_map, owner_split,
                   pending_k, pending_v, table, *, n_procs: int, cap: int):
    """Reference for one fused engine step.

    Args mirror the engine carry slices: ``keys``/``vals`` are the task's
    mapped records (S,), ``rep`` the compute-repeat scalar, ``task_id``
    the global task id scalar, ``owner_map``/``owner_split`` the carried
    (vocab,) partition maps, ``pending_k``/``pending_v`` the previous
    step's in-flight (P, cap) chunk, ``table`` the (vocab,) dense window.

    Returns ``(table, bk, bv, counts)``: the folded window, the (P, cap)
    push buckets, and the per-owner fill counts.
    """
    uk, uv = local_reduce_repeated(keys, vals, keys.shape[0], rep)
    owners = lookup_owner(owner_map, owner_split, uk, task_id, n_procs)
    bk, bv, counts, (ofk, ofv) = bucketize(uk, uv, n_procs, cap,
                                           owners=owners)
    win = DenseWindow(table).put(pending_k.reshape(-1),
                                 pending_v.reshape(-1))
    win = win.put(ofk, ofv)
    return win.table, bk, bv, counts


def records_dense(keys, vals, vocab: int):
    """Dense (vocab,) total of a record array — conservation-check helper
    for the kernel tests (every input record must land in exactly one of:
    the window delta, a push bucket, or the overflow fold)."""
    from repro.core.kv import KEY_SENTINEL
    keys = keys.reshape(-1)
    vals = vals.reshape(-1)
    valid = (keys != KEY_SENTINEL) & (keys >= 0) & (keys < vocab)
    idx = jnp.where(valid, keys, 0)
    return jnp.zeros((vocab,), jnp.int32).at[idx].add(
        jnp.where(valid, vals, 0))
