"""jit wrapper matching the models/ssm ssd_ref signature."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.backend import default_interpret
from repro.kernels.ssd_scan.kernel import ssd_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk: int = 256, init_state=None,
        interpret: bool | None = None):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); B/C: (B, S, G, N).
    Returns (y (B, S, H, P), final_state (B, H, P, N)).

    ``init_state`` is folded in by running the kernel from zero and adding
    the closed-form init contribution (exactness preserved; the serving
    path never threads init_state through prefill)."""
    Bb, S, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    # (B, S, H, *) -> (B*H, S, *)
    xf = x.transpose(0, 2, 1, 3).reshape(Bb * H, S, Pd)
    dtf = dt.transpose(0, 2, 1).reshape(Bb * H, S, 1)
    Bh = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        Bb * H, S, N)
    Ch = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3).reshape(
        Bb * H, S, N)
    Af = jnp.broadcast_to(A[None, :], (Bb, H)).reshape(Bb * H, 1)
    y, st = ssd_pallas(xf, dtf, Af, Bh, Ch, chunk=chunk,
                       interpret=default_interpret(interpret))
    y = y.reshape(Bb, H, S, Pd).transpose(0, 2, 1, 3)
    st = st.reshape(Bb, H, Pd, N)
    if init_state is not None:
        # y_init[t] = C_t · (init * exp(cum_t)); state += init * exp(cum_S)
        dA = dt.astype(jnp.float32) * A[None, None, :]
        cum = jnp.cumsum(dA, axis=1)                      # (B, S, H)
        Chh = jnp.repeat(C, rep, axis=2)                  # (B, S, H, N)
        y_init = jnp.einsum("bshn,bhpn,bsh->bshp", Chh,
                            init_state.astype(jnp.float32), jnp.exp(cum),
                            preferred_element_type=jnp.float32)
        y = y + y_init.astype(y.dtype)
        st = st + init_state.astype(jnp.float32) \
            * jnp.exp(cum[:, -1])[:, :, None, None]       # (B,H,1,1)
    return y, st
