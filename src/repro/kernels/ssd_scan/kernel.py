"""Chunked SSD (Mamba-2 state-space duality) scan.

The SSD decomposition is itself the paper's decoupled pattern: the
quadratic *intra-chunk* term is independent per chunk (parallel producer),
while the (P × N) *inter-chunk* state pass is a tiny sequential consumer —
on TPU the state carry lives in VMEM scratch across a sequential grid axis,
so the MXU-heavy intra-chunk GEMMs of chunk c+1 overlap the state fold of
chunk c in the pipelined grid (the same overlap MR-1S gets from its
chunked push).

Per grid step the working set is one chunk: x (c × P), B/C (c × N), the
(c × c) decay matrix and the (P × N) state — c = 256, P = 64, N = 128 is
~0.6 MB fp32, VMEM-friendly; all contraction dims are 64/128/256 so the
MXU stays dense.

Grid: (B*H, n_chunks) — chunks sequential (state dependency).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params as kernels_compat_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state, *,
                chunk: int):
    ic = pl.program_id(1)
    n_c = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0].astype(jnp.float32)               # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (c,)
    A = a_ref[0, 0]                                # scalar (negative)
    Bm = b_ref[0].astype(jnp.float32)              # (c, N)
    Cm = c_ref[0].astype(jnp.float32)              # (c, N)

    dA = dt * A                                    # (c,)
    cum = jnp.cumsum(dA)                           # (c,)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    s = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c, c)
    s = s * L
    xdt = x * dt[:, None]                          # (c, P)
    y = jax.lax.dot_general(s, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c, P)

    # carried-in state contribution: y_inter = (C @ state^T) * exp(cum)
    y += jax.lax.dot_general(Cm, state[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: state' = state * exp(cum_last) + (xdt * d2e)^T @ B
    decay_to_end = jnp.exp(cum[-1] - cum)          # (c,)
    upd = jax.lax.dot_general(
        xdt * decay_to_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (P, N)
    state[...] = state[...] * jnp.exp(cum[-1]) + upd

    @pl.when(ic == n_c - 1)
    def _fin():
        st_ref[0] = state[...]


def ssd_pallas(x, dt, A, B, C, *, chunk: int = 256, interpret: bool = True):
    """x: (BH, S, P); dt: (BH, S, 1); A: (BH, 1); B/C: (BH, S, N).
    Returns (y (BH, S, P), state (BH, P, N) fp32)."""
    BH, S, Pd = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    n_c = -(-S // chunk)
    pad = n_c * chunk - S
    if pad:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        x, dt, B, C = padf(x), padf(dt), padf(B), padf(C)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((BH, n_c * chunk, Pd), x.dtype),
                   jax.ShapeDtypeStruct((BH, Pd, N), jnp.float32)),
        grid=(BH, n_c),
        in_specs=[
            pl.BlockSpec((1, chunk, Pd), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, 1), lambda b, ic: (b, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ic: (b, ic, 0)),
        ],
        out_specs=(pl.BlockSpec((1, chunk, Pd), lambda b, ic: (b, ic, 0)),
                   pl.BlockSpec((1, Pd, N), lambda b, ic: (b, 0, 0))),
        scratch_shapes=[pltpu.VMEM((Pd, N), jnp.float32)],
        compiler_params=kernels_compat_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C)
    return y[:, :S], st
