"""Oracle: the chunked SSD reference in models/ssm (validated against the
sequential token-by-token recurrence)."""
from repro.models.ssm import ssd_ref
