"""Bucket-slot kernel — the paper's Displacement window on TPU.

Given per-record expert/owner ids, every record needs its *slot within its
bucket* (where the one-sided put lands) and each bucket its fill count.
That is a segmented prefix-sum: slot[t] = #{t' < t : id[t'] == id[t]}.

TPU formulation: one-hot the ids against the expert lane (E lanes), cumsum
over the token (sublane) axis inside the block, and carry per-expert
running totals across blocks in VMEM scratch — sequential grid over token
blocks, zero data-dependent addressing. Output slots feed the dispatch
gather; counts are the displacement table peers read.

Grid: (token_blocks,), arbitrary (carry dependency).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params as kernels_compat_params


def _slots_kernel(eid_ref, slot_ref, cnt_ref, carry, *, n_experts: int):
    j = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        carry[...] = jnp.zeros_like(carry)

    eid = eid_ref[0, :]                                   # (B,)
    Bt = eid.shape[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (Bt, n_experts), 1)
    valid = (eid >= 0) & (eid < n_experts)
    oh = ((eid[:, None] == lanes) & valid[:, None]).astype(jnp.int32)
    prefix = jnp.cumsum(oh, axis=0)                       # inclusive
    slot_mat = carry[0, :][None, :] + prefix - 1          # (B, E)
    picked = jnp.sum(jnp.where(oh == 1, slot_mat, 0), axis=1)
    slot_ref[0, :] = jnp.where(valid, picked, -1)
    carry[0, :] = carry[0, :] + prefix[-1, :]

    @pl.when(j == nb - 1)
    def _fin():
        cnt_ref[0, :] = carry[0, :]


def bucket_slots_pallas(eids: jnp.ndarray, n_experts: int, *,
                        block_tok: int = 1024, interpret: bool = True):
    """eids: (T,) int32 (negative / >=E -> invalid). Returns
    (slots (T,) int32 [-1 for invalid], counts (E,) int32)."""
    T = eids.shape[0]
    block_tok = min(block_tok, max(T, 1))
    nb = -(-T // block_tok)
    pad = nb * block_tok - T
    e = jnp.pad(eids, (0, pad), constant_values=-1).reshape(nb, block_tok)

    kernel = functools.partial(_slots_kernel, n_experts=n_experts)
    slots, counts = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((nb, block_tok), jnp.int32),
                   jax.ShapeDtypeStruct((1, n_experts), jnp.int32)),
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, block_tok), lambda j: (j, 0))],
        out_specs=(pl.BlockSpec((1, block_tok), lambda j: (j, 0)),
                   pl.BlockSpec((1, n_experts), lambda j: (0, 0))),
        scratch_shapes=[pltpu.VMEM((1, n_experts), jnp.int32)],
        compiler_params=kernels_compat_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(e)
    return slots.reshape(-1)[:T], counts[0]
