"""Pure-jnp oracle for bucket_slots."""
from __future__ import annotations

import jax.numpy as jnp


def bucket_slots_ref(eids: jnp.ndarray, n_experts: int):
    T = eids.shape[0]
    valid = (eids >= 0) & (eids < n_experts)
    oh = ((eids[:, None] == jnp.arange(n_experts)[None, :])
          & valid[:, None]).astype(jnp.int32)
    prefix = jnp.cumsum(oh, axis=0) - 1
    picked = jnp.take_along_axis(
        prefix, jnp.clip(eids, 0, n_experts - 1)[:, None], axis=1)[:, 0]
    slots = jnp.where(valid, picked, -1)
    counts = jnp.sum(oh, axis=0)
    return slots, counts
