from __future__ import annotations

from functools import partial

import jax

from repro.kernels.backend import default_interpret
from repro.kernels.moe_dispatch.kernel import bucket_slots_pallas


@partial(jax.jit, static_argnames=("n_experts", "interpret"))
def bucket_slots(eids, n_experts: int, interpret: bool | None = None):
    return bucket_slots_pallas(eids, n_experts,
                               interpret=default_interpret(interpret))
