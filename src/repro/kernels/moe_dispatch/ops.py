from __future__ import annotations

from functools import partial

import jax

from repro.kernels.moe_dispatch.kernel import bucket_slots_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("n_experts", "interpret"))
def bucket_slots(eids, n_experts: int, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return bucket_slots_pallas(eids, n_experts, interpret=interpret)
