"""jit'd wrappers. ``interpret`` defaults to True off-TPU so the same call
sites run everywhere; on TPU hardware pass interpret=False for the compiled
kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.backend import default_interpret
from repro.kernels.wordcount_hash.kernel import hist_pallas
from repro.kernels.wordcount_hash.ref import hist_ref


@partial(jax.jit, static_argnames=("vocab", "hash_mod", "interpret"))
def wordcount_hist(tokens, vocab: int, hash_mod: int = 0,
                   interpret: bool | None = None):
    return hist_pallas(tokens, vocab, hash_mod=hash_mod,
                       interpret=default_interpret(interpret))


@partial(jax.jit, static_argnames=("vocab", "hash_mod"))
def wordcount_hist_ref(tokens, vocab: int, hash_mod: int = 0):
    return hist_ref(tokens, vocab, hash_mod=hash_mod)
