"""Pure-jnp oracle for the wordcount histogram kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.kv import mix32

SENTINEL = jnp.iinfo(jnp.int32).max


def hist_ref(tokens: jnp.ndarray, vocab: int, *, hash_mod: int = 0
             ) -> jnp.ndarray:
    valid = tokens != SENTINEL
    if hash_mod > 0:
        keys = (mix32(tokens) % jnp.uint32(hash_mod)).astype(jnp.int32)
    else:
        keys = tokens
    keys = jnp.where(valid, keys, vocab)      # ghost slot
    return jnp.zeros((vocab + 1,), jnp.int32).at[keys].add(1)[:vocab]
