"""Histogram kernel — the Map + Local-Reduce inner loop on TPU.

The paper's Map phase hashes every word and scatters a <key,1> record into
the owner's bucket. Scatters are hostile to the TPU vector unit, so the
TPU-native formulation is a *tiled compare-reduce histogram*: for a tile of
``block_voc`` key slots and a block of ``block_tok`` tokens, the count is a
(tokens × slots) equality matrix reduced over tokens — pure VPU work with
perfect lane utilization, no data-dependent addressing. (This is the
hardware adaptation DESIGN.md §2 records: hash-scatter → compare-reduce.)

Grid: (vocab_tiles, token_blocks); vocab tiles are parallel, token blocks
sequential (accumulate into the same output tile).

An optional Murmur3-style ownership hash (``hash_mod > 0``) runs *inside*
the kernel so the owner histogram (the paper's Displacement-window math)
costs no extra memory pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params as kernels_compat_params

SENTINEL = jnp.iinfo(jnp.int32).max


def _mix32(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _hist_kernel(tok_ref, out_ref, *, block_voc: int, hash_mod: int):
    i = pl.program_id(0)          # vocab tile
    j = pl.program_id(1)          # token block (sequential)
    toks = tok_ref[0, :]          # (block_tok,)
    valid = toks != SENTINEL
    if hash_mod > 0:
        keys = (_mix32(toks) % jnp.uint32(hash_mod)).astype(jnp.int32)
    else:
        keys = toks
    base = i * block_voc
    ids = base + jax.lax.broadcasted_iota(
        jnp.int32, (toks.shape[0], block_voc), 1)
    hits = (keys[:, None] == ids) & valid[:, None]
    partial = jnp.sum(hits.astype(jnp.int32), axis=0)    # (block_voc,)

    @pl.when(j == 0)
    def _init():
        out_ref[0, :] = partial

    @pl.when(j != 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + partial


def hist_pallas(tokens: jnp.ndarray, vocab: int, *, hash_mod: int = 0,
                block_tok: int = 1024, block_voc: int = 512,
                interpret: bool = True) -> jnp.ndarray:
    """tokens: (N,) int32 (SENTINEL = skip). Returns (vocab,) int32 counts
    of ``token`` (hash_mod=0) or ``mix32(token) % hash_mod`` (owner mode —
    then ``vocab`` must be >= hash_mod)."""
    N = tokens.shape[0]
    block_tok = min(block_tok, max(N, 1))
    n_blocks = -(-N // block_tok)
    pad = n_blocks * block_tok - N
    toks = jnp.pad(tokens, (0, pad), constant_values=SENTINEL)
    toks = toks.reshape(n_blocks, block_tok)

    block_voc = min(block_voc, vocab)
    n_tiles = -(-vocab // block_voc)
    vpad = n_tiles * block_voc

    kernel = functools.partial(_hist_kernel, block_voc=block_voc,
                               hash_mod=hash_mod)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_tiles, block_voc), jnp.int32),
        grid=(n_tiles, n_blocks),
        in_specs=[pl.BlockSpec((1, block_tok), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((1, block_voc), lambda i, j: (i, 0)),
        compiler_params=kernels_compat_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(toks)
    return out.reshape(vpad)[:vocab]
