# Pallas TPU kernels for the compute hot-spots (validated on CPU with
# interpret=True; BlockSpecs tile for VMEM / MXU on the v5e target):
#   wordcount_hash  — Map+LocalReduce histogram (the paper's hot loop)
#   moe_dispatch    — bucket-slot prefix counts (the displacement window)
#   flash_attention — blocked online-softmax prefill attention
#   flash_decode    — 1-token query vs long KV cache (decode roofline)
#   ssd_scan        — Mamba-2 chunked state-space-dual scan

from jax.experimental.pallas import tpu as _pltpu


def compiler_params(**kw):
    """Version-compat constructor: ``pltpu.CompilerParams`` (new jax) was
    named ``TPUCompilerParams`` on jax 0.4.x."""
    cls = getattr(_pltpu, "CompilerParams", None) or _pltpu.TPUCompilerParams
    return cls(**kw)
