"""The replication taint lattice and its abstract interpreter.

Two-point lattice over SPMD values, per mesh axis set:

    REPLICATED  ⊑  VARYING

A value is REPLICATED when every rank holds the same bits (the paper's
windows-synchronized state: claim cursors, owner maps, overflow totals);
VARYING otherwise. The interpreter walks a jaxpr with standard abstract
interpretation: join = max, monotone transfer functions per primitive,
fixpoints for ``scan``/``while`` carries, and a *control taint* that
tracks whether execution itself is rank-divergent (a ``cond`` predicate
or ``while`` trip count derived from ``axis_index``).

Three findings originate here:

  * SPMD001 — a collective names a mesh axis outside the program's
    allowed set (the engine contract is ``("procs",)``);
  * SPMD002 — a collective is reachable under rank-divergent control
    flow (the SPMD deadlock analog of an unmatched one-sided epoch);
  * REP001  — an output the backend asserts replicated is derived from
    rank-varying data without an intervening collective (e.g. a dropped
    ``psum`` on a progress row).

Soundness notes: unknown primitives conservatively join their inputs and
any hidden sub-jaxpr is still scanned for collectives; ``psum`` (and
friends) only launder taint when reducing over an *allowed named* axis —
positional-axes psum (from vmap) is a plain local op.
"""
from __future__ import annotations

import dataclasses

from jax import core as jcore

from repro.analysis.tracer import subjaxprs, where_of

REPLICATED = 0
VARYING = 1

# full-axis reductions: every rank receives the identical result
REPLICATING = frozenset({"psum", "pmax", "pmin", "all_gather"})
# rank-dependent data movement: ranks receive different slices
SHUFFLING = frozenset({"all_to_all", "ppermute", "pgather", "pscatter"})
COLLECTIVES = REPLICATING | SHUFFLING

# higher-order primitives whose single sub-jaxpr maps invars/outvars 1:1
# onto the equation's own — taint passes straight through
_TRANSPARENT = frozenset({
    "pjit", "shard_map", "closed_call", "core_call", "remat",
    "checkpoint", "custom_jvp_call", "custom_vjp_call",
    "custom_vjp_call_jaxpr",
})


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding, keyed by rule id + jaxpr provenance."""
    rule: str
    program: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.program} @ {self.where}: {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def named_axes(eqn) -> tuple:
    """The *named* mesh axes a collective operates over (ints from vmap
    positional reductions are dropped)."""
    axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    return tuple(a for a in axes if isinstance(a, str))


class TaintAnalyzer:
    """Abstract interpreter over one program's jaxpr."""

    def __init__(self, program: str, allowed_axes):
        self.program = program
        self.allowed = frozenset(allowed_axes)
        self.findings: list[Finding] = []
        self._seen: set = set()

    # -- findings ----------------------------------------------------------

    def _emit(self, rule: str, where: str, message: str) -> None:
        key = (rule, where, message)
        if key not in self._seen:      # fixpoint passes revisit equations
            self._seen.add(key)
            self.findings.append(Finding(rule, self.program, where, message))

    # -- interpretation ----------------------------------------------------

    def run(self, closed: jcore.ClosedJaxpr, in_taints: list) -> list:
        """Propagate input taints through the whole program; returns the
        flat output taints (findings accumulate on ``self.findings``)."""
        return self._eval(closed, list(in_taints), REPLICATED)

    def _eval(self, jaxpr, in_taints: list, control: int) -> list:
        if isinstance(jaxpr, jcore.ClosedJaxpr):
            # closed-over consts are host constants: replicated
            jaxpr = jaxpr.jaxpr
        env: dict = {}

        def read(atom) -> int:
            if isinstance(atom, jcore.Literal):
                return REPLICATED
            return env.get(atom, REPLICATED)

        for v in jaxpr.constvars:
            env[v] = REPLICATED
        for v, t in zip(jaxpr.invars, in_taints):
            env[v] = t
        for eqn in jaxpr.eqns:
            ts = [read(x) for x in eqn.invars]
            outs = self._transfer(eqn, ts, control)
            for v, t in zip(eqn.outvars, outs):
                env[v] = t
        return [read(x) for x in jaxpr.outvars]

    def _transfer(self, eqn, ts: list, control: int) -> list:
        name = eqn.primitive.name
        n_out = len(eqn.outvars)
        join_in = max(ts, default=REPLICATED)

        if name in COLLECTIVES:
            axes = named_axes(eqn)
            bad = sorted(a for a in axes if a not in self.allowed)
            if bad:
                self._emit(
                    "SPMD001", where_of(eqn),
                    f"collective '{name}' over mesh axis {bad} outside "
                    f"the allowed set {sorted(self.allowed)}")
            if control == VARYING:
                self._emit(
                    "SPMD002", where_of(eqn),
                    f"collective '{name}' reachable under rank-divergent "
                    "control flow (predicate tainted by axis_index) — "
                    "ranks would disagree on whether to enter it")
            if not axes:               # positional-only (vmapped) reduce
                return [join_in] * n_out
            if name in REPLICATING:
                return [REPLICATED] * n_out
            return [VARYING] * n_out

        if name == "axis_index":
            return [VARYING] * n_out

        if name == "cond":             # also `switch` (multi-branch cond)
            pred, args = ts[0], ts[1:]
            child = max(control, pred)
            outs = [REPLICATED] * n_out
            for branch in eqn.params["branches"]:
                bouts = self._eval(branch, list(args), child)
                outs = [max(a, b) for a, b in zip(outs, bouts)]
            # rank-divergent predicate -> outputs are control-dependent
            return [max(o, pred) for o in outs]

        if name == "while":
            p = eqn.params
            cn, bn = p["cond_nconsts"], p["body_nconsts"]
            cond_c, body_c = ts[:cn], ts[cn:cn + bn]
            carry = list(ts[cn + bn:])
            pred = REPLICATED
            for _ in range(len(carry) + 2):    # monotone: must stabilize
                pred = max(pred, self._eval(
                    p["cond_jaxpr"], cond_c + carry,
                    max(control, pred))[0])
                child = max(control, pred)
                new = self._eval(p["body_jaxpr"], body_c + carry, child)
                # rank-divergent trip count -> carries diverge too
                merged = [max(a, b, pred) for a, b in zip(carry, new)]
                if merged == carry:
                    break
                carry = merged
            return carry

        if name == "scan":             # static trip count: no divergence
            p = eqn.params
            nc, nk = p["num_consts"], p["num_carry"]
            consts, xs = ts[:nc], ts[nc + nk:]
            carry = list(ts[nc:nc + nk])
            ys = [REPLICATED] * (n_out - nk)
            for _ in range(len(carry) + 2):
                outs = self._eval(p["jaxpr"], consts + carry + xs, control)
                ys = [max(a, b) for a, b in zip(ys, outs[nk:])]
                merged = [max(a, b) for a, b in zip(carry, outs[:nk])]
                if merged == carry:
                    break
                carry = merged
            return carry + ys

        if name in _TRANSPARENT:
            sub = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
            if sub is not None:
                inner = sub.jaxpr if isinstance(sub, jcore.ClosedJaxpr) \
                    else sub
                if len(inner.invars) == len(ts):
                    outs = self._eval(sub, ts, control)
                    if len(outs) == n_out:
                        return outs

        # unknown primitive: conservatively join inputs; still sweep any
        # hidden sub-jaxpr (e.g. a pallas kernel body) so a collective
        # buried inside cannot escape SPMD001/SPMD002
        for sub in subjaxprs(eqn.params):
            self._eval(sub, [join_in] * len(sub.invars), control)
        return [join_in] * n_out


def analyze_handle(handle, closed: jcore.ClosedJaxpr) -> list:
    """Run the taint interpreter over a traced ProgramHandle and check
    its replication contract. Returns all findings (SPMD001/2 + REP001).
    """
    analyzer = TaintAnalyzer(handle.name, handle.allowed_axes)
    replicated_in = frozenset(handle.replicated_in)
    in_taints = [REPLICATED if p in replicated_in else VARYING
                 for p in handle.arg_paths]
    out_taints = analyzer.run(closed, in_taints)
    replicated_out = frozenset(handle.replicated_out)
    for path, taint in zip(handle.out_paths, out_taints):
        if path in replicated_out and taint == VARYING:
            analyzer._emit(
                "REP001", path,
                f"output '{path}' is asserted replicated but derives "
                "from rank-varying data with no intervening collective "
                "(e.g. a dropped psum)")
    return analyzer.findings
