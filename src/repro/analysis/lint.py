"""fleetlint CLI — ``python -m repro.analysis.lint``.

Modes:

  --all        check the shipping programs AND kernels (the default)
  --programs   only the backend x use-case matrix
  --kernels    only the pallas kernels
  --selftest   run the seeded mutant corpus instead: every rule must
               fire on its known-bad seed and stay quiet on the
               near-miss (exit 1 otherwise)

Output options: ``--json`` (machine-readable findings), ``--verbose``
(per-program progress), ``--waive RULE:SUBSTR`` (repeatable — silence a
finding by rule id + a substring of its provenance, e.g.
``--waive PAL002:moe_dispatch``; waived findings are still reported,
they just do not fail the run).

Exit status: 0 clean, 1 findings (or selftest failure).
"""
from __future__ import annotations

import argparse
import json
import sys


def _parse_waivers(raw: list[str]) -> list[tuple[str, str]]:
    waivers = []
    for w in raw:
        rule, _, substr = w.partition(":")
        if not rule or not substr:
            raise SystemExit(f"--waive needs RULE:SUBSTR, got {w!r}")
        waivers.append((rule, substr))
    return waivers


def _is_waived(finding, waivers) -> bool:
    return any(finding.rule == rule
               and (substr in finding.program or substr in finding.where)
               for rule, substr in waivers)


def run_programs(verbose: bool, out=sys.stderr) -> tuple[list, int]:
    from repro.analysis import corpus, rules
    findings, checked = [], 0
    for handle in corpus.shipping_programs():
        got = rules.check_program(handle)
        findings.extend(got)
        checked += 1
        if verbose:
            status = "ok" if not got else f"{len(got)} finding(s)"
            print(f"  program {handle.name}: {status}", file=out)
    return findings, checked


def run_kernels(verbose: bool, out=sys.stderr) -> tuple[list, int]:
    from repro.analysis import corpus, rules
    findings, checked = [], 0
    for kc in corpus.shipping_kernels():
        got = rules.check_kernel(kc)
        findings.extend(got)
        checked += 1
        if verbose:
            status = "ok" if not got else f"{len(got)} finding(s)"
            print(f"  kernel {kc.name}: {status}", file=out)
    return findings, checked


def run_selftest(verbose: bool, out=sys.stderr) -> bool:
    """Mutant corpus gate: each rule fires on its seed, never on the
    near-miss. Returns True when the analyzer passes its own test."""
    from repro.analysis import corpus
    ok = True
    for mutant in corpus.MUTANTS:
        got = corpus.run_mutant(mutant)
        fired = any(f.rule == mutant.rule for f in got)
        if mutant.fires:
            good = fired
            expect = f"must fire {mutant.rule}"
        else:
            good = not got          # near-miss: NO findings at all
            expect = "must stay quiet"
        ok &= good
        mark = "ok" if good else "FAIL"
        if verbose or not good:
            print(f"  mutant {mutant.name} ({expect}): {mark} "
                  f"[{len(got)} finding(s)]", file=out)
    return ok


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="fleetlint: static SPMD/pallas analysis over the "
                    "shipping program corpus")
    ap.add_argument("--all", action="store_true",
                    help="programs + kernels (default)")
    ap.add_argument("--programs", action="store_true")
    ap.add_argument("--kernels", action="store_true")
    ap.add_argument("--selftest", action="store_true",
                    help="run the known-bad mutant corpus instead")
    ap.add_argument("--json", action="store_true", dest="as_json")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--waive", action="append", default=[],
                    metavar="RULE:SUBSTR",
                    help="silence findings of RULE whose program or "
                         "provenance contains SUBSTR (repeatable)")
    args = ap.parse_args(argv)
    waivers = _parse_waivers(args.waive)

    if args.selftest:
        ok = run_selftest(args.verbose)
        print("fleetlint selftest:", "PASS" if ok else "FAIL")
        return 0 if ok else 1

    do_programs = args.programs or args.all or not args.kernels
    do_kernels = args.kernels or args.all or not args.programs
    findings, checked = [], {}
    if do_programs:
        got, n = run_programs(args.verbose)
        findings += got
        checked["programs"] = n
    if do_kernels:
        got, n = run_kernels(args.verbose)
        findings += got
        checked["kernels"] = n

    live = [f for f in findings if not _is_waived(f, waivers)]
    waived = [f for f in findings if _is_waived(f, waivers)]

    if args.as_json:
        print(json.dumps({
            "checked": checked,
            "findings": [f.to_json() for f in live],
            "waived": [f.to_json() for f in waived],
        }, indent=2))
    else:
        for f in waived:
            print(f"waived  {f}")
        for f in live:
            print(str(f))
        scope = ", ".join(f"{n} {k}" for k, n in checked.items())
        verdict = "clean" if not live else f"{len(live)} finding(s)"
        print(f"fleetlint: {scope} checked — {verdict}"
              + (f" ({len(waived)} waived)" if waived else ""))
    return 1 if live else 0


if __name__ == "__main__":
    sys.exit(main())
