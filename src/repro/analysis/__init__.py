"""fleetlint — jaxpr-level static analysis for the SPMD fleet.

Traces every registered backend x use-case program (and every pallas
kernel) to jaxprs and proves, without executing anything:

  * SPMD001/SPMD002 — collective uniformity: collectives name allowed
    mesh axes and are never reachable under rank-divergent control flow;
  * REP001          — replication invariants: values the engines assert
    replicated really are products of replicated inputs + collectives;
  * PAL001..PAL003  — pallas static checks: BlockSpec index maps in
    bounds, integer accumulators wide enough, one interpret-mode policy.

Entry points: ``python -m repro.analysis.lint`` (CLI),
``repro.analysis.rules.check_program`` / ``check_kernel`` (library),
``tests/test_analysis.py`` (pytest gate over the shipping matrix plus a
known-bad mutant corpus).
"""
from repro.analysis.taint import Finding  # noqa: F401
