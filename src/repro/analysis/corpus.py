"""The program corpus fleetlint runs over.

Two halves:

  * the *shipping* matrix — every registered backend x use-case program
    (the same admission-time set the multi-tenant scheduler asserts) and
    every pallas kernel in ``kernels/``, all of which must lint clean;
  * the *mutant* corpus — seeded known-bad programs/kernels, one firing
    example and one near-miss per rule, so the pytest gate proves each
    rule both fires and stays quiet (false-positive guard).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis.rules import KernelCheck
from repro.core.registry import JobSpec, ProgramHandle, available_backends, \
    get_backend
from repro.core.usecase import as_map_fn
from repro.core.usecases import Histogram, InvertedIndex, WordCount
from repro.distributed.collectives import shard_map

# -- shipping matrix --------------------------------------------------------

# one instance per use-case; window sizes stay small — trace time is
# shape-independent and the analyzer never executes anything
SHIPPING_CASES = (
    ("wordcount", WordCount(vocab=512)),
    ("histogram", Histogram(vocab=512, n_bins=64)),
    ("invindex", InvertedIndex(queries=(3, 5, 7), n_docs=4,
                               tasks_per_doc=2)),
)


def procs_mesh(n_procs: int | None = None) -> Mesh:
    """1-D ``("procs",)`` mesh over the visible devices (P=1 is fine —
    collectives trace identically at any size)."""
    devs = jax.devices()
    n = n_procs or len(devs)
    return Mesh(np.array(devs[:n]), ("procs",))


def shipping_programs(mesh: Mesh | None = None,
                      seg_tasks: int = 2) -> list[ProgramHandle]:
    """Every backend x use-case (x stealing variant) as ProgramHandles."""
    if mesh is None:
        mesh = procs_mesh()
    n_procs = int(mesh.devices.size)
    handles: list[ProgramHandle] = []
    for bname in available_backends():
        backend = get_backend(bname)
        for cname, usecase in SHIPPING_CASES:
            variants = [(False, False, "")]
            if getattr(backend, "supports_stealing", False):
                variants.append((True, False, "+steal"))
            if getattr(backend, "supports_fused_map", False):
                # the fused hot path is a different compiled program
                # (a pallas kernel inside the engine scan) — it must
                # pass the same SPMD/replication gate as the unfused one
                variants.append((False, True, "+fused"))
                variants.append((True, True, "+steal+fused"))
            for stealing, fused, suffix in variants:
                spec = JobSpec(vocab=usecase.window, task_size=8,
                               push_cap=16, n_procs=n_procs,
                               segment=seg_tasks, stealing=stealing,
                               fused_map=fused)
                handles.extend(backend.trace_handles(
                    spec, as_map_fn(usecase), mesh, seg_tasks=seg_tasks,
                    tag=f"{bname}/{cname}{suffix}"))
            if getattr(backend, "supports_coded", False) \
                    and n_procs % 2 == 0:
                # the coded exchange (JobSpec.code_rate=2): r-replicated
                # column blocks + the XOR multicast step — a distinct
                # compiled program that must hold the same replication
                # contract. Gated on an even mesh (code groups need
                # r | n_procs): the in-process P=1 run skips it, the
                # P=8 CI analysis job covers it.
                for stealing, suffix in ((False, "+coded"),
                                         (True, "+steal+coded")):
                    spec = JobSpec(vocab=usecase.window, task_size=8,
                                   push_cap=16, n_procs=n_procs,
                                   segment=seg_tasks, stealing=stealing,
                                   code_rate=2)
                    handles.extend(backend.trace_handles(
                        spec, as_map_fn(usecase), mesh,
                        seg_tasks=seg_tasks,
                        tag=f"{bname}/{cname}{suffix}"))
            if getattr(backend, "supports_coschedule", False):
                # the co-scheduled engine: a 2-member WorkDomain's
                # composite program — key-window offsetting plus the
                # psum-maintained ``carry.job_work`` row — ships
                # through the same SPMD/replication gate
                for stealing, suffix in ((False, "+cosched"),
                                         (True, "+steal+cosched")):
                    spec = JobSpec(vocab=usecase.window * 2,
                                   task_size=8, push_cap=16,
                                   n_procs=n_procs, segment=seg_tasks,
                                   stealing=stealing, coslots=2,
                                   costride=seg_tasks)
                    handles.extend(backend.trace_handles(
                        spec, as_map_fn(usecase), mesh,
                        seg_tasks=seg_tasks,
                        tag=f"{bname}/{cname}{suffix}"))
    # the elastic re-mesh fold ships through the same gate as the
    # engines: its replicated-out contract (folded owner map/split +
    # psum checksum) is exactly what REP001 exists to check
    from repro.fleet.remesh import remesh_program_handles
    handles.extend(remesh_program_handles(mesh))
    return handles


def shipping_kernels() -> list[KernelCheck]:
    """Every kernel in ``kernels/`` as a KernelCheck with representative
    shipped shapes and declared worst-case counts."""
    from repro.core.kv import KEY_SENTINEL
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.flash_decode import ops as fd
    from repro.kernels.fused_map import ops as fm
    from repro.kernels.moe_dispatch import ops as moe
    from repro.kernels.ssd_scan import ops as ssd
    from repro.kernels.wordcount_hash import ops as wc

    N, T = 4096, 1024
    S, V, Pn, C = 64, 512, 8, 16         # fused step: shipped engine scale
    f32, i32 = jnp.float32, jnp.int32
    return [
        KernelCheck(
            "fused_map",
            build=lambda: (fm.fused_map_step,
                           (jnp.zeros((S,), i32), jnp.zeros((S,), i32),
                            jnp.int32(1), jnp.int32(0),
                            jnp.zeros((V,), i32), jnp.ones((V,), i32),
                            jnp.full((Pn, C), KEY_SENTINEL, i32),
                            jnp.zeros((Pn, C), i32),
                            jnp.zeros((V,), i32)),
                           dict(n_procs=Pn, cap=C, block_voc=128,
                                interpret=True)),
            # int32 outputs hold per-key window totals; the engine's
            # record bound under the PR 6 saturating-combine contract
            # keeps every legitimate total well inside 2^30
            worst_count=2 ** 30,
            ops_module="repro.kernels.fused_map.ops",
            kernel_fn="repro.kernels.fused_map.kernel:fused_map_pallas"),
        KernelCheck(
            "wordcount_hash",
            build=lambda: (wc.wordcount_hist, (jnp.zeros((N,), i32),),
                           dict(vocab=512, hash_mod=8, interpret=True)),
            worst_count=N,
            ops_module="repro.kernels.wordcount_hash.ops",
            kernel_fn="repro.kernels.wordcount_hash.kernel:hist_pallas"),
        KernelCheck(
            "moe_dispatch",
            build=lambda: (moe.bucket_slots, (jnp.zeros((T,), i32),),
                           dict(n_experts=8, interpret=True)),
            worst_count=T,
            ops_module="repro.kernels.moe_dispatch.ops",
            kernel_fn="repro.kernels.moe_dispatch.kernel:"
                      "bucket_slots_pallas"),
        KernelCheck(
            "flash_attention",
            build=lambda: (fa.flash_attention,
                           (jnp.zeros((1, 128, 4, 64), f32),
                            jnp.zeros((1, 128, 2, 64), f32),
                            jnp.zeros((1, 128, 2, 64), f32)),
                           dict(causal=True, block_q=64, block_kv=64,
                                interpret=True)),
            ops_module="repro.kernels.flash_attention.ops",
            kernel_fn="repro.kernels.flash_attention.kernel:"
                      "flash_attention_pallas"),
        KernelCheck(
            "flash_decode",
            build=lambda: (fd.flash_decode,
                           (jnp.zeros((2, 4, 32), f32),
                            jnp.zeros((2, 256, 2, 32), f32),
                            jnp.zeros((2, 256, 2, 32), f32),
                            jnp.int32(100)),
                           dict(block_kv=128, interpret=True)),
            ops_module="repro.kernels.flash_decode.ops",
            kernel_fn="repro.kernels.flash_decode.kernel:"
                      "flash_decode_pallas"),
        KernelCheck(
            "ssd_scan",
            build=lambda: (ssd.ssd,
                           (jnp.zeros((1, 128, 2, 4), f32),
                            jnp.zeros((1, 128, 2), f32),
                            jnp.zeros((2,), f32),
                            jnp.zeros((1, 128, 1, 8), f32),
                            jnp.zeros((1, 128, 1, 8), f32)),
                           dict(chunk=64, interpret=True)),
            ops_module="repro.kernels.ssd_scan.ops",
            kernel_fn="repro.kernels.ssd_scan.kernel:ssd_pallas"),
    ]


# -- mutant corpus ----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Mutant:
    """One seeded corpus entry. ``kind`` selects the checker:
    ``program`` -> check_program, ``kernel`` -> check_kernel,
    ``ops`` -> check_ops_module. ``fires`` is the expectation: True for
    the known-bad seed, False for its near-miss twin."""
    name: str
    rule: str
    fires: bool
    kind: str
    build: Callable = dataclasses.field(compare=False)


def _sm_handle(name, body, mesh, n_in: int = 1, replicated_in=(),
               replicated_out=(), width: int = 8) -> ProgramHandle:
    """Wrap a per-shard body into a traced-shape ProgramHandle: inputs
    are (P, width) int32 rows (rank-varying unless named in
    ``replicated_in``), output is one (1,)-shaped value per shard."""
    args = tuple(jax.ShapeDtypeStruct((int(mesh.devices.size), width),
                                      jnp.int32) for _ in range(n_in))
    specs = tuple(P("procs") for _ in range(n_in))
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=specs,
                           out_specs=P("procs")))
    return ProgramHandle(
        name=name, fn=fn, args=args,
        arg_paths=tuple(f"x{i}" for i in range(n_in)),
        out_paths=("total",), replicated_in=replicated_in,
        replicated_out=replicated_out, allowed_axes=("procs",))


def _two_axis_mesh() -> Mesh:
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("procs", "rows"))


def _spmd001(fires: bool) -> ProgramHandle:
    # psum over "rows" — a real mesh axis, but outside the engine
    # contract's allowed set ("procs",)
    axis = "rows" if fires else "procs"

    def body(x):
        return lax.psum(x.sum(), axis)[None]

    return _sm_handle(f"mutant/spmd001/{axis}", body, _two_axis_mesh())


def _spmd002(fires: bool) -> ProgramHandle:
    mesh = procs_mesh(1)

    def bad(x):
        # predicate derived from axis_index: ranks disagree on whether
        # the psum inside the branch executes -> divergence/deadlock
        pred = lax.axis_index("procs") % 2 == 0
        return lax.cond(pred,
                        lambda v: lax.psum(v, "procs"),
                        lambda v: v, x.sum())[None]

    def near(x):
        # same shape of program, but the predicate is itself a psum
        # product — replicated, so every rank takes the same branch
        pred = lax.psum(x.sum(), "procs") > 0
        return lax.cond(pred,
                        lambda v: lax.psum(v, "procs"),
                        lambda v: v, x.sum())[None]

    return _sm_handle(f"mutant/spmd002/{'bad' if fires else 'near'}",
                      bad if fires else near, mesh)


def _rep001(fires: bool) -> ProgramHandle:
    mesh = procs_mesh(1)

    def bad(x):
        # dropped psum: a per-rank partial sum flows into an output the
        # handle asserts replicated
        return x.sum()[None]

    def near(x):
        return lax.psum(x.sum(), "procs")[None]

    return _sm_handle(f"mutant/rep001/{'bad' if fires else 'near'}",
                      bad if fires else near, mesh,
                      replicated_out=("total",))


def _rep001_fold(fires: bool) -> ProgramHandle:
    # the elastic-fold failure mode: each rank's folded-window total
    # must be dup-summed to become the fleet total. The bad twin
    # "broadcasts" it around the ring instead — ppermute is a shuffle,
    # not a replication (every rank ends holding a *different* value),
    # which the taint rules treat as rank-varying unconditionally.
    mesh = procs_mesh(1)
    n = int(mesh.devices.size)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def bad(x):
        return lax.ppermute(x.sum()[None], "procs", perm)

    def near(x):
        return lax.psum(x.sum(), "procs")[None]

    return _sm_handle(f"mutant/rep001-fold/{'bad' if fires else 'near'}",
                      bad if fires else near, mesh,
                      replicated_out=("total",))


def _rep001_crossjob(fires: bool) -> ProgramHandle:
    # the cross-job cursor failure mode: ``carry.job_work`` (executed
    # work per member slot) is asserted replicated — each rank
    # scatter-adds the repeats it executed into a local slot row, and
    # only a psum turns those partials into the fleet row. The bad twin
    # feeds the row around the ring instead: ppermute is a shuffle, not
    # a replication (every rank ends holding a *different* partial), so
    # the taint rules keep it rank-varying and REP001 fires.
    mesh = procs_mesh(1)
    n = int(mesh.devices.size)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def _slot_row(x):
        slot = x[0, 0] % 2          # member slot of the claimed task
        return jnp.zeros((1, 2), jnp.int32).at[0, slot].add(x.sum())

    def bad(x):
        return lax.ppermute(_slot_row(x), "procs", perm)[0, :1]

    def near(x):
        return lax.psum(_slot_row(x), "procs")[0, :1]

    return _sm_handle(
        f"mutant/rep001-crossjob/{'bad' if fires else 'near'}",
        bad if fires else near, mesh, replicated_out=("total",))


def _rep001_coded(fires: bool) -> ProgramHandle:
    # the coded-exchange failure mode: the decoded-bucket total each
    # rank recovers from the XOR multicast is per-rank partial state —
    # only a psum turns it into the asserted-replicated fleet total.
    # The bad twin feeds the decode accumulator around the ring instead:
    # ppermute is a shuffle, not a replication (every rank ends holding
    # a *different* decoded partial), so REP001 fires.
    mesh = procs_mesh(1)
    n = int(mesh.devices.size)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def _decoded_total(x):
        # XOR a received coded row against locally-mapped side info
        dec = jnp.bitwise_xor(x[0], x[-1])
        return dec.sum()

    def bad(x):
        return lax.ppermute(_decoded_total(x)[None], "procs", perm)

    def near(x):
        return lax.psum(_decoded_total(x), "procs")[None]

    return _sm_handle(
        f"mutant/rep001-coded/{'bad' if fires else 'near'}",
        bad if fires else near, mesh, replicated_out=("total",))


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _pal001(fires: bool) -> KernelCheck:
    index_map = (lambda i: (i + 1, 0)) if fires else (lambda i: (i, 0))

    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            grid=(8,),
            in_specs=[pl.BlockSpec((1, 128), index_map)],
            out_specs=pl.BlockSpec((1, 128), lambda i: (i, 0)),
            interpret=True)(x)

    return KernelCheck(
        f"mutant/pal001/{'bad' if fires else 'near'}",
        build=lambda: (fn, (jnp.zeros((8, 128), jnp.float32),), {}),
        worst_count=None)


def _pal001_fused(fires: bool) -> KernelCheck:
    # the fused_map failure mode: a sequential grid streams (vocab,)
    # table tiles while record-domain operands ride along as full
    # blocks; the bad twin's tile index map is off by one, so the last
    # grid step reads a tile past the padded table
    tile_map = (lambda j: (j + 1,)) if fires else (lambda j: (j,))

    def kernel(t_ref, r_ref, o_ref):
        o_ref[...] = t_ref[...] + r_ref[0]

    def fn(table, recs):
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((512,), jnp.int32),
            grid=(8,),
            in_specs=[pl.BlockSpec((64,), tile_map),
                      pl.BlockSpec((16,), lambda j: (0,))],
            out_specs=pl.BlockSpec((64,), lambda j: (j,)),
            interpret=True)(table, recs)

    return KernelCheck(
        f"mutant/pal001-fused/{'bad' if fires else 'near'}",
        build=lambda: (fn, (jnp.zeros((512,), jnp.int32),
                            jnp.zeros((16,), jnp.int32)), {}),
        worst_count=10 ** 6)


def _pal002(fires: bool) -> KernelCheck:
    def fn(x):
        return pl.pallas_call(
            _copy_kernel,
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.int32),
            grid=(8,),
            in_specs=[pl.BlockSpec((1, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1, 128), lambda i: (i, 0)),
            interpret=True)(x)

    # 2^40 synthetic records cannot fit an int32 accumulator; 10^6 can
    worst = 2 ** 40 if fires else 10 ** 6
    return KernelCheck(
        f"mutant/pal002/{'bad' if fires else 'near'}",
        build=lambda: (fn, (jnp.zeros((8, 128), jnp.int32),), {}),
        worst_count=worst)


def _pal003(fires: bool):
    import types

    from repro.kernels.backend import default_interpret
    mod = types.ModuleType("mutant_ops")
    if fires:
        mod._on_tpu = lambda: False        # private policy copy

        def wrapper(x, interpret: bool = True):    # wrong default too
            return x
    else:
        mod.default_interpret = default_interpret

        def wrapper(x, interpret: bool | None = None):
            return x
    wrapper.__module__ = mod.__name__   # "defined in" the fake module
    mod.wrapper = wrapper
    return mod


MUTANTS = (
    Mutant("spmd001-bad", "SPMD001", True, "program",
           lambda: _spmd001(True)),
    Mutant("spmd001-near", "SPMD001", False, "program",
           lambda: _spmd001(False)),
    Mutant("spmd002-bad", "SPMD002", True, "program",
           lambda: _spmd002(True)),
    Mutant("spmd002-near", "SPMD002", False, "program",
           lambda: _spmd002(False)),
    Mutant("rep001-bad", "REP001", True, "program",
           lambda: _rep001(True)),
    Mutant("rep001-near", "REP001", False, "program",
           lambda: _rep001(False)),
    Mutant("rep001-fold-bad", "REP001", True, "program",
           lambda: _rep001_fold(True)),
    Mutant("rep001-fold-near", "REP001", False, "program",
           lambda: _rep001_fold(False)),
    Mutant("rep001-crossjob-bad", "REP001", True, "program",
           lambda: _rep001_crossjob(True)),
    Mutant("rep001-crossjob-near", "REP001", False, "program",
           lambda: _rep001_crossjob(False)),
    Mutant("rep001-coded-bad", "REP001", True, "program",
           lambda: _rep001_coded(True)),
    Mutant("rep001-coded-near", "REP001", False, "program",
           lambda: _rep001_coded(False)),
    Mutant("pal001-bad", "PAL001", True, "kernel",
           lambda: _pal001(True)),
    Mutant("pal001-near", "PAL001", False, "kernel",
           lambda: _pal001(False)),
    Mutant("pal001-fused-bad", "PAL001", True, "kernel",
           lambda: _pal001_fused(True)),
    Mutant("pal001-fused-near", "PAL001", False, "kernel",
           lambda: _pal001_fused(False)),
    Mutant("pal002-bad", "PAL002", True, "kernel",
           lambda: _pal002(True)),
    Mutant("pal002-near", "PAL002", False, "kernel",
           lambda: _pal002(False)),
    Mutant("pal003-bad", "PAL003", True, "ops",
           lambda: _pal003(True)),
    Mutant("pal003-near", "PAL003", False, "ops",
           lambda: _pal003(False)),
)


def run_mutant(mutant: Mutant) -> list:
    """Run the matching checker over one mutant; returns its findings."""
    from repro.analysis import rules
    built = mutant.build()
    if mutant.kind == "program":
        return rules.check_program(built)
    if mutant.kind == "kernel":
        return rules.check_kernel(built)
    if mutant.kind == "ops":
        return rules.check_ops_module(built, mutant.name)
    raise ValueError(f"unknown mutant kind {mutant.kind!r}")
