"""Tracing and jaxpr-walking utilities for fleetlint.

Everything here is read-only over jaxprs: trace a
:class:`~repro.core.registry.ProgramHandle` to a ClosedJaxpr (nothing
executes — args are ShapeDtypeStructs), walk equations recursively
through higher-order primitives (pjit / shard_map / scan / while / cond
/ custom_* / pallas_call), and summarize source provenance for findings.
"""
from __future__ import annotations

from collections.abc import Iterable, Iterator

import jax
from jax import core as jcore


def trace_handle(handle) -> jcore.ClosedJaxpr:
    """Trace ``handle.fn(*handle.args)`` to a ClosedJaxpr (no execution).

    The flattened invars follow ``handle.arg_paths`` order (pytree-leaf
    order of ``args``); a mismatch means the handle mis-declares its
    interface, which is itself an error worth raising loudly."""
    closed = jax.make_jaxpr(handle.fn)(*handle.args)
    n_in, n_paths = len(closed.jaxpr.invars), len(handle.arg_paths)
    if n_in != n_paths:
        raise ValueError(
            f"{handle.name}: traced {n_in} flat inputs but arg_paths "
            f"names {n_paths} — handle interface out of sync")
    n_out, n_opaths = len(closed.jaxpr.outvars), len(handle.out_paths)
    if n_out != n_opaths:
        raise ValueError(
            f"{handle.name}: traced {n_out} flat outputs but out_paths "
            f"names {n_opaths} — handle interface out of sync")
    return closed


def where_of(eqn) -> str:
    """``file:line (fn)`` provenance of an equation, best effort."""
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown>"


def _jaxprs_in(v) -> Iterator[jcore.Jaxpr]:
    if isinstance(v, jcore.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jcore.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _jaxprs_in(x)


def subjaxprs(params: dict) -> Iterator[jcore.Jaxpr]:
    """Every jaxpr nested in an equation's params (branches, bodies,
    kernels, ...)."""
    for v in params.values():
        yield from _jaxprs_in(v)


def all_eqns(jaxpr: jcore.Jaxpr) -> Iterator:
    """Depth-first over every equation, descending into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in subjaxprs(eqn.params):
            yield from all_eqns(sub)


def find_eqns(closed: jcore.ClosedJaxpr, names: Iterable[str]) -> list:
    names = frozenset(names)
    return [e for e in all_eqns(closed.jaxpr) if e.primitive.name in names]


def contains_primitive(jaxpr: jcore.Jaxpr, names: Iterable[str]) -> bool:
    names = frozenset(names)
    return any(e.primitive.name in names for e in all_eqns(jaxpr))
