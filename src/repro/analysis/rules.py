"""Rule implementations.

| rule    | proves                                                        |
|---------|---------------------------------------------------------------|
| SPMD001 | collectives only name mesh axes in the program's allowed set  |
| SPMD002 | no collective reachable under rank-divergent control flow     |
| REP001  | outputs asserted replicated really are (taint lattice)        |
| PAL001  | BlockSpec index maps stay in bounds for the shipped grid      |
| PAL002  | integer kernel outputs declare a fitting worst-case count     |
| PAL003  | one shared interpret-mode policy; fallbacks match signatures  |

``check_program`` runs SPMD001/SPMD002/REP001 over one
:class:`~repro.core.registry.ProgramHandle`; ``check_kernel`` runs
PAL001..PAL003 over one :class:`KernelCheck`.
"""
from __future__ import annotations

import dataclasses
import functools
import importlib
import inspect
import itertools
import math
from collections.abc import Callable

import jax
import jax.numpy as jnp

from repro.analysis import taint, tracer
from repro.analysis.taint import Finding

# -- programs (SPMD001 / SPMD002 / REP001) ----------------------------------


def check_program(handle) -> list[Finding]:
    """Trace one ProgramHandle and run the taint rules over it."""
    closed = tracer.trace_handle(handle)
    return taint.analyze_handle(handle, closed)


# -- kernels (PAL001 / PAL002 / PAL003) -------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelCheck:
    """One kernel entry in the shipping corpus.

    ``build()`` returns ``(fn, args, kwargs)`` — a representative traced
    call. ``worst_count`` declares the largest value any *integer* output
    can legitimately hold (PAL002 requires the declaration and that it
    fits the dtype). ``ops_module``/``kernel_fn`` point PAL003 at the
    wrapper module and the ``module:attr`` pallas entry point."""
    name: str
    build: Callable = dataclasses.field(compare=False)
    worst_count: int | None = None
    ops_module: str | None = None
    kernel_fn: str | None = None


def check_kernel(kc: KernelCheck) -> list[Finding]:
    fn, args, kwargs = kc.build()
    if kwargs:
        fn = functools.partial(fn, **kwargs)
    closed = jax.make_jaxpr(fn)(*args)
    findings = check_block_bounds(closed, kc.name)
    findings += check_int_capacity(closed, kc)
    if kc.ops_module:
        findings += check_ops_module(
            importlib.import_module(kc.ops_module), kc.name)
    if kc.kernel_fn:
        findings += check_kernel_signature(kc.kernel_fn, kc.name)
    return findings


def _grid_points(grid: tuple) -> list:
    """Every grid point when the grid is small; otherwise the corner/mid
    lattice (index maps are near-affine, so extremes catch the bugs)."""
    if math.prod(grid) <= 4096:
        return list(itertools.product(*[range(g) for g in grid]))
    axes = [sorted({0, g // 2, g - 1}) for g in grid]
    return list(itertools.product(*axes))


def _block_dim(entry) -> int:
    # block_shape entries are ints, or markers (Mapped/Squeezed) for
    # size-1 squeezed dims depending on the pallas version
    return int(entry) if isinstance(entry, int) else 1


def check_block_bounds(closed, program: str) -> list[Finding]:
    """PAL001: evaluate every BlockSpec index map over the shipped grid
    and require each block index to stay inside the array.

    Scalar-prefetch operands are supplied as zeros — the check covers
    the grid sweep exactly and prefetch-dependent maps at one sample
    point (documented limitation)."""
    findings = []
    for eqn in tracer.find_eqns(closed, ("pallas_call",)):
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            continue
        if getattr(gm, "num_dynamic_grid_bounds", 0):
            continue                       # bounds unknown statically
        grid = tuple(g for g in gm.grid if isinstance(g, int))
        if len(grid) != len(gm.grid) or not grid:
            continue
        points = _grid_points(grid)
        for opi, bm in enumerate(gm.block_mappings):
            if bm is None:
                continue
            shape = tuple(bm.array_shape_dtype.shape)
            blocks = tuple(_block_dim(b) for b in bm.block_shape)
            if len(shape) != len(blocks):
                continue
            limits = [-(-d // b) for d, b in zip(shape, blocks)]
            cj = bm.index_map_jaxpr
            extra = [jnp.zeros(v.aval.shape, v.aval.dtype)
                     for v in cj.jaxpr.invars[len(grid):]]
            if len(cj.jaxpr.invars) < len(grid):
                continue
            for pt in points:
                idx = jax.core.eval_jaxpr(cj.jaxpr, cj.consts,
                                          *pt, *extra)
                if len(idx) != len(limits):
                    break
                oob = [(d, int(i)) for d, (i, lim)
                       in enumerate(zip(idx, limits))
                       if int(i) < 0 or int(i) >= lim]
                if oob:
                    d, i = oob[0]
                    findings.append(Finding(
                        "PAL001", program, tracer.where_of(eqn),
                        f"operand {opi}: index map sends grid point "
                        f"{pt} to block index {i} on dim {d} (valid "
                        f"range [0, {limits[d]}) for array dim "
                        f"{shape[d]}, block {blocks[d]})"))
                    break                  # one finding per operand
    return findings


def check_int_capacity(closed, kc: KernelCheck) -> list[Finding]:
    """PAL002: every integer output needs a declared worst-case count
    that fits its dtype — silent wraparound is how a 2^31-record count
    reads as negative."""
    findings = []
    for i, v in enumerate(closed.jaxpr.outvars):
        dtype = v.aval.dtype
        if not jnp.issubdtype(dtype, jnp.integer):
            continue
        cap = jnp.iinfo(dtype).max
        if kc.worst_count is None:
            findings.append(Finding(
                "PAL002", kc.name, f"output {i}",
                f"integer accumulator ({dtype}) with no declared "
                "worst-case count — declare KernelCheck.worst_count "
                "or widen the dtype"))
        elif kc.worst_count > cap:
            findings.append(Finding(
                "PAL002", kc.name, f"output {i}",
                f"worst-case count {kc.worst_count} exceeds "
                f"{dtype} capacity {cap} — accumulator can wrap"))
    return findings


def check_ops_module(mod, program: str) -> list[Finding]:
    """PAL003 (policy half): a kernel wrapper module must route
    interpret-mode defaults through the one shared policy in
    ``repro.kernels.backend`` — private ``_on_tpu`` copies are exactly
    the drift this analyzer exists to prevent."""
    from repro.kernels import backend as shared
    findings = []
    where = getattr(mod, "__name__", str(mod))
    if getattr(mod, "_on_tpu", None) is not None:
        findings.append(Finding(
            "PAL003", program, where,
            "module defines a private _on_tpu policy; use "
            "repro.kernels.backend.default_interpret"))
    wrappers = []
    for attr, fn in vars(mod).items():
        if attr.startswith("_") or not callable(fn):
            continue
        if fn is shared.default_interpret or fn is shared.on_tpu:
            continue               # the shared policy itself, re-exported
        if getattr(fn, "__module__", None) != getattr(mod, "__name__", None):
            continue               # imported (e.g. the raw pallas entry
            #                        point, whose True default is fine —
            #                        check_kernel_signature covers it)
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):
            continue
        if "interpret" in params:
            wrappers.append((attr, params["interpret"]))
    for attr, param in wrappers:
        if param.default is not None:
            findings.append(Finding(
                "PAL003", program, f"{where}.{attr}",
                f"wrapper defaults interpret={param.default!r}; the "
                "contract is interpret: bool | None = None resolved "
                "via default_interpret"))
    if wrappers and getattr(mod, "default_interpret", None) \
            is not shared.default_interpret:
        findings.append(Finding(
            "PAL003", program, where,
            "wrapper has an interpret parameter but the module does "
            "not use the shared repro.kernels.backend.default_interpret"))
    return findings


def check_kernel_signature(kernel_fn: str, program: str) -> list[Finding]:
    """PAL003 (signature half): the pallas entry point itself must
    accept ``interpret`` so the wrapper's fallback can reach it."""
    modname, attr = kernel_fn.split(":")
    fn = getattr(importlib.import_module(modname), attr)
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return []
    if "interpret" not in params:
        return [Finding(
            "PAL003", program, kernel_fn,
            "pallas entry point has no interpret parameter — the "
            "interpret-mode fallback cannot reach it")]
    return []
