"""Fig 14 — cross-job co-scheduling: global work stealing over a fleet.

Fig 11 shows fair time-slicing fixing *latency* fairness between jobs;
this benchmark attacks the work the slicer cannot touch: a fair slice
still runs ONE job's segment on the whole mesh, so a job whose tail is
concentrated on a hot rank gates every one of its slices at that rank's
speed — K imbalanced jobs pay K hot tails, serially. The WorkDomain
(``repro.core.workdomain``) merges program-compatible jobs into one
composite engine program, so the in-scan claim function
(``core/steal.py``) balances across job boundaries: a rank drained by
job A's light column steals job B's hot tail *in the same device step*
(OS4M's operation-level global scheduling, PAPERS.md).

Methodology mirrors fig9/fig11: **real runs** on host devices prove
record-identity (every co-scheduled job must reproduce its solo
records bit-for-bit, the only acceptance criterion that matters if it
fails) and count actual cross-rank steals inside the merged domain,
while the **calibrated lockstep model** — fed the segment-by-segment
schedules the claim function actually realizes, chained through the
progress row exactly as the engine chains them — produces the
makespan/latency headline. (CPU host devices serialize rank compute,
so a real-run makespan cannot show a parallel win; the model is the
honest instrument, as in fig9.) The model replays BOTH fleets:

  * ``fair``          — fig11's fair slicer: each job solo, one
                        width-1 segment per slice, round-robin;
  * ``fair+cosched``  — one WorkDomain: the merged grid in
                        width-``PACK`` segments with small jobs in
                        higher priority lanes; member latency = the
                        segment in which the shared cursor consumed
                        its last task.

Priority lanes matter for the Jain gate: with equal lanes the giant
job's tail monopolizes early segments and every small job's latency is
quantized to "end of the fleet's first pass", which *reduces* fairness
even as makespan collapses.  Small-jobs-first (``priority=k``; job k
shrinks with k under the Zipf sizes) plus a sub-``K`` pack restores
fig11's interactive-tenant story on top of the makespan win.

Reported per K ∈ {4, 16}: makespan, mean/p95 latency and the Jain
index over per-job normalized service rates (solo / latency), for both
fleets, plus the real-run exactness/steal evidence.

Artifacts: ``results/fig14_crossjob.json`` + repo-root
``BENCH_crossjob.json``.

    PYTHONPATH=src python benchmarks/fig14_crossjob.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess

import numpy as np

try:
    from benchmarks.common import REPO, Costs, calibrate, run_py, save_json
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, Costs, calibrate, run_py, save_json

SIZE_ZIPF = 2.0                  # job-size skew (one giant, many small)
TAIL_SKEW = 1.6                  # per-job rank skew: each job has a hot rank
MEAN_REP = 3
TASK_SIZE = 4096                 # shared by calibration and model
PUSH_CAP = 1024
PACK = 4                         # member segments per domain segment

# Parameters are prepended as plain assignments — the code is brace-heavy.
REAL_CODE = """
import json
import numpy as np
from repro.core import JobConfig, JobScheduler, submit
from repro.core.planner import plan_input
from repro.core.usecases import WordCount
from repro.data.corpus import zipf_skew_repeats
from repro.data.source import ZipfSource
from repro.distributed.mesh import local_mesh

VOCAB = 4096
mesh = local_mesh((P,), ("procs",))


def make_jobs(K):
    w = np.arange(1, K + 1, dtype=np.float64) ** (-SIZE_ZIPF)
    w /= w.sum()
    jobs = []
    for k in range(K):
        n = max(int(round(TOTAL * w[k])), P * TASK)
        n -= n % TASK
        T = plan_input(n, TASK, P).tasks_per_proc
        # each job's hot rank is k mod P: the cross-job adversary —
        # different members gate on different ranks, which is exactly
        # what a fleet-wide cursor can balance and a solo slicer cannot
        reps = np.roll(zipf_skew_repeats(P, T, TAIL_SKEW,
                                         mean_rep=MEAN_REP, seed=k),
                       k, axis=0)
        cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                        task_size=TASK, push_cap=CAP, n_procs=P,
                        segment=1, stealing=True)
        jobs.append(dict(k=k, cfg=cfg, n=n, reps=reps,
                         src=ZipfSource(n, VOCAB, seed=2000 + k)))
    return jobs


def run_fleet(jobs, coschedule, measure):
    sched = JobScheduler(policy="fair", mesh=mesh, coschedule=coschedule,
                         copack=PACK)
    for j in jobs:
        # small jobs (larger k) ride higher priority lanes in the domain
        sched.submit(j["cfg"], j["src"], tenant=f"tenant-{j['k']}",
                     name=f"job-{j['k']}", repeats=j["reps"],
                     priority=j["k"])
    res = sched.run_until_complete()
    if not measure:
        return None
    lat = np.array([sched.latency(f"job-{j['k']}") for j in jobs])
    row = dict(latencies_s=[float(v) for v in lat],
               makespan_s=float(lat.max()),
               mean_latency_s=float(lat.mean()),
               p95_latency_s=float(np.percentile(lat, 95)),
               n_unique_programs=sched.n_unique_programs,
               records={j["k"]: res[f"job-{j['k']}"].records
                        for j in jobs})
    if coschedule:
        row["n_domains"] = len(sched._domains)
        row["crossrank_steals"] = int(sum(
            np.asarray(d.handle._carry.stolen)[0].sum()
            for d in sched._domains))
        row["job_work"] = [int(v) for d in sched._domains
                           for v in d.job_work()]
    return row


out = {}
for K in KS:
    jobs = make_jobs(K)
    solo = {}
    for j in jobs:                        # per-job exactness baselines
        res = submit(j["cfg"], j["src"], mesh=mesh,
                     repeats=j["reps"]).result()
        solo[j["k"]] = res.records
    row = {"jobs": [dict(k=j["k"], n_tokens=j["n"]) for j in jobs],
           "fleets": {}}
    for label, cos in (("fair", False), ("fair+cosched", True)):
        if WARM:
            run_fleet(jobs, cos, measure=False)   # warm the programs
        r = run_fleet(jobs, cos, measure=True)
        r["exact_all"] = bool(all(r["records"][j["k"]] == solo[j["k"]]
                                  for j in jobs))
        del r["records"]
        row["fleets"][label] = r
    out[str(K)] = row
print(json.dumps(out))
"""


# ---------------------------------------------------------------------------
# the lockstep fleet model — replaying the realized schedules
# ---------------------------------------------------------------------------

def _member_grids(K: int, P: int, total_cols: int):
    """K member grids with fig11's Zipf job sizes and per-job hot-rank
    tails (job k hot on rank k mod P) — same adversary as REAL_CODE."""
    from repro.data.corpus import zipf_skew_repeats
    w = np.arange(1, K + 1, dtype=np.float64) ** (-SIZE_ZIPF)
    w /= w.sum()
    grids = []
    for k in range(K):
        T = max(int(round(total_cols * w[k])), 1)
        ids = np.arange(P * T, dtype=np.int32).reshape(P, T)
        reps = np.roll(zipf_skew_repeats(P, T, TAIL_SKEW,
                                         mean_rep=MEAN_REP, seed=k),
                       k, axis=0)
        grids.append((ids, reps))
    return grids


def _lockstep_seg(costs: Costs, exec_reps: np.ndarray) -> float:
    """Lockstep cost of one realized segment (the '1s+steal' round
    structure of benchmarks.common.simulate, on a given schedule)."""
    t = 0.0
    for k in range(exec_reps.shape[1]):
        col = exec_reps[:, k]
        live = col > 0
        if not live.any():
            continue
        busy = np.where(live, costs.task_time(col), 0.0) + costs.t_fold
        comp = float(busy.max())
        dur = (max(comp, costs.t_a2a_chunk) if costs.comm_overlap
               else comp + costs.t_a2a_chunk)
        t += dur + costs.t_fetch
    return t


def model_fleet(costs: Costs, K: int, P: int, total_cols: int) -> dict:
    """Model both fleets over the same member grids.

    fair: each job is sliced solo in width-1 segments (fig11's fair
    scheduler with ``segment=1``) — within a slice every rank runs its
    own column task, so the hot rank gates the slice; slices round-robin
    across jobs (what the fair policy converges to for equal tenants).

    fair+cosched: ONE WorkDomain — the merged composite grid advances
    in width-K segments through the *realized* steal schedule, chained
    through the progress row exactly as the engine chains segments; a
    member's latency is the model time at the end of the segment in
    which the shared cursor consumed its last task.
    """
    from repro.core.steal import fleet_merge, steal_schedule
    grids = _member_grids(K, P, total_cols)
    stride = max(g.shape[1] * P for g, _ in grids)

    # -- fair: solo per-segment durations, then round-robin interleave
    seg_durs = []                       # per job: list of slice costs
    for ids, reps in grids:
        work = np.zeros((P,), np.int32)
        durs = []
        for c in range(ids.shape[1]):
            sch = steal_schedule(ids[:, c: c + 1], reps[:, c: c + 1],
                                 work0=work)
            work = sch.work
            durs.append(_lockstep_seg(costs, sch.exec_reps))
        seg_durs.append(durs)
    t = 0.0
    lat_fair = [0.0] * K
    cursor = [0] * K
    alive = list(range(K))
    while alive:
        for j in list(alive):
            t += seg_durs[j][cursor[j]]
            cursor[j] += 1
            if cursor[j] == len(seg_durs[j]):
                lat_fair[j] = t
                alive.remove(j)
    solo = [float(sum(d)) for d in seg_durs]      # job alone on the mesh

    # -- fair+cosched: one domain, width-PACK segments over the merged
    # grid with small jobs (larger k) in higher priority lanes — the
    # same lanes/pack the scheduler realizes via submit(priority=k) and
    # JobScheduler(copack=PACK)
    ids, reps = fleet_merge([g for g, _ in grids],
                            [r for _, r in grids], stride=stride,
                            priorities=list(range(K)))
    totals = [int((g >= 0).sum()) for g, _ in grids]
    done = np.zeros((K,), np.int64)
    t = 0.0
    lat_co = [0.0] * K
    work = np.zeros((P,), np.int32)
    for c0 in range(0, ids.shape[1], PACK):
        sch = steal_schedule(ids[:, c0: c0 + PACK],
                             reps[:, c0: c0 + PACK],
                             work0=work, coslots=K, costride=stride)
        work = sch.work
        t += _lockstep_seg(costs, sch.exec_reps)
        ex = sch.exec_ids[sch.exec_ids >= 0]
        done += np.bincount(ex // stride, minlength=K)
        for j in range(K):
            if lat_co[j] == 0.0 and done[j] >= totals[j]:
                lat_co[j] = t

    def summarize(lat):
        lat = np.asarray(lat)
        x = np.asarray(solo) / np.maximum(lat, 1e-12)
        return dict(makespan_s=float(lat.max()),
                    mean_latency_s=float(lat.mean()),
                    p95_latency_s=float(np.percentile(lat, 95)),
                    jain=float(x.sum() ** 2 / (len(x) * (x ** 2).sum())),
                    latencies_s=[float(v) for v in lat])

    return {"P": P, "total_cols": total_cols,
            "n_tasks": [int(t_) for t_ in totals],
            "fair": summarize(lat_fair),
            "fair+cosched": summarize(lat_co)}


def measure_real(ks, n_procs, total, task, cap, warm=True) -> dict:
    # One subprocess per K with a bounded per-attempt timeout and
    # retries: on a 1-core host, XLA's 8-device collective rendezvous
    # can occasionally starve and stall a run forever (observed as a
    # sleeping process, not slow compute — retrying a fresh subprocess
    # recovers every time). Clean runs finish well inside the budget,
    # so a stalled attempt is cheap to abandon.
    out = {}
    for k in ks:
        params = (f"P={n_procs}\nTASK={task}\nCAP={cap}\nKS=[{k}]\n"
                  f"TOTAL={total}\nSIZE_ZIPF={SIZE_ZIPF}\n"
                  f"TAIL_SKEW={TAIL_SKEW}\nMEAN_REP={MEAN_REP}\n"
                  f"PACK={PACK}\nWARM={int(warm)}\n")
        for attempt in range(3):
            try:
                got = run_py(params + REAL_CODE, n_devices=n_procs,
                             timeout=300)
                break
            except subprocess.TimeoutExpired:
                print(f"[fig14] real run K={k} stalled "
                      f"(attempt {attempt + 1}/3), retrying...")
        else:
            raise RuntimeError(f"real run K={k} stalled 3 times")
        out.update(json.loads(got.strip().splitlines()[-1]))
    return out


def run(quick: bool = False, smoke: bool = False) -> dict:
    # the model is cheap — keep it at full scale even in smoke so the
    # printed makespan/jain story matches the committed baseline; only
    # the real (subprocess) runs shrink
    if smoke:
        model_ks, model_p, model_cols = (4, 16), 8, 96
        real_ks, real_p, real_total, task, cap = (4,), 2, 98_304, 512, 256
    elif quick:
        model_ks, model_p, model_cols = (4, 16), 32, 48
        real_ks, real_p, real_total, task, cap = \
            (4, 16), 4, 393_216, 1024, 256
    else:
        model_ks, model_p, model_cols = (4, 16), 64, 96
        real_ks, real_p, real_total, task, cap = \
            (4, 16), 8, 786_432, 1024, 512

    print("[fig14] calibrating per-op costs...")
    calib = calibrate(task_size=TASK_SIZE, push_cap=PUSH_CAP)
    fetch = calib["t_a2a_lat"] + calib["t_a2a_byte"] * (
        (TASK_SIZE + 2) * 4) / (PUSH_CAP * 8)
    costs = dataclasses.replace(Costs.from_calibration(calib),
                                t_fetch=fetch)

    model = {}
    for K in model_ks:
        row = model_fleet(costs, K, model_p, model_cols)
        model[str(K)] = row
        f, c = row["fair"], row["fair+cosched"]
        print(f"[fig14] model K={K:<3} makespan {f['makespan_s']:.3f}s ->"
              f" {c['makespan_s']:.3f}s "
              f"({100 * (1 - c['makespan_s'] / f['makespan_s']):+.1f}%),"
              f" jain {f['jain']:.2f} -> {c['jain']:.2f}")

    print(f"[fig14] real runs (P={real_p}, total={real_total}, "
          f"K={list(real_ks)})...")
    real = measure_real(real_ks, real_p, real_total, task, cap,
                        warm=not smoke)

    maxk = str(max(model_ks))
    mf = model[maxk]["fair"]
    mc = model[maxk]["fair+cosched"]
    win_mk = 100.0 * (1 - mc["makespan_s"] / mf["makespan_s"])
    win_p95 = 100.0 * (1 - mc["p95_latency_s"] / mf["p95_latency_s"])
    exact = all(fl["exact_all"] for row in real.values()
                for fl in row["fleets"].values())
    steals = sum(row["fleets"]["fair+cosched"]["crossrank_steals"]
                 for row in real.values())
    one_domain = all(row["fleets"]["fair+cosched"]["n_domains"] == 1
                     for row in real.values())
    rec = {
        "size_zipf": SIZE_ZIPF, "tail_skew": TAIL_SKEW,
        "mean_rep": MEAN_REP, "K_values": list(model_ks),
        "model": model,
        "real": {"P": real_p, "total_tokens": real_total,
                 "K_values": list(real_ks), "per_k": real},
        "calibration": calib,
        "criteria": {
            "max_K": int(maxk),
            # the acceptance gate: at the highest K the co-scheduled
            # fleet must beat fig11's fair slicer on BOTH makespan...
            "cosched_makespan_win_pct": win_mk,
            "cosched_beats_fair_makespan": bool(
                mc["makespan_s"] < mf["makespan_s"]),
            "cosched_p95_win_pct": win_p95,
            # ...and latency fairness (Jain over solo/latency)
            "jain_fair": mf["jain"],
            "jain_cosched": mc["jain"],
            "cosched_beats_fair_jain": bool(mc["jain"] > mf["jain"]),
            # measured, not assumed: every job in every fleet at every
            # K reproduced its solo records bit-for-bit
            "all_jobs_exact": bool(exact),
            # and the merged domain actually stole across ranks (the
            # mechanism ran — the win is not a bookkeeping artifact)
            "crossjob_steals_real": int(steals),
            "crossjob_stealing_active": bool(steals > 0),
            "one_domain_per_fleet": bool(one_domain),
        },
    }
    path = save_json("fig14_crossjob.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        root = os.path.join(REPO, "BENCH_crossjob.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    print(f"[fig14] K={maxk}: cosched vs fair makespan {win_mk:+.1f}%, "
          f"p95 {win_p95:+.1f}%, jain {mf['jain']:.2f} -> "
          f"{mc['jain']:.2f}; real cross-rank steals {steals}")
    print("wrote " + " and ".join(wrote))
    if not exact:
        raise RuntimeError("a co-scheduled job diverged from its solo "
                           "run — see real.per_k.*.fleets.*.exact_all")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller model grid / fewer tokens")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, never overwrites the "
                         "committed baseline")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
