"""Fig 8 — non-blocking I/O overlap: streamed vs fully-resident input.

The paper's decoupled strategy overlaps each Map task's compute with the
asynchronous retrieval of the next task's input (§2.1). This benchmark
measures that overlap on the Job API's streaming path:

  * **resident** — the input array lives in host RAM and each segment's
    block is gathered synchronously on the critical path
    (``prefetch=False``): the blocking-I/O baseline, equivalent to the
    old pre-sharded data path.
  * **streamed** — the input is a memory-mapped token file behind
    ``MmapTokenSource``; the SegmentFeed reads segment t+1 by file
    offset and dispatches its device transfer in a background thread
    while the engine computes segment t (``prefetch=True``).

The overlap win is ``1 - streamed/resident`` per task size; streamed
must stay within 10% of (or beat) resident even where segments are tiny
and the prefetch thread has nothing to hide behind.

Artifacts: ``results/fig8_io_overlap.json`` and a repo-root
``BENCH_io_overlap.json`` (machine-readable perf trajectory seed).

    PYTHONPATH=src python benchmarks/fig8_io_overlap.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks.common import REPO, run_py, save_json
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, run_py, save_json

CODE = """
import json, os, tempfile, time
import numpy as np
from repro.core import JobConfig, submit
from repro.core.usecases import WordCount
from repro.data.corpus import synth_corpus
from repro.data.source import MmapTokenSource

P, VOCAB, CAP = {n_procs}, 65536, 1024
N = {n_tokens}
SEG = {segment}
tokens = synth_corpus(N, VOCAB, seed=0)
path = os.path.join(tempfile.mkdtemp(), "corpus.bin")
tokens.tofile(path)

def run(task, dataset, prefetch):
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                    task_size=task, push_cap=CAP, n_procs=P, segment=SEG)
    h = submit(cfg, dataset, prefetch=prefetch)
    h._ensure_segmented()          # compile outside the timed region
    t0 = time.perf_counter()
    while h.step():
        pass
    res = h.result()
    return time.perf_counter() - t0, res, h.feed.stats

out = {{}}
oracle = None
for task in {task_sizes}:
    run(task, tokens, False)                    # warm: compile this shape
    rs = [run(task, tokens, False) for _ in range(
        {reps})]                                # resident, blocking gather
    ss = [run(task, MmapTokenSource(path), True) for _ in range({reps})]
    t_res = min(t for t, _, _ in rs)
    t_str = min(t for t, _, _ in ss)
    r0, s0 = rs[0][1], ss[0][1]
    assert s0.records == r0.records, "streamed != resident records"
    st = ss[0][2]
    out[str(task)] = dict(
        resident_s=t_res, streamed_s=t_str,
        overlap_win_pct=100.0 * (1.0 - t_str / t_res),
        prefetch_hits=st.prefetch_hits, segments=st.segments_built,
        feed_max_live_bytes=st.max_live_bytes,
        bytes_streamed=st.bytes_read)
print(json.dumps(out))
"""


def measure(task_sizes, n_tokens: int, segment: int, n_procs: int = 8,
            reps: int = 3) -> dict:
    out = run_py(CODE.format(n_procs=n_procs, n_tokens=n_tokens,
                             segment=segment, task_sizes=list(task_sizes),
                             reps=reps),
                 n_devices=n_procs)
    per_size = json.loads(out.strip().splitlines()[-1])
    worst = min(v["overlap_win_pct"] for v in per_size.values())
    return {
        "n_tokens": n_tokens, "segment": segment, "n_procs": n_procs,
        "per_task_size": per_size,
        "worst_overlap_win_pct": worst,
        "streamed_within_10pct": worst >= -10.0,
    }


def run(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        rec = measure(task_sizes=[1024], n_tokens=131_072, segment=2,
                      n_procs=2, reps=1)
    elif quick:
        rec = measure(task_sizes=[1024, 4096], n_tokens=1_000_000,
                      segment=2)
    else:
        rec = measure(task_sizes=[1024, 4096, 16384], n_tokens=4_000_000,
                      segment=2)
    path = save_json("fig8_io_overlap.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        # — a CI-scale smoke run must never clobber it
        root = os.path.join(REPO, "BENCH_io_overlap.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    print(json.dumps(rec["per_task_size"], indent=1))
    print(f"worst overlap win: {rec['worst_overlap_win_pct']:+.1f}% "
          f"(streamed within 10% of resident: "
          f"{rec['streamed_within_10pct']})")
    print("wrote " + " and ".join(wrote))
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer tokens / task sizes")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, still writes both artifacts")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
