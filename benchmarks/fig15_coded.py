"""Fig 15 — coded shuffle: bytes-on-the-wire vs the r=1 reference.

Coded MapReduce (PAPERS.md, arXiv 1512.01625) trades r× replicated map
work for ~1/r shuffle traffic: when every task runs on r consecutive
ranks, one XOR-coded multicast block per step replaces the r-1 unicast
bucket rows inside each code group, and inter-group buckets are
deduplicated to one speaker each (``JobConfig(code_rate=r)``,
core/coded.py + distributed/collectives.coded_exchange).

This benchmark states the win as PUSH-SHUFFLE bytes on the wire,
accounted deterministically over each *realized* run (fixed-capacity
buckets exactly as the engine ships them; the coded multicast block is
counted ONCE per step — the multicast convention of the Coded MapReduce
literature). Per rank per step the engine ships

    r=1:  P-1 unicast bucket blocks
    r>1:  1 coded block + (P/r - 1) speaker blocks

so at P=6 the ratio is 0.60 at r=2 and 0.40 at r=3 — independent of the
rank skew ``s``, which the sweep demonstrates while wall time and steal
counts vary. The trade is reported honestly: replication multiplies map
compute, feed reads, and the steal path's fetch blocks by r
(``fetch_bytes`` / ``feed_bytes_read`` ride in the artifact next to the
headline ``shuffle_bytes``); replication pays exactly when the reduce
path — not the map path — is the bottleneck.

**Exactness is measured, not assumed**: every run (r∈{1,2,3}, a stolen
r=2 arm, every skew) is recorded against the r=1 reference records and
the host oracle; bench-guard require_true's both flags, and an absolute
floor on the bytes win makes a silently-degenerate r=1 fallback fail CI.

Artifacts: ``results/fig15_coded.json`` + repo-root ``BENCH_coded.json``.

    PYTHONPATH=src python benchmarks/fig15_coded.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks.common import REPO, run_py, save_json
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, run_py, save_json

SKEWS = [0.0, 0.6, 1.1, 1.6]
MEAN_REP = 4
TASK_SIZE = 4096
PUSH_CAP = 1024
# P must be divisible by every code rate swept (6 = lcm(2, 3)); the
# bytes ratio (P/r)/(P-1) then clears the 0.65 acceptance gate at r=2
N_PROCS = 6
CODE_RATES = [1, 2, 3]

REAL_CODE = """
import collections, json
import numpy as np
from repro.core import JobConfig, submit
from repro.core.coded import RECORD_BYTES, shuffle_bytes
from repro.core.planner import plan_input
from repro.core.usecases import WordCount
from repro.data.corpus import synth_corpus, zipf_skew_repeats

P, N, VOCAB, task, CAP = {n_procs}, {n_tokens}, 65536, {task_size}, {push_cap}
tokens = synth_corpus(N, VOCAB, seed=0)
oracle = collections.Counter(np.asarray(tokens).tolist())
T = plan_input(N, task, P).tasks_per_proc
arms = [("r1", 1, False), ("r2", 2, False), ("r3", 3, False),
        ("r2+steal", 2, True)]
out = {{}}
for s in {skews}:
    reps = zipf_skew_repeats(P, T, s, mean_rep={mean_rep}, seed=1)
    row, base = {{}}, None
    for label, r, stealing in arms:
        cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                        task_size=task, push_cap=CAP, n_procs=P,
                        stealing=stealing, code_rate=r)
        submit(cfg, tokens, repeats=reps).result()    # compile + warm
        walls = []
        for _ in range({reps_n}):
            h = submit(cfg, tokens, repeats=reps)
            res = h.result()
            walls.append(res.wall_time)
        if base is None:
            base = res.records
        # bytes accounted over the realized schedule: every arm runs T
        # engine steps (the coded grid is T r-wide column blocks), and
        # the steal fetch ships r*(task+2) int32 per stolen block
        row[label] = dict(
            wall_s=min(walls), r=r, n_steals=res.n_steals,
            shuffle_bytes=shuffle_bytes(P, T, CAP, r),
            fetch_bytes=res.n_steals * r * (task + 2) * 4,
            feed_bytes_read=int(h.feed.stats.bytes_read),
            # recorded, not asserted: the artifact carries the real
            # outcome so bench-guard's require_true is a live check
            records_equal=bool(res.records == base),
            oracle_exact=bool(res.records == dict(oracle)))
    out[str(s)] = row
print(json.dumps(out))
"""


def measure_real(skews, n_procs: int, n_tokens: int, reps_n: int) -> dict:
    out = run_py(REAL_CODE.format(n_procs=n_procs, n_tokens=n_tokens,
                                  skews=list(skews), mean_rep=MEAN_REP,
                                  reps_n=reps_n, task_size=TASK_SIZE,
                                  push_cap=PUSH_CAP),
                 n_devices=n_procs)
    return json.loads(out.strip().splitlines()[-1])


def run(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        skews = [SKEWS[0], SKEWS[-1]]
        real_n, reps_n = 98_304, 1
    elif quick:
        skews = SKEWS
        real_n, reps_n = 393_216, 1
    else:
        skews = SKEWS
        real_n, reps_n = 786_432, 2

    from repro.core.coded import shuffle_blocks_per_step
    P = N_PROCS
    blocks = {str(r): shuffle_blocks_per_step(P, r) for r in CODE_RATES}

    print(f"[fig15] real runs (P={P}, N={real_n}, r={CODE_RATES})...")
    real = measure_real(skews, P, real_n, reps_n)

    top = real[str(skews[-1])]
    ref = top["r1"]["shuffle_bytes"]
    ratio = {str(r): top[f"r{r}"]["shuffle_bytes"] / ref
             for r in CODE_RATES if r > 1}
    records_equal = all(arm["records_equal"]
                        for row in real.values() for arm in row.values())
    oracle_exact = all(arm["oracle_exact"]
                       for row in real.values() for arm in row.values())
    rec = {
        "skews": list(skews), "mean_rep": MEAN_REP,
        "code_rates": CODE_RATES,
        "real": {"P": P, "n_tokens": real_n, "task_size": TASK_SIZE,
                 "push_cap": PUSH_CAP, "per_skew": real},
        "bytes": {
            # per rank per step logical payload blocks; the coded
            # multicast block counts once (see module docstring)
            "per_step_blocks": blocks,
            "shuffle_ratio_at_max_skew": ratio,
        },
        "criteria": {
            "shuffle_ratio_r2_at_max_skew": ratio["2"],
            "shuffle_ratio_r3_at_max_skew": ratio["3"],
            # the headline: shuffle bytes saved by r=2 vs the r=1
            # reference (a degenerate r=1 fallback scores 0 and trips
            # bench-guard's absolute floor)
            "bytes_win_r2_pct": 100.0 * (1.0 - ratio["2"]),
            "bytes_win_r3_pct": 100.0 * (1.0 - ratio["3"]),
            # the acceptance gate: r=2 must cut shuffle bytes to at
            # most 0.65x the r=1 reference at the largest skew point
            "r2_le_065_at_max_skew": bool(ratio["2"] <= 0.65),
            "records_equal": records_equal,
            "oracle_exact": oracle_exact,
        },
    }
    path = save_json("fig15_coded.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        # — a CI-scale smoke run must never clobber it
        root = os.path.join(REPO, "BENCH_coded.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    print(f"[fig15] shuffle ratio at s={skews[-1]}: "
          f"r=2 {ratio['2']:.2f}x, r=3 {ratio['3']:.2f}x "
          f"(records_equal={records_equal}, oracle_exact={oracle_exact})")
    print("wrote " + " and ".join(wrote))
    if not (records_equal and oracle_exact):
        raise RuntimeError("coded runs diverged from the r=1 reference — "
                           "see real.per_skew flags in the artifact")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer tokens / single timing rep")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, results/ artifact only")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
