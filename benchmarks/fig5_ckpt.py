"""Fig 5 — checkpoint overhead of the storage-window fault-tolerance path.

Paper: MPI storage windows + MPI_Win_sync after each Map task and after
Reduce cost only ≈4.8% because transfers overlap compute.

Here: a segmented MR-1S JobHandle snapshots its window carry after every
``step()`` via ``handle.checkpoint(manager)`` (the device_get runs in a
worker thread, overlapping the next segment's compute — the same
mechanism). We measure wall time with checkpoints off / async /
sync(blocking).
"""
from __future__ import annotations

import json

from benchmarks.common import run_py, save_json

CODE = """
import json, time, tempfile
import numpy as np, jax
from repro.ckpt.checkpoint import CheckpointManager
from repro.core import JobConfig, submit
from repro.core.usecases import WordCount
from repro.data.corpus import synth_corpus

P, task, VOCAB = 8, 4096, 65536
N = {n_tokens}
tokens = synth_corpus(N, VOCAB, seed=0)
cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                task_size=task, push_cap=1024, n_procs=P, segment=2)

def run(mode):
    mgr = CheckpointManager(tempfile.mkdtemp(), keep=2) \\
        if mode != "off" else None
    handle = submit(cfg, tokens)
    handle._ensure_segmented()
    jax.block_until_ready(handle.carry)
    t0 = time.perf_counter()
    while True:
        more = handle.step()
        if mode == "async":
            handle.checkpoint(mgr)
        elif mode == "sync":
            mgr.save(handle.cursor, handle.carry,
                     extra={{"cursor": handle.cursor}})
        if not more:
            break
    out = handle.result()
    if mgr:
        mgr.wait()
    return time.perf_counter() - t0

out = {{}}
for mode in ("off", "async", "sync"):
    run(mode)                        # warm (compile)
    ts = [run(mode) for _ in range(3)]
    out[mode] = min(ts)
print(json.dumps(out))
"""


def run(quick: bool = False) -> dict:
    n = 500_000 if quick else 2_000_000
    out = run_py(CODE.format(n_tokens=n), n_devices=8)
    t = json.loads(out.strip().splitlines()[-1])
    rec = {
        "times_s": t,
        "async_overhead_pct": 100 * (t["async"] / t["off"] - 1),
        "sync_overhead_pct": 100 * (t["sync"] / t["off"] - 1),
        "paper_claim_pct": 4.8,
    }
    print(f"[fig5] ckpt overhead: async {rec['async_overhead_pct']:+.1f}% "
          f"(paper ≈4.8%), blocking {rec['sync_overhead_pct']:+.1f}%")
    save_json("fig5_ckpt.json", rec)
    return rec


if __name__ == "__main__":
    run()
