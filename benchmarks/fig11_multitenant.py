"""Fig 11 — multi-tenant scheduling: K concurrent jobs over one mesh.

The paper's decoupled strategy keeps *processes* from waiting on each
other; this benchmark lifts the argument one level: when K tenants'
*jobs* are unbalanced (Zipf-skewed sizes — one giant, many small), a
job-granular FIFO queue serializes every tenant behind the straggler,
while `repro.core.scheduler.JobScheduler` time-slices all live jobs at
*segment* granularity over the same compiled engines (OS4M's
operation-granularity scheduling, PAPERS.md).

Real runs only — scheduling is host-side ordering, so its latency
effects are directly measurable even on one oversubscribed CPU core
(unlike phase overlap, which needs the lockstep model). For each
K ∈ {1, 4, 16}: a WordCount/Histogram/InvertedIndex job mix with
Zipf(2.0) sizes is submitted biggest-first (the adversarial
head-of-line order) under FIFO vs fair-share vs priority, and we
record per-job completion latency, makespan, mean/p95 latency, and the
Jain fairness index over per-job normalized service rates
(solo_wall / latency). Every job's records are compared against its
own solo run — time slicing must be invisible in the output — and the
whole fleet shares one FeedBudget plus (asserted) one compiled program
per use-case.

Artifacts: ``results/fig11_multitenant.json`` + repo-root
``BENCH_multitenant.json``.

    PYTHONPATH=src python benchmarks/fig11_multitenant.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks.common import REPO, run_py, save_json
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, run_py, save_json

SIZE_ZIPF = 2.0                  # job-size skew exponent (one giant tenant)

# Parameters are prepended as plain assignments (P, TASK, CAP, KS, TOTAL,
# SIZE_ZIPF, BUDGET_SEGS) — no str.format, the code below is brace-heavy.
REAL_CODE = """
import json
import numpy as np
from repro.core import JobConfig, JobScheduler, submit
from repro.core.usecases import Histogram, InvertedIndex, WordCount
from repro.data.source import ZipfSource
from repro.distributed.mesh import local_mesh

VOCAB = 4096
mesh = local_mesh((P,), ("procs",))

USECASES = [
    ("wordcount", WordCount(vocab=VOCAB)),
    ("histogram", Histogram(vocab=VOCAB, n_bins=64)),
    ("inverted-index", InvertedIndex(queries=(3, 17, 42, 99), n_docs=8,
                                     tasks_per_doc=2)),
]


def make_jobs(K):
    w = np.arange(1, K + 1, dtype=np.float64) ** (-SIZE_ZIPF)
    w /= w.sum()
    jobs = []
    for k in range(K):     # biggest first: the straggler leads the queue
        n = max(int(round(TOTAL * w[k])), P * TASK)
        n -= n % TASK                     # whole tasks only
        label, uc = USECASES[k % len(USECASES)]
        cfg = JobConfig(usecase=uc, backend="1s", task_size=TASK,
                        push_cap=CAP, n_procs=P, segment=1)
        jobs.append(dict(k=k, label=label, cfg=cfg, n=n,
                         src=ZipfSource(n, VOCAB, seed=1000 + k)))
    return jobs


# warm the three compiled programs once; every run below (solo or
# scheduled, any K) shares them — the memoization the scheduler asserts
for _, uc in USECASES:
    cfg = JobConfig(usecase=uc, backend="1s", task_size=TASK,
                    push_cap=CAP, n_procs=P, segment=1)
    submit(cfg, ZipfSource(2 * P * TASK, VOCAB, seed=7), mesh=mesh).result()

out = {}
for K in KS:
    jobs = make_jobs(K)
    solo = {}
    for j in jobs:                        # per-job exactness baselines
        res = submit(j["cfg"], j["src"], mesh=mesh).result()
        solo[j["k"]] = (res.records, res.wall_time)
    row = {"jobs": [dict(k=j["k"], usecase=j["label"], n_tokens=j["n"])
                    for j in jobs],
           "policies": {}}
    for pol in ("fifo", "fair", "priority"):
        sched = JobScheduler(policy=pol, mesh=mesh,
                             max_live_bytes=BUDGET_SEGS * P * TASK * 4)
        for j in jobs:
            # smaller jobs carry higher priority (the interactive-tenant
            # story for the priority policy)
            sched.submit(j["cfg"], j["src"], tenant=f"tenant-{j['k']}",
                         name=f"job-{j['k']}", priority=j["k"])
        res = sched.run_until_complete()
        lat = np.array([sched.latency(f"job-{j['k']}") for j in jobs])
        exact = all(res[f"job-{j['k']}"].records == solo[j["k"]][0]
                    for j in jobs)
        x = np.array([solo[j["k"]][1] for j in jobs]) / np.maximum(lat,
                                                                   1e-9)
        jain = float(x.sum() ** 2 / (len(x) * (x ** 2).sum()))
        denials = sum(sj.handle.feed.stats.budget_denials
                      for sj in sched.jobs)
        row["policies"][pol] = dict(
            makespan_s=float(lat.max()),
            mean_latency_s=float(lat.mean()),
            p95_latency_s=float(np.percentile(lat, 95)),
            jain=jain,
            latencies_s=[float(v) for v in lat],
            exact_all=bool(exact),
            n_unique_programs=sched.n_unique_programs,
            budget_denials=int(denials))
    out[str(K)] = row
print(json.dumps(out))
"""


def measure_real(ks, n_procs: int, total: int, task: int, cap: int,
                 budget_segs: int) -> dict:
    params = (f"P={n_procs}\nTASK={task}\nCAP={cap}\nKS={list(ks)}\n"
              f"TOTAL={total}\nSIZE_ZIPF={SIZE_ZIPF}\n"
              f"BUDGET_SEGS={budget_segs}\n")
    out = run_py(params + REAL_CODE, n_devices=n_procs)
    return json.loads(out.strip().splitlines()[-1])


def run(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        ks, n_procs, total, task, cap = (1, 8), 2, 196_608, 512, 256
    elif quick:
        ks, n_procs, total, task, cap = (1, 4, 16), 4, 1_228_800, 1024, 256
    else:
        ks, n_procs, total, task, cap = (1, 4, 16), 8, 3_145_728, 1024, 512
    budget_segs = 8          # tight on purpose: K=16 tenants must queue
                             # prefetch behind the shared FeedBudget

    print(f"[fig11] real runs (P={n_procs}, total={total}, K={list(ks)})...")
    real = measure_real(ks, n_procs, total, task, cap, budget_segs)

    maxk = str(max(ks))
    pk = real[maxk]["policies"]
    fifo, fair = pk["fifo"], pk["fair"]
    win_p95 = 100.0 * (1 - fair["p95_latency_s"] / fifo["p95_latency_s"])
    win_mean = 100.0 * (1 - fair["mean_latency_s"] / fifo["mean_latency_s"])
    mk_pct = 100.0 * (fair["makespan_s"] / fifo["makespan_s"] - 1)
    lat_prio = pk["priority"]["latencies_s"]
    half = len(lat_prio) // 2
    # submission is biggest-first and priority=k, so the SECOND half of
    # the latency list is the high-priority (small, interactive) cohort
    prio_ok = (len(lat_prio) < 2
               or (sum(lat_prio[half:]) / max(len(lat_prio) - half, 1)
                   < sum(lat_prio[:half]) / half))
    exact = all(p["exact_all"]
                for row in real.values() for p in row["policies"].values())
    rec = {
        "size_zipf": SIZE_ZIPF,
        "K_values": list(ks),
        "per_k": real,
        "criteria": {
            "max_K": int(maxk),
            # the acceptance gate: at the highest K, fair share must cut
            # the p95 job latency vs head-of-line FIFO by >= 25%...
            "fairshare_p95_win_pct": win_p95,
            "fairshare_beats_fifo_p95": bool(win_p95 > 0),
            "fairshare_mean_win_pct": win_mean,
            # ...without inflating the fleet makespan (same total work,
            # same mesh — slicing order must be ~free)
            "fair_vs_fifo_makespan_pct": mk_pct,
            "jain_fair": fair["jain"],
            "jain_fifo": fifo["jain"],
            "fair_jain_beats_fifo": bool(fair["jain"] > fifo["jain"]),
            "priority_favors_high": bool(prio_ok),
            # measured, not assumed: every job under every policy at
            # every K stayed record-identical to its solo run
            "all_jobs_exact": bool(exact),
        },
    }
    path = save_json("fig11_multitenant.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        root = os.path.join(REPO, "BENCH_multitenant.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    print(f"[fig11] K={maxk}: fair vs fifo p95 {win_p95:+.1f}% "
          f"(mean {win_mean:+.1f}%, makespan {mk_pct:+.1f}%), "
          f"jain {fifo['jain']:.2f} -> {fair['jain']:.2f}")
    print("wrote " + " and ".join(wrote))
    if not exact:
        raise RuntimeError("a scheduled job diverged from its solo run — "
                           "see per_k.*.policies.*.exact_all")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller fleet / fewer tokens")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, never overwrites the "
                         "committed baseline")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
