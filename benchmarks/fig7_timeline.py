"""Fig 7 — execution timelines under unbalanced work.

Paper: passive-target RMA in real MPI implementations degrades to
active-target-like patterns; adding redundant lock/unlock after each task
("improved" variant) forced progression and bought ≈5%.

TPU adaptation (DESIGN.md §2): XLA's runtime dispatches collectives
eagerly — there is no lazy-progression to force, so the paper's trick is
structurally unnecessary here; the analogue we can measure is forcing a
host sync (block_until_ready) every round, which only *adds* overhead.
We report both timelines (model) and the measured eager-vs-forced-sync
delta (real), recording the adaptation finding.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import (Costs, calibrate, run_py, save_json,
                               simulate)
from repro.data.corpus import imbalance_repeats


def ascii_timeline(timeline: list, P: int, width: int = 72) -> str:
    total = timeline[-1][1]
    rows = []
    for p in range(min(P, 8)):
        cells = []
        for (t0, t1, phase, busy) in timeline:
            n = max(1, round((t1 - t0) / total * width))
            frac = busy[p] / max(t1 - t0, 1e-12)
            ch = {"map": "M", "map+reduce": "O", "shuffle": "S",
                  "reduce": "R", "combine": "C", "drain": "d"}[phase]
            cells.append((ch if frac > 0.66 else
                          ch.lower() if frac > 0.15 else ".") * n)
        rows.append(f"p{p}: " + "".join(cells)[:width + 8])
    return "\n".join(rows)


FORCED_SYNC_CODE = """
import json, time
import numpy as np, jax
from repro.core import JobConfig, submit
from repro.core.usecases import WordCount
from repro.data.corpus import imbalance_repeats, synth_corpus

P, task, VOCAB = 8, 4096, 65536
tokens = synth_corpus({n_tokens}, VOCAB, seed=0)
cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                task_size=task, push_cap=1024, n_procs=P, segment=1)
T = (len(tokens) + task * P - 1) // (task * P)
reps = imbalance_repeats(P, T, mode="unbalanced", hot_factor=8,
                         hot_fraction=0.125)

def run(force_sync):
    handle = submit(cfg, tokens, repeats=reps)
    handle._ensure_segmented()
    jax.block_until_ready(handle.carry)
    t0 = time.perf_counter()
    seg_times = []
    while True:
        more = handle.step()
        if force_sync:
            t_s = time.perf_counter()
            jax.block_until_ready(handle.carry) # the "redundant lock/unlock"
            seg_times.append(time.perf_counter() - t_s)
        if not more:
            break
    handle.result()
    return time.perf_counter() - t0, seg_times

run(False)
t_eager, _ = run(False)
t_forced, segs = run(True)
print(json.dumps(dict(t_eager=t_eager, t_forced=t_forced,
                      delta_pct=100*(t_forced/t_eager-1),
                      seg_times=segs[:32])))
"""


def run(quick: bool = False) -> dict:
    calib = calibrate()
    costs = Costs.from_calibration(calib)
    P, T = 8, 16
    reps = imbalance_repeats(P, T, mode="unbalanced", hot_factor=8,
                             hot_fraction=0.125)
    rec: dict = {}
    for backend in ("2s", "1s"):
        total, tl = simulate(costs, reps, backend, want_timeline=True)
        art = ascii_timeline(tl, P)
        rec[backend] = {"total_s": total, "timeline": tl[:64],
                        "ascii": art}
        print(f"[fig7] {backend} (model, unbalanced, total "
              f"{total*1e3:.1f} ms):\n{art}")
    out = run_py(FORCED_SYNC_CODE.format(
        n_tokens=500_000 if quick else 1_000_000), n_devices=8)
    rec["forced_sync"] = json.loads(out.strip().splitlines()[-1])
    d = rec["forced_sync"]["delta_pct"]
    print(f"[fig7] forced per-round host sync vs eager: {d:+.1f}% "
          f"(paper's lock/unlock trick bought +5% on MPI; XLA dispatch is "
          f"already eager — adaptation finding, DESIGN.md §2)")
    # per-segment times expose the hot-rank bubble (the paper's Fig 7
    # communication-pattern view)
    segs = rec["forced_sync"]["seg_times"]
    if segs:
        print(f"[fig7] measured per-round seconds (first 8): "
              f"{[round(s, 3) for s in segs[:8]]}")
    save_json("fig7_timeline.json", rec)
    return rec


if __name__ == "__main__":
    run()
