"""Fig 13 — elastic recovery: re-mesh a live fleet vs restart it.

The paper's fault-tolerance argument (storage windows, ~4.8% overhead,
Fig 5) covers snapshot *cost*; this benchmark measures what the
snapshots buy when ranks actually die. A K-job fleet runs at P under
``repro.fleet.FleetSupervisor`` three times, with solo-run exactness
baselines for every job:

  * **clean**    — no faults: the supervised wall-time floor;
  * **recover**  — a mid-run kill shrinks the mesh (P -> P_new); every
    job is elastic-restored from its latest fleet snapshot
    (``repro.fleet.remesh``: windows folded with saturating adds,
    checksum-verified, tasks re-bucketized — no job is resubmitted by
    the user) and the fleet finishes on the survivors;
  * **restart**  — same kill, same checkpoint cadence, but the
    snapshots are IGNORED at recovery (``restore_on_remesh=False``):
    every uncollected job restarts FROM SCRATCH on the survivors — the
    recovery discipline a non-elastic framework is reduced to, at
    identical checkpointing cost.

Reported: MTTR (the re-mesh itself: fold + re-bucketize + re-admission),
recovery overhead over clean, restart overhead over clean, and the
recovery-vs-restart win. Engine programs for both mesh sizes are warmed
before any timed campaign, so the numbers isolate the recovery
*mechanism* (state fold + re-executed suffix) from one-time jit cost —
the steady-state story for a long-lived fleet. Exactness is asserted,
not assumed: every job in every campaign must be record-identical to
its solo run, kills included.

Artifacts: ``results/fig13_elastic.json`` + repo-root
``BENCH_elastic.json``.

    PYTHONPATH=src python benchmarks/fig13_elastic.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

try:
    from benchmarks.common import REPO, run_py, save_json
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, run_py, save_json

# Parameters are prepended as plain assignments (P, P_NEW, K, TASK, SEG,
# BASE_TOK, CKPT_EVERY) — no str.format, the code below is brace-heavy.
REAL_CODE = """
import json
import sys
import tempfile
import time
import numpy as np
from repro.core import JobConfig, submit
from repro.core.usecases import Histogram, WordCount
from repro.distributed.mesh import make_mesh
from repro.fleet import FaultEvent, FaultPlan, FleetSupervisor
from repro.ft.elastic import remesh_fleet

VOCAB = 512
rng = np.random.default_rng(13)
USECASES = [WordCount(vocab=VOCAB), Histogram(vocab=VOCAB, n_bins=64)]
# uniform job sizes: every job must still be LIVE at the mid-run kill,
# so the recover/restart arms compare on identical uncollected sets
jobs = {}
for k in range(K):
    jobs[f"job-{k}"] = (USECASES[k % len(USECASES)],
                        rng.integers(0, VOCAB, size=BASE_TOK)
                        .astype(np.int32))

_t0 = time.perf_counter()


def stage(msg):
    print(f"[{time.perf_counter() - _t0:7.1f}s] {msg}",
          file=sys.stderr, flush=True)


def cfg(uc, P_run):
    return JobConfig(usecase=uc, backend="1s", task_size=TASK,
                     push_cap=256, segment=SEG, n_procs=P_run)


# solo exactness baselines + engine warm-up for BOTH mesh sizes (the
# campaigns then measure the recovery mechanism, not one-time jit)
solo = {}
for P_run in (P, P_NEW):
    mesh = make_mesh(remesh_fleet(P_run))
    for name, (uc, toks) in jobs.items():
        res = submit(cfg(uc, P_run), toks, mesh=mesh).result()
        if P_run == P:
            solo[name] = res.records
        stage(f"solo {name} @P={P_run}")

kill_ranks = tuple(range(P - P_NEW))


# warm the remesh path itself (fold programs for every table width,
# snapshot save/restore) with a throwaway killed mini-fleet, so the
# timed campaigns see steady-state recovery cost, not first-call jit
with tempfile.TemporaryDirectory() as d:
    warm = FleetSupervisor(
        n_procs=P, ckpt_dir=d, ckpt_every=1, slices_per_tick=1,
        plan=FaultPlan((FaultEvent(2, "kill", ranks=kill_ranks),)))
    for name, (uc, toks) in jobs.items():
        warm.submit(cfg(uc, P), toks[:TASK * P * SEG * 4], name=name)
    warm.run(max_ticks=100000)
    warm.close()
    assert not warm.failed and warm.recoveries, "warm-up fleet broke"
stage("warm-up kill fleet")


def campaign(ckpt_every, kill_tick=None, restore=True):
    events = []
    if kill_tick is not None:
        events.append(FaultEvent(kill_tick, "kill", ranks=kill_ranks))
    with tempfile.TemporaryDirectory() as d:
        sup = FleetSupervisor(n_procs=P, ckpt_dir=d,
                              plan=FaultPlan(tuple(events)),
                              ckpt_every=ckpt_every, slices_per_tick=4,
                              restore_on_remesh=restore)
        for name, (uc, toks) in jobs.items():
            sup.submit(cfg(uc, P), toks, name=name)
        t0 = time.perf_counter()
        res = sup.run(max_ticks=100000)
        wall = time.perf_counter() - t0
        sup.close()
    assert not sup.failed, sup.failed
    stage(f"campaign ckpt={ckpt_every} kill={kill_tick} "
          f"restore={restore}: {wall:.2f}s, {sup.ticks_run} ticks")
    exact = all(res[n].records == solo[n] for n in jobs)
    return dict(
        wall_s=wall, ticks=sup.ticks_run, exact=bool(exact),
        final_p=sup.n_procs,
        recoveries=[dict(tick=r.tick, p_old=r.p_old, p_new=r.p_new,
                         seconds=r.seconds, restored=r.jobs_restored,
                         scratch=r.jobs_scratch)
                    for r in sup.recoveries])


clean = campaign(ckpt_every=CKPT_EVERY)
# kill at 2/3 of the clean run: late enough that the restart arm's
# redone prefix dwarfs single-core scheduler noise, with snapshots
# guaranteed to exist (ckpt_every ticks have long passed)
kill_tick = max(2, 2 * clean["ticks"] // 3)
recover = campaign(ckpt_every=CKPT_EVERY, kill_tick=kill_tick)
# control arm: identical checkpoint cadence, but snapshots are IGNORED
# at recovery — every job restarts from scratch on the survivors
restart = campaign(ckpt_every=CKPT_EVERY, kill_tick=kill_tick,
                   restore=False)
assert recover["final_p"] == P_NEW and restart["final_p"] == P_NEW
print(json.dumps(dict(clean=clean, recover=recover, restart=restart,
                      kill_tick=kill_tick)))
"""


def measure_real(n_procs: int, p_new: int, k: int, task: int, seg: int,
                 base_tok: int, ckpt_every: int) -> dict:
    params = (f"P={n_procs}\nP_NEW={p_new}\nK={k}\nTASK={task}\n"
              f"SEG={seg}\nBASE_TOK={base_tok}\nCKPT_EVERY={ckpt_every}\n")
    out = run_py(params + REAL_CODE, n_devices=n_procs)
    return json.loads(out.strip().splitlines()[-1])


def run(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        n_procs, p_new, k, task, base_tok = 2, 1, 4, 64, 49_152
    elif quick:
        n_procs, p_new, k, task, base_tok = 4, 3, 4, 64, 32_768
    else:
        # per-job tokens are capped well under the empirical boundary
        # (~86k at P=6) where XLA's in-process CPU collectives on a
        # SUBSET mesh of the forced host devices can deadlock at an
        # all_to_all rendezvous on an oversubscribed single core — a
        # host-emulation artifact, not an engine property (P=4 and P=8
        # run the same sizes fine, and fleetlint proves collective
        # uniformity for these programs)
        n_procs, p_new, k, task, base_tok = 8, 6, 4, 64, 49_152
    seg, ckpt_every = 4, 2

    print(f"[fig13] elastic campaigns (P={n_procs} -> {p_new}, K={k}, "
          f"{base_tok} base tokens)...")
    real = measure_real(n_procs, p_new, k, task, seg, base_tok,
                        ckpt_every)

    clean, recover, restart = (real["clean"], real["recover"],
                               real["restart"])
    mttr = float(sum(r["seconds"] for r in recover["recoveries"]))
    rec_over = 100.0 * (recover["wall_s"] / clean["wall_s"] - 1)
    res_over = 100.0 * (restart["wall_s"] / clean["wall_s"] - 1)
    win = 100.0 * (1 - recover["wall_s"] / restart["wall_s"])
    restored = sum(r["restored"] for r in recover["recoveries"])
    rec = {
        "P": n_procs, "P_new": p_new, "K": k,
        "kill_tick": real["kill_tick"],
        "clean": clean, "recover": recover, "restart": restart,
        "criteria": {
            # measured, not assumed: every job in every campaign —
            # clean, killed+recovered, killed+restarted — matched its
            # solo records exactly
            "records_equal": bool(clean["exact"] and recover["exact"]
                                  and restart["exact"]),
            # the kill was survived WITHOUT resubmission: every
            # uncollected job came back via elastic restore
            "all_jobs_elastic_restored": bool(
                restored > 0
                and all(r["scratch"] == 0
                        for r in recover["recoveries"])),
            "mttr_s": mttr,
            "recovery_overhead_pct": rec_over,
            "restart_overhead_pct": res_over,
            "recovery_win_vs_restart_pct": win,
            # the point of the subsystem: folding snapshots onto the
            # survivors must beat re-running the fleet from scratch
            "recovery_beats_restart": bool(
                recover["wall_s"] < restart["wall_s"]),
        },
    }
    path = save_json("fig13_elastic.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        root = os.path.join(REPO, "BENCH_elastic.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    print(f"[fig13] P {n_procs}->{p_new}: MTTR {mttr:.2f}s, recovery "
          f"{rec_over:+.1f}% vs clean (restart {res_over:+.1f}%), "
          f"win vs restart {win:+.1f}%")
    print("wrote " + " and ".join(wrote))
    if not rec["criteria"]["records_equal"]:
        raise RuntimeError("a supervised job diverged from its solo run "
                           "— elastic recovery is NOT exact")
    if not rec["criteria"]["recovery_beats_restart"]:
        raise RuntimeError(
            f"elastic recovery ({recover['wall_s']:.2f}s) did not beat "
            f"restart-from-scratch ({restart['wall_s']:.2f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller fleet / fewer tokens")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, never overwrites the "
                         "committed baseline")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
