"""Fig 4 — strong/weak scaling, balanced vs unbalanced, MR-1S vs MR-2S.

Paper numbers to reproduce (Tegner, PUMA-Wikipedia):
  4a strong/balanced:    MR-1S ≈ +4.8% at ≤64 procs, loses at 256
  4b weak/balanced:      ≈0.5% apart
  4c strong/unbalanced:  MR-1S ≈ +20.4% average
  4d weak/unbalanced:    MR-1S ≈ +23.1% average, peak 33.9%

Output per cell: calibrated-model times at the paper's process counts +
real wall-times at P=2..8 (single-core caveat in common.py).
"""
from __future__ import annotations


import numpy as np

from benchmarks.common import Costs, calibrate, run_py, save_json, simulate
from repro.data.corpus import imbalance_repeats

PAPER_PROCS = [16, 32, 64, 128, 256]
HOT_FACTOR = 8           # hot ranks compute each task 8x (paper footnote 5)
HOT_FRACTION = 0.125


REAL_CODE = """
import json, time
import numpy as np
from repro.core import JobConfig, submit
from repro.core.usecases import WordCount
from repro.data.corpus import imbalance_repeats, synth_corpus

P = {n_procs}
N = {n_tokens}
VOCAB = 65536
task = 4096
tokens = synth_corpus(N, VOCAB, seed=0)
from repro.core.planner import plan_input
T = plan_input(N, task, P).tasks_per_proc
reps = imbalance_repeats(P, T, mode={mode!r}, hot_factor=8,
                         hot_fraction=0.125)
out = {{}}
for backend in ("1s", "2s"):
    cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                    task_size=task, push_cap=1024, n_procs=P)
    submit(cfg, tokens, repeats=reps).result()   # compile + correctness
    out[backend] = submit(cfg, tokens, repeats=reps).result().wall_time
print(json.dumps(out))
"""


def real_times(n_procs: int, n_tokens: int, mode: str) -> dict[str, float]:
    import json
    out = run_py(REAL_CODE.format(n_procs=n_procs, n_tokens=n_tokens,
                                  mode=mode), n_devices=n_procs)
    return json.loads(out.strip().splitlines()[-1])


def model_row(costs: Costs, P: int, T: int, mode: str) -> dict:
    reps = imbalance_repeats(P, T, mode=mode, hot_factor=HOT_FACTOR,
                             hot_fraction=HOT_FRACTION)
    t2 = simulate(costs, reps, "2s")
    t1 = simulate(costs, reps, "1s")
    return {"P": P, "T": T, "mode": mode, "t_2s": t2, "t_1s": t1,
            "improvement_pct": 100 * (1 - t1 / t2)}


def run(quick: bool = False) -> dict:
    print("[fig4] calibrating per-op costs...")
    calib = calibrate()
    costs_cpu = Costs.from_calibration(calib)
    rec: dict = {"calibration": calib, "model": {}, "real": {},
                 "tpu_projection": {}}

    # --- calibrated model at the paper's scales -------------------------
    T_STRONG = 512                      # fixed dataset: tasks shrink with P
    for fig, mode, weak in (("4a", "balanced", False),
                            ("4b", "balanced", True),
                            ("4c", "unbalanced", False),
                            ("4d", "unbalanced", True)):
        rows: list[dict] = []
        for P in PAPER_PROCS:
            T = 32 if weak else max(2, T_STRONG // P)
            rows.append(model_row(costs_cpu, P, T, mode))
        rec["model"][fig] = rows
        avg = float(np.mean([r["improvement_pct"] for r in rows]))
        peak = float(np.max([r["improvement_pct"] for r in rows]))
        rec["model"][fig + "_summary"] = {"avg_pct": avg, "peak_pct": peak}
        print(f"[fig4] {fig} ({mode}, {'weak' if weak else 'strong'}): "
              f"model avg {avg:+.1f}% peak {peak:+.1f}%")

    # --- TPU-parameterized projection (v5e constants) --------------------
    for fig, mode, _weak in (("4b", "balanced", True),
                             ("4d", "unbalanced", True)):
        rows = []
        for P in PAPER_PROCS:
            c = Costs.tpu_like(n_procs=P)
            T = 32
            reps = imbalance_repeats(P, T, mode=mode, hot_factor=HOT_FACTOR,
                                     hot_fraction=HOT_FRACTION)
            rows.append({"P": P,
                         "improvement_pct": 100 * (
                             1 - simulate(c, reps, "1s")
                             / simulate(c, reps, "2s"))})
        rec["tpu_projection"][fig] = rows

    # --- win vs imbalance degree (the mechanism, isolated) ----------------
    for mode in ("unbalanced", "random"):
        rows = []
        for hf in (1, 2, 4, 8, 16):
            reps = imbalance_repeats(64, 32, mode=mode, hot_factor=hf,
                                     hot_fraction=HOT_FRACTION, seed=1)
            t2 = simulate(costs_cpu, reps, "2s")
            t1 = simulate(costs_cpu, reps, "1s")
            rows.append({"hot_factor": hf,
                         "improvement_pct": 100 * (1 - t1 / t2)})
        rec["model"][f"win_vs_imbalance_{mode}"] = rows
        print(f"[fig4] win vs hot_factor ({mode}):",
              [(r["hot_factor"], round(r["improvement_pct"], 1))
               for r in rows])

    # --- real wall-times (small P; single-core caveat) -------------------
    procs = [2, 4, 8] if not quick else [4]
    n_tok = 2_000_000 if not quick else 500_000
    for mode in ("balanced", "unbalanced"):
        rows = []
        for P in procs:
            t = real_times(P, n_tok, mode)
            rows.append({"P": P, **t,
                         "improvement_pct": 100 * (1 - t["1s"] / t["2s"])})
            print(f"[fig4] real P={P} {mode}: 2s={t['2s']:.2f}s "
                  f"1s={t['1s']:.2f}s ({rows[-1]['improvement_pct']:+.1f}%)")
        rec["real"][mode] = rows

    save_json("fig4_scaling.json", rec)
    return rec


if __name__ == "__main__":
    run()
