"""Benchmark harness entry point — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]

Outputs land in results/*.json; the console shows the paper-comparison
summaries EXPERIMENTS.md quotes.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets / fewer repetitions")
    ap.add_argument("--only", default="",
                    help="comma list: fig4,fig5,fig6,fig7,fig8,fig9,fig10,"
                         "fig11,fig13,roofline")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))

    from benchmarks import (fig4_scaling, fig5_ckpt, fig6_memory,
                            fig7_timeline, fig8_io_overlap, fig9_imbalance,
                            fig10_keyskew, fig11_multitenant,
                            fig13_elastic, moe_dispatch_bench, roofline)
    benches = [("fig4", fig4_scaling.run), ("fig5", fig5_ckpt.run),
               ("fig6", fig6_memory.run), ("fig7", fig7_timeline.run),
               ("fig8", fig8_io_overlap.run),
               ("fig9", fig9_imbalance.run),
               ("fig10", fig10_keyskew.run),
               ("fig11", fig11_multitenant.run),
               ("fig13", fig13_elastic.run),
               ("moe", moe_dispatch_bench.run),
               ("roofline", lambda quick: roofline.run(quick=quick))]
    failed = []
    for name, fn in benches:
        if only and name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn(quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)
    print("\nall benchmarks complete — results/*.json")


if __name__ == "__main__":
    main()
