"""Benchmark harness entry point — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig4,...]
    PYTHONPATH=src python -m benchmarks.run --smoke-all [--group bench]
    PYTHONPATH=src python -m benchmarks.run --quick-all

``REGISTRY`` below is the single list CI consumes: ``--smoke-all`` runs
every registered smoke-capable benchmark in a group and verifies each
one actually wrote a non-empty ``results/*.json`` artifact, so adding a
figure here (plus its ``check_regression`` entry) wires it into the
workflows with NO workflow edits. Groups keep the chaos benchmark
(fig13, its own CI job) out of the default bench sweep.

Outputs land in results/*.json; the console shows the paper-comparison
summaries EXPERIMENTS.md quotes.
"""
from __future__ import annotations

import argparse
import dataclasses
import importlib
import os
import sys
import time
import traceback


@dataclasses.dataclass(frozen=True)
class Bench:
    """One registered benchmark: resolved lazily so importing this
    module (e.g. from check_regression) stays free of jax state."""
    name: str
    module: str                 # import path holding run(quick[, smoke])
    artifact: str               # filename it writes under results/
    smoke: bool = False         # run(smoke=True) supported (CI-sized)
    group: str = "bench"        # CI job family: "bench" | "chaos"


REGISTRY: tuple[Bench, ...] = (
    Bench("fig4", "benchmarks.fig4_scaling", "fig4_scaling.json"),
    Bench("fig5", "benchmarks.fig5_ckpt", "fig5_ckpt.json"),
    Bench("fig6", "benchmarks.fig6_memory", "fig6_memory.json"),
    Bench("fig7", "benchmarks.fig7_timeline", "fig7_timeline.json"),
    Bench("fig8", "benchmarks.fig8_io_overlap", "fig8_io_overlap.json",
          smoke=True),
    Bench("fig9", "benchmarks.fig9_imbalance", "fig9_imbalance.json",
          smoke=True),
    Bench("fig10", "benchmarks.fig10_keyskew", "fig10_keyskew.json",
          smoke=True),
    Bench("fig11", "benchmarks.fig11_multitenant",
          "fig11_multitenant.json", smoke=True),
    Bench("fig12", "benchmarks.fig12_roofline", "fig12_roofline.json",
          smoke=True),
    Bench("fig13", "benchmarks.fig13_elastic", "fig13_elastic.json",
          smoke=True, group="chaos"),
    Bench("fig14", "benchmarks.fig14_crossjob", "fig14_crossjob.json",
          smoke=True),
    Bench("fig15", "benchmarks.fig15_coded", "fig15_coded.json",
          smoke=True),
    Bench("moe", "benchmarks.moe_dispatch_bench", "moe_dispatch.json"),
    Bench("roofline", "benchmarks.roofline", "roofline.json"),
)


def _run_one(bench: Bench, quick: bool, smoke: bool) -> None:
    fn = importlib.import_module(bench.module).run
    if bench.smoke:
        fn(quick=quick, smoke=smoke)
    else:
        fn(quick=quick)


def _artifact_ok(bench: Bench) -> bool:
    from benchmarks.common import RESULTS
    path = os.path.join(RESULTS, bench.artifact)
    return os.path.isfile(path) and os.path.getsize(path) > 0


def _sweep(benches, quick: bool, smoke: bool) -> list[str]:
    """Run each benchmark and verify its artifact landed non-empty."""
    failed: list[str] = []
    for b in benches:
        print(f"\n===== {b.name} =====")
        t0 = time.time()
        try:
            _run_one(b, quick=quick, smoke=smoke)
            print(f"[{b.name}] done in {time.time()-t0:.1f}s")
        except Exception:
            failed.append(b.name)
            traceback.print_exc()
            continue
        if not _artifact_ok(b):
            failed.append(b.name)
            print(f"[{b.name}] FAIL: results/{b.artifact} missing or "
                  "empty")
    return failed


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller datasets / fewer repetitions")
    ap.add_argument("--only", default="",
                    help="comma list of registered names: "
                         + ",".join(b.name for b in REGISTRY))
    ap.add_argument("--smoke-all", action="store_true",
                    help="CI: every smoke-capable benchmark in --group "
                         "at smoke scale, artifact-checked")
    ap.add_argument("--quick-all", action="store_true",
                    help="nightly: every smoke-capable benchmark (all "
                         "groups) at --quick scale, artifact-checked")
    ap.add_argument("--group", default="bench",
                    choices=["bench", "chaos", "all"],
                    help="which CI job family --smoke-all sweeps")
    args = ap.parse_args(argv)

    if args.smoke_all or args.quick_all:
        if args.quick_all:
            benches = [b for b in REGISTRY if b.smoke]
        else:
            benches = [b for b in REGISTRY if b.smoke and
                       (args.group == "all" or b.group == args.group)]
        failed = _sweep(benches, quick=args.quick_all,
                        smoke=args.smoke_all)
        if failed:
            print(f"\nFAILED: {failed}")
            sys.exit(1)
        print(f"\nall {len(benches)} benchmarks complete — results/*.json")
        return

    only = set(filter(None, args.only.split(",")))
    unknown = only - {b.name for b in REGISTRY}
    if unknown:
        ap.error(f"unknown benchmark names: {sorted(unknown)}")
    benches = [b for b in REGISTRY if not only or b.name in only]
    failed = _sweep(benches, quick=args.quick, smoke=False)
    if failed:
        print(f"\nFAILED: {failed}")
        sys.exit(1)
    print("\nall benchmarks complete — results/*.json")


if __name__ == "__main__":
    main()
