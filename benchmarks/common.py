"""Shared benchmark infrastructure.

Two measurement modes, both honest about this container:

1. **Real runs** — the engines execute end-to-end on 8 host devices and we
   record wall time. On ONE oversubscribed CPU core, device threads are
   work-conserving: a fast rank's idle time is absorbed by the slow rank's
   compute, so phase-overlap gains physically cannot appear in wall time
   here. Real runs therefore validate correctness + schedule overheads.

2. **Calibrated lockstep schedule model** — per-op costs (map at repeat r,
   bucketize, window fold, chunk all_to_all, combine) are *measured* on
   this machine one-at-a-time (no contention), then composed into the exact
   SPMD lockstep makespan of each engine's schedule. This mirrors how the
   TPU executes the same programs (collectives synchronize; XLA overlaps
   async pushes with compute) and is what EXPERIMENTS.md compares against
   the paper's Fig 4. The model also takes TPU-parameterized constants
   (bytes / ICI bw) for the production-scale projections.

Subprocess isolation: every real engine run happens in a fresh process with
its own ``--xla_force_host_platform_device_count`` (the main process never
touches jax device state — same rule as the dry-run).
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
RESULTS = os.path.join(REPO, "results")


def run_py(code: str, n_devices: int = 8, timeout: int = 580) -> str:
    prelude = (f"import os\nos.environ['XLA_FLAGS'] = "
               f"'--xla_force_host_platform_device_count={n_devices}'\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c",
                           prelude + textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-3000:]}")
    return proc.stdout


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def stream_triad_gbps(mb: float = 256.0, reps: int = 5) -> float:
    """Measured machine memory bandwidth, STREAM-triad style.

    ``a = b + s * c`` over preallocated arrays large enough to defeat the
    caches; counts 3 reads + 2 writes per element (numpy materializes the
    multiply into ``a`` first), best-of-``reps``. This is the roofline
    ceiling fig12 states achieved-bandwidth fractions against — measured
    here, on this machine, not quoted from a spec sheet.
    """
    n = int(mb * 2**20 / 8 / 3)          # three resident arrays of float64
    a = np.empty(n)
    b = np.random.default_rng(0).random(n)
    c = np.random.default_rng(1).random(n)
    s = 1.000001
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.multiply(c, s, out=a)
        np.add(a, b, out=a)
        best = min(best, time.perf_counter() - t0)
    return 5 * n * 8 / best / 1e9


# ---------------------------------------------------------------------------
# per-op cost calibration (measured, no contention)
# ---------------------------------------------------------------------------

CALIB_CODE = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from repro.core.kv import (bucketize, local_reduce, local_reduce_repeated,
                           mix32, KEY_SENTINEL)
from repro.core.windows import DenseWindow
from repro.core.usecase import as_map_fn
from repro.core.usecases import WordCount

TASK = {task_size}
P = {n_procs}
CAP = {push_cap}
VOCAB = {vocab}

def timeit(fn, *args, n=20):
    jax.block_until_ready(fn(*args))          # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n

rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, VOCAB, TASK), jnp.int32)
map_fn = as_map_fn(WordCount(vocab=VOCAB))

def make_task(r):
    # the full per-task sender work at repeat r: map + (repeated) local
    # reduce + bucketize — exactly the engines' phase I+II
    @jax.jit
    def f(t):
        keys, vals = map_fn(t, jnp.int32(0), jnp.int32(r))
        uk, uv = local_reduce_repeated(keys, vals, keys.shape[0],
                                       jnp.int32(r))
        return bucketize(uk, uv, P, CAP)
    return f

t_task1 = timeit(make_task(1), toks)
t_task8 = timeit(make_task(8), toks)
t_task_per_rep = max((t_task8 - t_task1) / 7, 0.0)

win = jnp.zeros((VOCAB,), jnp.int32)
ck = jnp.asarray(rng.integers(0, VOCAB, (P, CAP)), jnp.int32)
cv = jnp.ones((P, CAP), jnp.int32)
@jax.jit
def fold(w, k, v):
    return DenseWindow(w).put(k.reshape(-1), v.reshape(-1)).table
t_fold = timeit(fold, win, ck, cv)

# combine: one merge level at window W
W = VOCAB
ka = jnp.sort(jnp.asarray(rng.integers(0, VOCAB, W), jnp.int32))
va = jnp.ones((W,), jnp.int32)
from repro.core.kv import merge_sorted
@jax.jit
def merge(k1, v1, k2, v2):
    return merge_sorted(k1, v1, k2, v2, W)
t_merge = timeit(merge, ka, va, ka, va)

print(json.dumps(dict(t_task1=t_task1, t_task_per_rep=t_task_per_rep,
                      t_fold=t_fold, t_merge=t_merge,
                      chunk_bytes=float(P * CAP * 8))))
"""

A2A_CODE = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import shard_map
from repro.distributed.mesh import local_mesh

n = {n_procs}
CAP = {push_cap}
mesh = local_mesh((n,), ("procs",))

def measure(cap):
    def body(x):
        x = x[0]
        return lax.all_to_all(x, "procs", 0, 0, tiled=False)[None]
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("procs"),),
                           out_specs=P("procs")))
    x = jnp.ones((n, n, cap, 2), jnp.int32)
    jax.block_until_ready(fn(x))
    t0 = time.perf_counter()
    for _ in range(10):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 10

# two sizes -> per-op latency (alpha) + per-chunk-bytes slope (beta):
# the bulk MPI_Alltoallv pays alpha once for T chunks; the chunked
# one-sided pushes pay it every round
t1 = measure(CAP)
t8 = measure(CAP * 8)
beta = max((t8 - t1) / 7, 0.0)
alpha = max(t1 - beta, 0.0)
print(json.dumps(dict(t_a2a=t1, t_a2a_lat=alpha, t_a2a_byte=beta,
                      bytes_per_dev=float(n * CAP * 8))))
"""


def calibrate(task_size=4096, n_procs=8, push_cap=1024, vocab=65536) -> dict:
    out = run_py(CALIB_CODE.format(task_size=task_size, n_procs=n_procs,
                                   push_cap=push_cap, vocab=vocab),
                 n_devices=1)
    costs = json.loads(out.strip().splitlines()[-1])
    out2 = run_py(A2A_CODE.format(n_procs=n_procs, push_cap=push_cap),
                  n_devices=n_procs)
    costs.update(json.loads(out2.strip().splitlines()[-1]))
    return costs


# ---------------------------------------------------------------------------
# lockstep schedule simulator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Costs:
    """Per-op seconds. Build from ``calibrate()`` (CPU) or TPU constants."""
    t_task1: float           # full per-task sender work at repeat=1
                             #   (map + local reduce + bucketize)
    t_task_per_rep: float    # extra seconds per compute-repeat
    t_fold: float            # fold one (P, cap) chunk into the window
    t_merge: float           # one combine merge level
    t_a2a_lat: float         # all_to_all per-op latency (alpha)
    t_a2a_byte: float        # all_to_all per-chunk transfer time (beta)
    comm_overlap: bool = True   # async collectives overlap compute (TPU)
    t_io: float = 0.0        # input retrieval per task (paper: dominates);
                             #   prefetched → overlaps compute in BOTH
                             #   engines, so it adds as max(io, compute)
    t_fetch: float = 0.0     # 1s+steal only: the per-step task-fetch
                             #   all_to_all (a claimed task's input is
                             #   served by global id before map can run,
                             #   so it sits ON the critical path — the
                             #   steal scheduler's honest overhead)

    def task_time(self, rep: np.ndarray) -> np.ndarray:
        comp = self.t_task1 + self.t_task_per_rep * np.maximum(rep - 1, 0)
        return np.maximum(comp, self.t_io)

    @property
    def t_a2a_chunk(self) -> float:
        return self.t_a2a_lat + self.t_a2a_byte

    def t_a2a_bulk(self, T: int) -> float:
        """MPI_Alltoallv of T chunks: latency paid once (the collective's
        efficiency edge the paper observes on balanced / large-P runs)."""
        return self.t_a2a_lat + self.t_a2a_byte * T

    @staticmethod
    def from_calibration(c: dict, comm_overlap=True, t_io=0.0) -> Costs:
        return Costs(c["t_task1"], c["t_task_per_rep"], c["t_fold"],
                     c["t_merge"], c["t_a2a_lat"], c["t_a2a_byte"],
                     comm_overlap=comm_overlap, t_io=t_io)

    @staticmethod
    def tpu_like(task_mb=64.0, push_cap=1024, n_procs=256,
                 comm_overlap=True, storage_gbps=2.0) -> Costs:
        """First-principles v5e-flavoured constants (DESIGN.md §9): task
        compute is memory-bound over the task bytes; input retrieval from
        parallel storage at ``storage_gbps``/rank dominates (the paper's
        word-count regime: "execution mostly depends on the time required
        to retrieve the input"); chunk a2a over 50 GB/s ICI links."""
        hbm = 819e9
        link = 50e9
        task_bytes = task_mb * 2 ** 20
        chunk_bytes = n_procs * push_cap * 8
        return Costs(
            t_task1=task_bytes * 9 / hbm,        # hash + sort passes
            t_task_per_rep=task_bytes * 7 / hbm,
            t_fold=chunk_bytes * 2 / hbm,
            t_merge=chunk_bytes * 2 / hbm,
            t_a2a_lat=5e-6,
            t_a2a_byte=chunk_bytes / link,
            comm_overlap=comm_overlap,
            t_io=task_bytes / (storage_gbps * 1e9))


def simulate(costs: Costs, repeats: np.ndarray, backend: str,
             want_timeline: bool = False):
    """Exact lockstep makespan of one engine schedule.

    repeats: (P, T) compute-repeat factors. Returns seconds
    (+ optional per-round timeline [(t0, t1, phase, per_proc_busy)]).
    """
    P, T = repeats.shape
    mt = costs.task_time(repeats)                 # (P, T)
    n_levels = int(np.ceil(np.log2(max(P, 2))))
    timeline: list = []
    t = 0.0

    def round_(dur: float, phase: str, busy):
        nonlocal t
        if want_timeline:
            timeline.append((t, t + dur, phase, np.asarray(busy).tolist()))
        t += dur

    if backend == "2s":
        # 2S's map scan has NO collectives — devices run their whole task
        # list decoupled and sync only at the bulk a2a: the map phase is
        # max_p(Σ_t), not Σ_t max_p. (Equal for rank-hot imbalance;
        # kinder to 2S under random task-level imbalance.)
        per_proc = mt.sum(axis=1)
        round_(float(per_proc.max()), "map", per_proc)
        # bulk shuffle (T chunks of bytes in one fused a2a — latency
        # amortized, the collective's edge), then the reduce spike (fold T
        # chunks), then combine
        round_(costs.t_a2a_bulk(T), "shuffle",
               np.full(P, costs.t_a2a_bulk(T)))
        round_(costs.t_fold * T, "reduce", np.full(P, costs.t_fold * T))
        round_(costs.t_merge * n_levels, "combine",
               np.full(P, costs.t_merge * n_levels))
    elif backend in ("1s", "1s+steal"):
        # chunked push: fold of chunk k-1 overlaps the push of chunk k;
        # the a2a itself overlaps next round's compute when async — but
        # pays its latency every round (1S's downside on small tasks).
        # With stealing, the per-step schedule is the one the claim
        # function actually realizes (heavy tasks migrate to ranks that
        # ran ahead, packing them into the same lockstep rounds), and
        # every round additionally pays the task-fetch a2a up front.
        if backend == "1s+steal":
            from repro.core.steal import steal_schedule
            ids = np.arange(repeats.size, dtype=np.int32).reshape(P, T)
            mt = costs.task_time(steal_schedule(ids, repeats).exec_reps)
        for k in range(T):
            busy = mt[:, k] + costs.t_fold
            comp = busy.max()
            dur = max(comp, costs.t_a2a_chunk) if costs.comm_overlap \
                else comp + costs.t_a2a_chunk
            if backend == "1s+steal":
                dur += costs.t_fetch
            round_(dur, "map+reduce", busy)
        round_(costs.t_fold, "drain", np.full(P, costs.t_fold))
        round_(costs.t_merge * n_levels, "combine",
               np.full(P, costs.t_merge * n_levels))
    else:
        raise ValueError(backend)
    return (t, timeline) if want_timeline else t


def speedup(costs: Costs, repeats: np.ndarray) -> dict[str, float]:
    t2 = simulate(costs, repeats, "2s")
    t1 = simulate(costs, repeats, "1s")
    return {"t_2s": t2, "t_1s": t1, "improvement_pct": 100 * (1 - t1 / t2)}
