"""Fig 12 — roofline of the fused map→bucketize→combine hot path.

PR 8 fused the 1S engine's per-step inner loop (local reduce, owner
lookup, bucketize, both window folds) into one pallas kernel
(``kernels/fused_map``) that streams the dense Key-Value window — the
*window* IS the vocab axis here — through VMEM exactly once per step,
where the unfused path materializes it twice (pending fold + overflow
fold). This benchmark states that win the roofline way: bytes moved per
step, divided by the *measured* machine bandwidth, against the
*measured* per-step wall time.

Methodology (the repo's two honest modes, common.py):

  * **measured** — the unfused step composition and the fused kernel are
    timed standalone per vocab size on one host device. On CPU the fused
    kernel runs in pallas interpret mode, which adds executor overhead a
    real TPU does not pay — so measured fused wall is recorded (and must
    stay sane) but the headline is NOT an interpret-wall race;
  * **modeled** — per-step HBM bytes for each path (two window passes vs
    one, plus record-domain terms) over the STREAM-triad bandwidth
    measured on this machine (``common.stream_triad_gbps``). The
    falsifiable gate: the fused path's *modeled* step time must beat the
    unfused path's *measured* step time at the largest window — the
    model is only allowed to claim a win that clears real, measured
    wall time, not another model;
  * **real runs** — full engine jobs for {unfused, fused} x
    {hash, sampled+split} per vocab must stay record-identical to the
    unfused/hash baseline AND the numpy oracle (the kernel's exactness
    contract, live-checked every CI run).

Artifacts: ``results/fig12_roofline.json`` + repo-root
``BENCH_roofline.json``.

    PYTHONPATH=src python benchmarks/fig12_roofline.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

try:
    from benchmarks.common import REPO, run_py, save_json, stream_triad_gbps
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, run_py, save_json, stream_triad_gbps

VOCABS = [16384, 65536, 262144]          # dense window sizes swept
TASK_SIZE = 256                          # records per map task (S)
PUSH_CAP = 64                            # per-owner push-bucket capacity
N_PROCS = 4
ZIPF_A = 1.4                             # real-run key distribution

STEP_CODE = """
import functools, json, time
import numpy as np, jax, jax.numpy as jnp
from repro.core.kv import bucketize, local_reduce_repeated
from repro.core.partition import lookup_owner
from repro.core.windows import DenseWindow
from repro.kernels.fused_map.ops import fused_map_step

P, CAP, S = {n_procs}, {push_cap}, {task_size}

def timeit(fn, *args, n={timing_reps}):
    jax.block_until_ready(fn(*args))              # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n

rng = np.random.default_rng(0)
out = {{}}
for V in {vocabs}:
    keys = jnp.asarray(rng.integers(0, V, S), jnp.int32)
    vals = jnp.ones((S,), jnp.int32)
    omap = jnp.asarray(np.arange(V) % P, jnp.int32)
    osplit = jnp.ones((V,), jnp.int32)
    pk = jnp.asarray(rng.integers(0, V, (P, CAP)), jnp.int32)
    pv = jnp.ones((P, CAP), jnp.int32)
    tbl = jnp.zeros((V,), jnp.int32)

    # the exact phase II+III body of onesided._step, minus the a2a (the
    # push is identical in both paths, so it cancels out of the race)
    @jax.jit
    def unfused(keys, vals, omap, osplit, pk, pv, tbl):
        uk, uv = local_reduce_repeated(keys, vals, keys.shape[0],
                                       jnp.int32(1))
        owners = lookup_owner(omap, osplit, uk, jnp.int32(0), P)
        bk, bv, counts, (ofk, ofv) = bucketize(uk, uv, P, CAP,
                                               owners=owners)
        win = DenseWindow(tbl).put(pk.reshape(-1),
                                   pv.reshape(-1)).put(ofk, ofv)
        return win.table, bk, bv, counts

    fused = functools.partial(fused_map_step, n_procs=P, cap=CAP)
    t_un = timeit(unfused, keys, vals, omap, osplit, pk, pv, tbl)
    t_fu = timeit(fused, keys, vals, jnp.int32(1), jnp.int32(0),
                  omap, osplit, pk, pv, tbl)
    out[str(V)] = dict(unfused_step_s=t_un, fused_step_s=t_fu)
print(json.dumps(out))
"""

REAL_CODE = """
import json
from repro.core import JobConfig, submit
from repro.core.usecases import WordCount, wordcount_oracle
from repro.data.source import ZipfSource, read_all

P, N, TASK, CAP = {n_procs}, {n_tokens}, {task_size}, {push_cap}
PARTS = ["hash", "sampled+split"]
out = {{}}
for V in {vocabs}:
    src = ZipfSource(N, vocab=V, a={zipf_a}, seed=2)
    oracle = wordcount_oracle(read_all(src), V)
    row = {{}}
    base = None
    for fused in (False, True):
        for part in PARTS:
            cfg = JobConfig(usecase=WordCount(vocab=V), backend="1s",
                            task_size=TASK, push_cap=CAP, n_procs=P,
                            fused_map=fused, partitioner=part)
            submit(cfg, src).result()             # compile + warm
            walls = []
            for _ in range({reps_n}):
                res = submit(cfg, src).result()
                walls.append(res.wall_time)
            if base is None:
                base = res.records
            # recorded, not asserted: the artifact carries the live
            # outcome so bench-guard's records_equal gate is a real check
            tag = ("fused" if fused else "unfused") + "|" + part
            row[tag] = dict(wall_s=min(walls),
                            records_equal=bool(res.records == base),
                            oracle_equal=bool(res.records == oracle))
    out[str(V)] = row
print(json.dumps(out))
"""


def bytes_moved(V: int, S: int, P: int, cap: int) -> tuple[float, float]:
    """Per-step HBM bytes for the unfused and fused hot paths.

    Every table entry is int32 (4 bytes); a full window pass reads and
    writes each entry once (8 bytes/entry). The unfused path makes TWO
    passes per step — XLA materializes a fresh (V,) table per fold, once
    for the pending chunk and once for the overflow records — while the
    fused kernel makes ONE (both folds land in the same VMEM-resident
    tile). Record-domain terms: the unfused path runs three sort-based
    passes over the (S,) records (local_reduce's argsort + bucketize's
    two), each touching ~S*8 bytes per comparator level; the fused path
    keeps the record pass in VMEM and pays the two owner-map gathers at
    a cacheline per probe, plus the record/bucket streams themselves.
    """
    lg = max(int(np.ceil(np.log2(max(S, 2)))), 1)
    table_pass = 8.0 * V                  # read + write, 4B entries
    rec_stream = 8.0 * S                  # one (keys, vals) record stream
    unfused = (2 * table_pass             # pending fold + overflow fold
               + 3 * rec_stream * lg      # local_reduce + 2 bucketize sorts
               + 4 * rec_stream)          # map out / reduce in / buckets
    fused = (table_pass                   # the single window pass
             + 2 * 64.0 * S               # owner_map/owner_split gathers
             + 2 * rec_stream             # records in, buckets out
             + 8.0 * P * cap)             # pending chunk read
    return unfused, fused


def measure_steps(vocabs, task_size: int, n_procs: int, push_cap: int,
                  timing_reps: int) -> dict:
    out = run_py(STEP_CODE.format(n_procs=n_procs, push_cap=push_cap,
                                  task_size=task_size, vocabs=list(vocabs),
                                  timing_reps=timing_reps),
                 n_devices=1)
    return json.loads(out.strip().splitlines()[-1])


def measure_real(vocabs, n_procs: int, n_tokens: int, task_size: int,
                 push_cap: int, reps_n: int) -> dict:
    out = run_py(REAL_CODE.format(n_procs=n_procs, n_tokens=n_tokens,
                                  task_size=task_size, push_cap=push_cap,
                                  vocabs=list(vocabs), zipf_a=ZIPF_A,
                                  reps_n=reps_n),
                 n_devices=n_procs)
    return json.loads(out.strip().splitlines()[-1])


def run(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        vocabs = [65536]
        timing_reps, real_p, real_n, reps_n = 3, 2, 4096, 1
    elif quick:
        vocabs = VOCABS[:2]
        timing_reps, real_p, real_n, reps_n = 5, 4, 16384, 2
    else:
        vocabs = VOCABS
        timing_reps, real_p, real_n, reps_n = 10, N_PROCS, 32768, 3

    bw = stream_triad_gbps()
    print(f"[fig12] STREAM triad bandwidth: {bw:.1f} GB/s")

    print("[fig12] measuring per-step walls (1 device)...")
    steps = measure_steps(vocabs, TASK_SIZE, N_PROCS, PUSH_CAP,
                          timing_reps)
    rows = []
    for V in vocabs:
        m = steps[str(V)]
        b_un, b_fu = bytes_moved(V, TASK_SIZE, N_PROCS, PUSH_CAP)
        row = dict(
            vocab=V,
            unfused_step_s=m["unfused_step_s"],
            fused_step_s=m["fused_step_s"],
            bytes_unfused=b_un, bytes_fused=b_fu,
            model_unfused_s=b_un / (bw * 1e9),
            model_fused_s=b_fu / (bw * 1e9),
            # achieved fraction of the triad roofline: modeled bytes over
            # measured wall, normalized by measured bandwidth
            achieved_bw_frac_unfused=b_un / m["unfused_step_s"] / (bw * 1e9),
            achieved_bw_frac_fused=b_fu / m["fused_step_s"] / (bw * 1e9),
            measured_ratio_fused_vs_unfused=(m["fused_step_s"]
                                             / m["unfused_step_s"]),
        )
        rows.append(row)
        print(f"[fig12] V={V:<7} unfused={row['unfused_step_s']*1e3:.3f}ms "
              f"fused={row['fused_step_s']*1e3:.3f}ms "
              f"(model {row['model_unfused_s']*1e3:.3f} / "
              f"{row['model_fused_s']*1e3:.3f}ms, fused achieves "
              f"{100*row['achieved_bw_frac_fused']:.1f}% of triad bw)")

    print(f"[fig12] real runs (P={real_p}, N={real_n})...")
    real = measure_real(vocabs, real_p, real_n, TASK_SIZE, PUSH_CAP,
                        reps_n)
    rec_eq = all(b["records_equal"] for v in real.values()
                 for b in v.values())
    ora_eq = all(b["oracle_equal"] for v in real.values()
                 for b in v.values())

    top = rows[-1]
    rec = {
        "vocabs": list(vocabs), "task_size": TASK_SIZE,
        "push_cap": PUSH_CAP, "n_procs": N_PROCS,
        "triad_gbps": bw,
        "model": {"rows": rows},
        "real": {"P": real_p, "n_tokens": real_n, "per_vocab": real},
        # interpret-mode honesty: the measured fused wall includes the
        # pallas interpreter's executor overhead (absent on a real TPU),
        # so the measured ratio is recorded as a sanity bound, never as
        # the headline win — that is the model's job (common.py mode 2)
        "measured_ratio_note": "fused_step_s runs in pallas interpret "
                               "mode on CPU; the headline gate is "
                               "model_fused_s vs unfused_step_s",
        "criteria": {
            # the falsifiable headline: the fused path's modeled step
            # time (bytes over *measured* triad bandwidth) must clear the
            # unfused path's *measured* wall at the largest window
            "fused_model_beats_unfused_measured_at_max": bool(
                top["model_fused_s"] < top["unfused_step_s"]),
            # the structural win the kernel exists for: one window pass
            # instead of two -> just under half the bytes at large V
            "fused_bytes_win_pct_at_max": 100.0 * (
                1 - top["bytes_fused"] / top["bytes_unfused"]),
            # the fused kernel must actually move its modeled bytes at a
            # sane fraction of the machine's bandwidth, interpret
            # overhead included (absolute floor in bench-guard)
            "achieved_bw_frac_fused_at_max": top["achieved_bw_frac_fused"],
            "measured_ratio_fused_vs_unfused_at_max": top[
                "measured_ratio_fused_vs_unfused"],
            # exactness, live-checked on real engine runs: every
            # {unfused, fused} x {hash, sampled+split} config identical
            # to the unfused/hash baseline and to the numpy oracle
            "records_equal": rec_eq,
            "oracle_exact": ora_eq,
        },
    }
    path = save_json("fig12_roofline.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        # — CI-scale smoke runs must never clobber it (fig9/fig10 rule)
        root = os.path.join(REPO, "BENCH_roofline.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    c = rec["criteria"]
    print(f"[fig12] at V={top['vocab']}: fused moves "
          f"{c['fused_bytes_win_pct_at_max']:.1f}% fewer bytes "
          f"(model {top['model_fused_s']*1e3:.3f}ms vs measured unfused "
          f"{top['unfused_step_s']*1e3:.3f}ms), records_equal={rec_eq}")
    print("wrote " + " and ".join(wrote))
    if not (rec_eq and ora_eq):
        raise RuntimeError("fused path diverged from the unfused engine "
                           "— see real.per_vocab records_equal/"
                           "oracle_equal flags")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="two window sizes / fewer repetitions")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, still writes results/*.json")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
