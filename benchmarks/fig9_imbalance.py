"""Fig 9 — skewed workloads: 2S vs 1S vs 1S + device-side work stealing.

The paper's headline claim is that the decoupled strategy wins "up to
23%" exactly when per-process workloads are unexpectedly unbalanced;
Fan et al. (arXiv:1401.0355) identify key-distribution skew as the
realistic adversary. This benchmark builds that adversary — a fixed
compute budget concentrated over ranks by a Zipf law with exponent
``s`` (``repro.data.corpus.zipf_skew_repeats``) — and sweeps it across
three schedules:

  * ``2s``        — bulk-synchronous: the hot rank gates the barrier;
  * ``1s``        — decoupled: reduce work overlaps the map timeline,
                    but each rank still owns its assigned tasks;
  * ``1s+steal``  — decoupled + in-scan work stealing
                    (``JobConfig(stealing=True)``, core/steal.py):
                    ranks that ran ahead claim the hot rank's unstarted
                    tail, so the hot tasks pack into shared lockstep
                    rounds instead of serializing on one rank.

Methodology mirrors fig4 (see benchmarks/common.py): **real runs** on
host devices validate exactness (all three schedules must produce
identical records) and measure the steal machinery's overhead, while
the **calibrated lockstep model** — fed the schedule the claim function
actually realizes — produces the makespans at paper scales. The steal
model honestly charges the per-step task-fetch all_to_all on the
critical path.

Artifacts: ``results/fig9_imbalance.json`` + repo-root
``BENCH_imbalance.json``.

    PYTHONPATH=src python benchmarks/fig9_imbalance.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

try:
    from benchmarks.common import (REPO, Costs, calibrate, run_py,
                                   save_json, simulate)
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, Costs, calibrate, run_py, save_json, simulate

SKEWS = [0.0, 0.6, 1.1, 1.6]
MEAN_REP = 4
TASK_SIZE = 4096                 # shared by calibration, model and real runs
PUSH_CAP = 1024

REAL_CODE = """
import json, time
import numpy as np
from repro.core import JobConfig, submit
from repro.core.planner import plan_input
from repro.core.usecases import WordCount
from repro.data.corpus import synth_corpus, zipf_skew_repeats

P, N, VOCAB, task, CAP = {n_procs}, {n_tokens}, 65536, {task_size}, {push_cap}
tokens = synth_corpus(N, VOCAB, seed=0)
T = plan_input(N, task, P).tasks_per_proc
out = {{}}
for s in {skews}:
    reps = zipf_skew_repeats(P, T, s, mean_rep={mean_rep}, seed=1)
    row = {{}}
    base = None
    for label, backend, stealing in (("2s", "2s", False),
                                     ("1s", "1s", False),
                                     ("1s+steal", "1s", True)):
        cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                        task_size=task, push_cap=CAP, n_procs=P,
                        stealing=stealing)
        submit(cfg, tokens, repeats=reps).result()    # compile + warm
        walls = []
        for _ in range({reps_n}):
            res = submit(cfg, tokens, repeats=reps).result()
            walls.append(res.wall_time)
        if base is None:
            base = res.records
        # recorded, not asserted: the artifact carries the real outcome
        # so the bench-guard's oracle_exact gate is a live check
        row[label] = dict(wall_s=min(walls),
                          imbalance=float(res.imbalance),
                          n_steals=res.n_steals,
                          records_equal=bool(res.records == base))
    out[str(s)] = row
print(json.dumps(out))
"""


def model_rows(costs: Costs, P: int, T: int, skews) -> list[dict]:
    from repro.data.corpus import zipf_skew_repeats
    rows = []
    for s in skews:
        reps = zipf_skew_repeats(P, T, s, mean_rep=MEAN_REP, seed=1)
        t2 = float(simulate(costs, reps, "2s"))
        t1 = float(simulate(costs, reps, "1s"))
        ts = float(simulate(costs, reps, "1s+steal"))
        rows.append({
            "s": s, "P": P, "T": T,
            "t_2s": t2, "t_1s": t1, "t_steal": ts,
            "win_1s_vs_2s_pct": 100 * (1 - t1 / t2),
            "win_steal_vs_2s_pct": 100 * (1 - ts / t2),
            "win_steal_vs_1s_pct": 100 * (1 - ts / t1),
        })
    return rows


def measure_real(skews, n_procs: int, n_tokens: int, reps_n: int) -> dict:
    out = run_py(REAL_CODE.format(n_procs=n_procs, n_tokens=n_tokens,
                                  skews=list(skews), mean_rep=MEAN_REP,
                                  reps_n=reps_n, task_size=TASK_SIZE,
                                  push_cap=PUSH_CAP),
                 n_devices=n_procs)
    return json.loads(out.strip().splitlines()[-1])


def run(quick: bool = False, smoke: bool = False) -> dict:
    if smoke:
        skews = [SKEWS[0], SKEWS[-1]]
        model_p, model_t = 8, 8
        real_p, real_n, reps_n = 2, 131_072, 1
    elif quick:
        skews = SKEWS
        model_p, model_t = 32, 32
        real_p, real_n, reps_n = 4, 500_000, 2
    else:
        skews = SKEWS
        model_p, model_t = 64, 64
        real_p, real_n, reps_n = 8, 2_000_000, 3

    print("[fig9] calibrating per-op costs...")
    calib = calibrate(task_size=TASK_SIZE, push_cap=PUSH_CAP)
    # the steal path's fetch a2a moves (task_size+2) int32 per peer —
    # scale the calibrated per-chunk transfer (push_cap int32 pairs)
    fetch = calib["t_a2a_lat"] + calib["t_a2a_byte"] * (
        (TASK_SIZE + 2) * 4) / (PUSH_CAP * 8)
    costs = dataclasses.replace(Costs.from_calibration(calib),
                                t_fetch=fetch)
    rows = model_rows(costs, model_p, model_t, skews)
    for r in rows:
        print(f"[fig9] model s={r['s']:<4} 2s={r['t_2s']:.3f}s "
              f"1s={r['t_1s']:.3f}s steal={r['t_steal']:.3f}s "
              f"(steal vs 2s {r['win_steal_vs_2s_pct']:+.1f}%)")

    print(f"[fig9] real runs (P={real_p}, N={real_n})...")
    real = measure_real(skews, real_p, real_n, reps_n)
    overhead = [100.0 * (v["1s+steal"]["wall_s"] / v["1s"]["wall_s"] - 1)
                for v in real.values()]
    exact = all(b["records_equal"] for v in real.values()
                for b in v.values())
    top = rows[-1]
    rec = {
        "skews": list(skews), "mean_rep": MEAN_REP,
        "model": {"P": model_p, "T": model_t, "rows": rows},
        "real": {"P": real_p, "n_tokens": real_n, "per_skew": real},
        "calibration": calib,
        "steal_overhead_pct_worst": max(overhead),
        "criteria": {
            # the acceptance gate: at the highest skew the stealing
            # schedule must beat the bulk-synchronous baseline...
            "steal_beats_2s_at_max_skew": bool(top["t_steal"]
                                               < top["t_2s"]),
            "win_at_max_skew_pct": top["win_steal_vs_2s_pct"],
            # ...while every real run stayed record-identical across
            # all three schedules (measured, not assumed — a divergence
            # still lands in the artifact for bench-guard to flag)
            "oracle_exact": exact,
        },
    }
    path = save_json("fig9_imbalance.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        # — a CI-scale smoke run must never clobber it
        root = os.path.join(REPO, "BENCH_imbalance.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    print(f"[fig9] steal vs 2s at s={top['s']}: "
          f"{top['win_steal_vs_2s_pct']:+.1f}% "
          f"(worst real steal overhead {max(overhead):+.1f}%)")
    print("wrote " + " and ".join(wrote))
    if not exact:
        raise RuntimeError("schedules diverged — see real.per_skew "
                           "records_equal flags in the artifact")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller model grid / fewer tokens")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, still writes both artifacts")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
