"""§Roofline — three-term roofline per (arch × shape) from the dry-run.

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 peak, v5e)
    memory     = HLO_bytes_per_device / 819e9         (HBM bw)
    collective = wire_bytes_per_device / 50e9         (1 ICI link)

FLOPs/bytes come from the calibrated (unrolled, differenced, extrapolated)
lowerings — DESIGN.md §9; collective wire bytes from the partitioned HLO
(ring factors applied in hlo_stats). MODEL_FLOPS = 6·N_active·D tokens
(+ attention term) per device.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS, save_json

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256                        # single-pod roofline (the table's mesh)


def model_flops_per_device(arch: str, shape_name: str) -> float:
    """6·N_active·D (+ attention term) per device, per step.

    The attention term uses the *visible* KV extent (SWA window; causal
    half for full attention) and counts only layers that actually carry
    attention (hybrid stacks)."""
    from repro.config import SHAPES
    from repro.configs.registry import get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    N = cfg.active_param_count()
    S = shape.seq_len
    n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.n_layers)) \
        if cfg.n_heads else 0
    hd_eff = cfg.n_heads * cfg.d_head          # q·kᵀ + p·v width
    if cfg.attn_type == "swa" and cfg.sliding_window:
        kv_extent = min(S, cfg.sliding_window)
        attn_tok = S * kv_extent               # banded
    else:
        kv_extent = S
        attn_tok = S * S / 2                   # causal half
    if shape.kind == "train":
        tokens = shape.global_batch * S
        flops = 6.0 * N * tokens
        if n_attn:
            flops += 12.0 * attn_tok * hd_eff * n_attn * shape.global_batch
    elif shape.kind == "prefill":
        tokens = shape.global_batch * S
        flops = 2.0 * N * tokens
        if n_attn:
            flops += 4.0 * attn_tok * hd_eff * n_attn * shape.global_batch
    else:                           # decode: one token per sequence
        tokens = shape.global_batch
        flops = 2.0 * N * tokens
        if n_attn:
            flops += (4.0 * kv_extent * hd_eff * n_attn
                      * shape.global_batch)
    return flops / CHIPS


def load_cells(dryrun_dir: str, mesh: str = "singlepod") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def roofline_row(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec.get("status"),
                "reason": rec.get("reason", rec.get("error", ""))[:200]}
    cal = rec.get("calibration")
    if cal:
        flops = cal["flops_per_device"]
        hbm = cal["hbm_bytes_per_device"]
        coll = cal["collective_bytes_per_device"].get("total", 0.0)
        coll_detail = {k: v for k, v in
                       cal["collective_bytes_per_device"].items()
                       if not k.startswith("n_")
                       and not k.endswith("_result_bytes")}
    else:
        ca = rec["full"]["cost_analysis"]
        flops = ca.get("flops", 0.0)
        hbm = ca.get("bytes accessed", 0.0)
        coll = rec["full"]["collectives"].get("total", 0.0)
        coll_detail = {}
    t_comp = flops / PEAK_FLOPS
    t_mem = hbm / HBM_BW
    t_coll = coll / LINK_BW
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops_per_device(rec["arch"], rec["shape"])
    mem_bytes = (rec["full"].get("memory_analysis") or {})
    return {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (max(t_comp, 1e-30)
                              / max(t_comp, t_mem, t_coll)),
        "collective_detail": coll_detail,
        "hlo_flops_per_dev": flops, "hbm_bytes_per_dev": hbm,
        "coll_bytes_per_dev": coll,
        "peak_dev_bytes": mem_bytes.get("peak_memory_in_bytes"),
        "temp_dev_bytes": mem_bytes.get("temp_size_in_bytes"),
        "arg_dev_bytes": mem_bytes.get("argument_size_in_bytes"),
    }


def fmt_s(x: float) -> str:
    return f"{x*1e3:8.2f}ms" if x < 10 else f"{x:8.2f}s "


def run(dryrun_dir: str = None, quick: bool = False) -> dict:
    dryrun_dir = dryrun_dir or os.path.join(RESULTS, "dryrun")
    rows = [roofline_row(c) for c in load_cells(dryrun_dir)]
    rows = [r for r in rows if r]
    ok = [r for r in rows if r.get("status") == "ok"]
    print(f"[roofline] {len(ok)} cells (singlepod) | "
          f"{len(rows) - len(ok)} skipped/failed")
    hdr = (f"{'arch':<28}{'shape':<13}{'compute':>11}{'memory':>11}"
           f"{'collective':>11}  {'dominant':<11}{'useful':>7}{'roofl%':>7}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(ok, key=lambda r: (r['arch'], r['shape'])):
        print(f"{r['arch']:<28}{r['shape']:<13}"
              f"{fmt_s(r['compute_s'])}{fmt_s(r['memory_s'])}"
              f"{fmt_s(r['collective_s'])}  {r['dominant']:<11}"
              f"{r['useful_flops_ratio']:>7.2f}"
              f"{100*r['roofline_fraction']:>6.1f}%")
    rec = {"constants": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                         "link_bw": LINK_BW, "chips": CHIPS},
           "rows": rows}
    save_json("roofline.json", rec)
    return rec


if __name__ == "__main__":
    run()
