"""Beyond-paper: the decoupled (1s) vs bulk (2s) MoE dispatch, measured.

The paper's technique as an in-model feature: same routing, same bytes,
different schedule. On 8 host devices we measure real wall time of the
MoE layer under (a) balanced routing and (b) a skewed router (hot
experts — the structural imbalance the paper targets), plus the lowered
per-op collective schedule (chunked vs bulk) for the record.
"""
from __future__ import annotations

import json

from benchmarks.common import run_py, save_json

CODE = """
import dataclasses, json, time
import numpy as np, jax, jax.numpy as jnp
from repro.configs.registry import get_smoke_config
from repro.distributed.mesh import local_mesh
from repro.models import moe as moe_mod

base = get_smoke_config("llama4-maverick-400b-a17b")
mesh = local_mesh((2, 4), ("data", "model"))
B, S = 4, 512

def bench(mode, skew):
    cfg = dataclasses.replace(base, dispatch_mode=mode, top_k=2,
                              dispatch_groups=4, n_experts=8,
                              capacity_factor=1.25)
    p = moe_mod.init_moe(cfg, jax.random.key(0))
    if skew:
        # bias the router toward 2 hot experts (structural imbalance)
        r = np.array(p["router"], np.float32, copy=True)
        r[:, :2] += 2.0
        p = dict(p, router=jnp.asarray(r))
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                          jnp.bfloat16)
    fn = jax.jit(lambda xx: moe_mod.moe_forward(cfg, p, xx, mesh=mesh,
                                                dp_entry="data")[0])
    jax.block_until_ready(fn(x))
    t0 = time.perf_counter()
    for _ in range(20):
        out = fn(x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 20

out = {}
for skew in (False, True):
    t2 = bench("2s", skew)
    t1 = bench("1s", skew)
    out["skewed" if skew else "balanced"] = dict(
        t_2s=t2, t_1s=t1, improvement_pct=100 * (1 - t1 / t2))
print(json.dumps(out))
"""


def run(quick: bool = False) -> dict:
    out = run_py(CODE, n_devices=8)
    rec = json.loads(out.strip().splitlines()[-1])
    for k, v in rec.items():
        print(f"[moe-dispatch] {k}: 2s={v['t_2s']*1e3:.1f}ms "
              f"1s={v['t_1s']*1e3:.1f}ms ({v['improvement_pct']:+.1f}%)")
    save_json("moe_dispatch.json", rec)
    return rec


if __name__ == "__main__":
    run()
