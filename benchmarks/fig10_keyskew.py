"""Fig 10 — key-distribution skew: static hash vs skew-aware partitioners.

PR 3 (fig9) attacked *rank* imbalance with work stealing; this benchmark
attacks the reduce-side twin: a Zipf-skewed **key** distribution — what
WordCount on natural text produces — under the paper's static
``hash(key) % P`` ownership rule floods a few owners' windows, overflows
their push buckets (ownership transfers) and piles work onto the Combine
tree. Fan et al. (arXiv:1401.0355) balance the *observed* key
distribution instead; ``core/partition.py`` implements that as:

  * ``hash``          — the paper's modulo rule (baseline);
  * ``sampled``       — greedy LPT owner map from a sampled key
                        histogram (planner pre-pass);
  * ``sampled+split`` — additionally spreads hot keys over several
                        owners (mappers pick a replica by task id;
                        Combine's dup-sum keeps results exact).

Methodology mirrors fig9: **real runs** on host devices validate
exactness — every partitioner × {1s, 1s+steal} × skew must produce
records identical to the hash baseline (and the oracle) — and measure
the pre-pass overhead, while the **deterministic placement model**
replays the engines' exact bucketing rule over a synthetic corpus at
paper scale: per task, each key the task contains is one record routed
to ``owner(key, task)``; per-owner received-record totals give the
reduce-side load, calibrated per-record fold/merge costs turn them into
a modeled reduce+combine makespan, and per-(task, owner) counts over
``push_cap`` give the ownership-transfer volume.

Artifacts: ``results/fig10_keyskew.json`` + repo-root
``BENCH_keyskew.json``.

    PYTHONPATH=src python benchmarks/fig10_keyskew.py [--quick|--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

try:
    from benchmarks.common import REPO, calibrate, run_py, save_json
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO, calibrate, run_py, save_json

SKEWS = [1.1, 1.4, 1.8, 2.2]             # ZipfSource exponent (a > 1)
VOCAB = 65536
TASK_SIZE = 4096                         # shared with calibration
PUSH_CAP = 1024
SENT = np.int32(np.iinfo(np.int32).max)

REAL_CODE = """
import json
import numpy as np
from repro.core import JobConfig, submit
from repro.core.usecases import WordCount, wordcount_oracle
from repro.data.source import ZipfSource, read_all

P, N, VOCAB, TASK, CAP = {n_procs}, {n_tokens}, {vocab}, {task_size}, {push_cap}
COMBOS = [("1s", False), ("1s+steal", True)]
PARTS = ["hash", "sampled", "sampled+split"]
out = {{}}
for a in {skews}:
    src = ZipfSource(N, vocab=VOCAB, a=a, seed=2)
    oracle = wordcount_oracle(read_all(src), VOCAB)
    row = {{}}
    base = None
    for engine, stealing in COMBOS:
        for part in PARTS:
            cfg = JobConfig(usecase=WordCount(vocab=VOCAB), backend="1s",
                            task_size=TASK, push_cap=CAP, n_procs=P,
                            stealing=stealing, partitioner=part)
            submit(cfg, src).result()                 # compile + warm
            walls = []
            for _ in range({reps_n}):
                res = submit(cfg, src).result()
                walls.append(res.wall_time)
            if base is None:
                base = res.records
            # recorded, not asserted: the artifact carries the real
            # outcome so bench-guard's oracle_exact gate is a live check
            row[engine + "|" + part] = dict(
                wall_s=min(walls),
                n_split_keys=res.n_split_keys,
                records_equal=bool(res.records == base),
                oracle_equal=bool(res.records == oracle))
    out[str(a)] = row
print(json.dumps(out))
"""


def _mix32_np(x: np.ndarray) -> np.ndarray:
    """kv.mix32 in numpy (uint64 lanes, masked to 32 bits) — the host
    replay of the device owner pick for split keys."""
    m = np.uint64(0xFFFFFFFF)
    x = x.astype(np.uint64) & m
    x ^= x >> np.uint64(16)
    x = (x * np.uint64(0x85EBCA6B)) & m
    x ^= x >> np.uint64(13)
    x = (x * np.uint64(0xC2B2AE35)) & m
    x ^= x >> np.uint64(16)
    return x


def _check_mix():
    import jax.numpy as jnp
    from repro.core.kv import mix32
    xs = np.arange(1024, dtype=np.uint32)
    ref = np.asarray(mix32(jnp.asarray(xs))).astype(np.uint64)
    got = _mix32_np(xs)
    assert (ref == got).all(), "host mix32 diverged from kv.mix32"


def _task_uniques(source, n_tasks: int, task_size: int) -> list[np.ndarray]:
    out = []
    for t in range(n_tasks):
        chunk = source.read(t * task_size, task_size)
        out.append(np.unique(chunk[chunk != SENT]))
    return out


def placement_stats(uniques: list[np.ndarray], omap: np.ndarray,
                    osplit: np.ndarray, n_procs: int,
                    push_cap: int) -> dict:
    """Replay the engines' routing rule (bucketize + lookup_owner) over
    one corpus: per-owner received records and per-(task, owner) counts
    past ``push_cap`` (= ownership transfers kept local)."""
    recv = np.zeros((n_procs,), np.int64)
    transfers = 0
    for tid, keys in enumerate(uniques):
        k = np.maximum(osplit[keys], 1)
        pick = (_mix32_np(np.full(keys.shape, tid, np.uint32))
                % k.astype(np.uint64)).astype(np.int64)
        owners = (omap[keys].astype(np.int64)
                  + np.where(k > 1, pick, 0)) % n_procs
        counts = np.bincount(owners, minlength=n_procs)
        recv += counts
        transfers += int(np.maximum(counts - push_cap, 0).sum())
    mean = recv.mean() if recv.mean() else 1.0
    return dict(recv_per_owner_max=int(recv.max()),
                recv_total=int(recv.sum()),
                owner_imbalance=float(recv.max() / mean),
                transfers=transfers)


def model_rows(calib: dict, P: int, tasks_per_rank: int, task_size: int,
               model_push_cap: int, sample_tasks: int, skews) -> list[dict]:
    from repro.core.partition import (HashPartitioner, SampledPartitioner,
                                      sample_key_histogram)
    from repro.core.planner import plan_input, read_tasks
    from repro.core.usecases import WordCount
    from repro.data.source import ZipfSource

    # calibrated per-record costs: a (P, cap) chunk fold / a W-wide merge
    t_rec = calib["t_fold"] / (8 * PUSH_CAP)
    t_xfer = calib["t_merge"] / VOCAB
    t_map = tasks_per_rank * calib["t_task1"]
    n_tasks = P * tasks_per_rank
    uc = WordCount(vocab=VOCAB)
    parts = {"hash": HashPartitioner(),
             "sampled": SampledPartitioner(sample_tasks=sample_tasks),
             "sampled+split": SampledPartitioner(
                 sample_tasks=sample_tasks, split=True)}
    rows = []
    for a in skews:
        src = ZipfSource(n_tasks * task_size, vocab=VOCAB, a=a, seed=2)
        uniques = _task_uniques(src, n_tasks, task_size)
        plan = plan_input(n_tasks * task_size, task_size, P)
        hist = sample_key_histogram(
            lambda ids: read_tasks(src, plan, ids), plan, uc, sample_tasks)
        row: dict = {"a": a, "P": P, "n_tasks": n_tasks, "per_part": {}}
        for name, part in parts.items():
            omap, osplit = part.build(hist, P)
            st = placement_stats(uniques, omap, osplit, P, model_push_cap)
            # reduce-side critical path: the hottest owner's folds, plus
            # the transferred records the Combine tree must chew through
            st["t_reduce_s"] = (st["recv_per_owner_max"] * t_rec
                                + st["transfers"] * t_xfer)
            st["t_total_s"] = t_map + st["t_reduce_s"]
            st["n_split_keys"] = int((osplit > 1).sum())
            row["per_part"][name] = st
        h = row["per_part"]["hash"]
        for name in ("sampled", "sampled+split"):
            p = row["per_part"][name]
            p["win_reduce_vs_hash_pct"] = 100 * (
                1 - p["t_reduce_s"] / h["t_reduce_s"]) \
                if h["t_reduce_s"] else 0.0
            p["win_total_vs_hash_pct"] = 100 * (
                1 - p["t_total_s"] / h["t_total_s"])
        rows.append(row)
    return rows


def measure_real(skews, n_procs: int, n_tokens: int, reps_n: int) -> dict:
    out = run_py(REAL_CODE.format(n_procs=n_procs, n_tokens=n_tokens,
                                  vocab=VOCAB, task_size=TASK_SIZE,
                                  push_cap=PUSH_CAP, skews=list(skews),
                                  reps_n=reps_n),
                 n_devices=n_procs)
    return json.loads(out.strip().splitlines()[-1])


def run(quick: bool = False, smoke: bool = False) -> dict:
    _check_mix()
    if smoke:
        # the model pass is host numpy (cheap) — smoke keeps the quick
        # grid so its headline win stays comparable to the committed
        # baseline; only the real-run scale shrinks
        skews = [SKEWS[0], SKEWS[-1]]
        model_p, model_t, model_task, sample = 32, 16, 1024, 16
        real_p, real_n, reps_n = 2, 262_144, 2
    elif quick:
        skews = SKEWS
        model_p, model_t, model_task, sample = 32, 16, 1024, 16
        real_p, real_n, reps_n = 4, 262_144, 2
    else:
        skews = SKEWS
        model_p, model_t, model_task, sample = 64, 32, 1024, 32
        real_p, real_n, reps_n = 8, 1_000_000, 3

    print("[fig10] calibrating per-op costs...")
    calib = calibrate(task_size=TASK_SIZE, push_cap=PUSH_CAP)
    # model push_cap scaled to the model task size so hot owners actually
    # overflow (the full-size cap would hide the transfer mechanism at
    # model scale)
    model_cap = max(model_task // 256, 4)
    rows = model_rows(calib, model_p, model_t, model_task, model_cap,
                      sample, skews)
    for r in rows:
        h, s, sp = (r["per_part"][k] for k in
                    ("hash", "sampled", "sampled+split"))
        print(f"[fig10] model a={r['a']:<4} imbalance "
              f"hash={h['owner_imbalance']:.2f} "
              f"sampled={s['owner_imbalance']:.2f} "
              f"split={sp['owner_imbalance']:.2f}  "
              f"(split vs hash reduce "
              f"{sp['win_reduce_vs_hash_pct']:+.1f}%, "
              f"{sp['n_split_keys']} keys split)")

    print(f"[fig10] real runs (P={real_p}, N={real_n})...")
    real = measure_real(skews, real_p, real_n, reps_n)
    exact = all(b["records_equal"] and b["oracle_equal"]
                for v in real.values() for b in v.values())
    # pre-pass + non-hash placement overhead on real wall time (1s engine)
    overhead = [100.0 * (v["1s|" + p]["wall_s"] / v["1s|hash"]["wall_s"] - 1)
                for v in real.values() for p in ("sampled", "sampled+split")]
    top = rows[-1]["per_part"]
    rec = {
        "skews": list(skews), "vocab": VOCAB,
        "model": {"P": model_p, "tasks_per_rank": model_t,
                  "task_size": model_task, "push_cap": model_cap,
                  "sample_tasks": sample, "rows": rows},
        "real": {"P": real_p, "n_tokens": real_n, "per_skew": real},
        "calibration": calib,
        "partitioner_overhead_pct_worst": max(overhead),
        "criteria": {
            # the acceptance gates: at the highest key skew the sampled
            # map must beat static hash on the modeled reduce path, and
            # splitting must beat plain sampling...
            "sampled_beats_hash_at_max_skew": bool(
                top["sampled"]["t_reduce_s"] < top["hash"]["t_reduce_s"]),
            "split_beats_hash_at_max_skew": bool(
                top["sampled+split"]["t_reduce_s"]
                < top["hash"]["t_reduce_s"]),
            "win_split_vs_hash_reduce_pct": top["sampled+split"][
                "win_reduce_vs_hash_pct"],
            "hash_owner_imbalance_at_max_skew": top["hash"][
                "owner_imbalance"],
            "split_owner_imbalance_at_max_skew": top["sampled+split"][
                "owner_imbalance"],
            # ...while every real run stayed record-identical to the
            # hash baseline AND the numpy oracle (measured, not assumed)
            "oracle_exact": exact,
        },
    }
    path = save_json("fig10_keyskew.json", rec)
    wrote = [path]
    if not smoke:
        # only full/quick runs refresh the committed trajectory baseline
        # — a CI-scale smoke run must never clobber it (same rule as fig9)
        root = os.path.join(REPO, "BENCH_keyskew.json")
        with open(root, "w") as f:
            json.dump(rec, f, indent=1)
        wrote.append(root)
    c = rec["criteria"]
    print(f"[fig10] split vs hash at a={rows[-1]['a']}: "
          f"{c['win_split_vs_hash_reduce_pct']:+.1f}% modeled reduce win "
          f"(owner imbalance {c['hash_owner_imbalance_at_max_skew']:.2f} "
          f"-> {c['split_owner_imbalance_at_max_skew']:.2f}; worst real "
          f"overhead {max(overhead):+.1f}%)")
    print("wrote " + " and ".join(wrote))
    if not exact:
        raise RuntimeError("partitioners diverged — see real.per_skew "
                           "records_equal/oracle_equal flags")
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="smaller model grid / fewer tokens")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny run, still writes results/*.json")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
