"""Fig 6 — peak memory per node, MR-2S vs MR-1S.

Paper: both implementations peak 10.4–13.7 GB/node at 1 GB/proc input, the
peak occurring during Combine; MR-2S carries the additional full-map-output
send buffer.

Here both axes are measured exactly from the engines' device allocations:
  * analytic: every persistent buffer each engine holds, from its shapes
    (the engines are scan programs — their live set is the carry + per-task
    temporaries, so this is exact up to XLA temporaries);
  * measured: jax.live_arrays() peak sampled around the run on 8 devices.
"""
from __future__ import annotations

import json

from benchmarks.common import run_py, save_json


def analytic_bytes(n_tokens_per_proc: int, vocab: int, task: int,
                   push_cap: int, n_procs: int) -> dict[str, float]:
    """Per-process persistent device bytes, from the engine definitions."""
    T = max(1, n_tokens_per_proc // task)
    rec4 = 4                                   # int32
    chunk = n_procs * push_cap * 2 * rec4      # (P, cap) keys+vals
    window = vocab * rec4                      # dense KV window
    combine = 2 * vocab * rec4                 # sorted records (k, v)
    input_tasks = T * task * rec4              # resident task grid
    common = window + combine + input_tasks
    # MR-1S: double-buffered in-flight chunk (pending + current)
    mr1s = common + 2 * chunk
    # MR-2S: buffers EVERY task's buckets until the bulk shuffle
    mr2s = common + T * chunk + chunk
    return {"T": T, "mr1s": mr1s, "mr2s": mr2s,
            "mr2s_over_mr1s": mr2s / mr1s}


MEASURE_CODE = """
import json
from functools import partial
import numpy as np, jax
from jax.sharding import PartitionSpec as P
from repro.core import JobConfig, planner, submit
from repro.core import onesided, twosided
from repro.core.usecases import WordCount
from repro.data.corpus import synth_corpus
from repro.distributed.collectives import shard_map

NP, task, VOCAB, CAP = 8, 4096, 65536, 1024
N = {n_tokens}
tokens = synth_corpus(N, VOCAB, seed=0)

out = {{}}
for backend, mod in (("1s", onesided), ("2s", twosided)):
    h = submit(JobConfig(usecase=WordCount(vocab=VOCAB), backend=backend,
                         task_size=task, push_cap=CAP, n_procs=NP), tokens)
    # lowering-only: materialize the full resident grid the blocking path
    # would use (the streamed path never holds this on the host)
    grid = planner.shard_tasks(tokens, h.plan)
    fn = jax.jit(shard_map(
        partial(mod._engine, h.spec, h._map_fn), mesh=h.mesh,
        in_specs=(P("procs"), P("procs"), P("procs")),
        out_specs=(P("procs"), P("procs"))))
    compiled = fn.lower(grid, h._task_ids, h._repeats).compile()
    ma = compiled.memory_analysis()
    peak = getattr(ma, "peak_memory_in_bytes", None)
    if peak is None:      # jax 0.4.x: approximate peak from components
        peak = (ma.temp_size_in_bytes + ma.argument_size_in_bytes +
                ma.output_size_in_bytes)
    out[backend] = dict(
        peak=float(peak),
        temp=float(ma.temp_size_in_bytes),
        args=float(ma.argument_size_in_bytes))
out["ratio_peak_2s_over_1s"] = out["2s"]["peak"] / out["1s"]["peak"]
print(json.dumps(out))
"""


def run(quick: bool = False) -> dict:
    rec: dict = {"analytic": {}, "paper": "similar 10.4-13.7GB/node, "
                 "peak during Combine; 2S adds full map-output buffering"}
    # paper scale: 1 GB/proc (64 MB tasks), and this container's scale
    for label, toks_pp, vocab, task, cap, P in (
            ("paper_scale_1GBpp", 256 * 2 ** 20, 1 << 22, 16 * 2 ** 20,
             1 << 16, 256),
            ("container_scale", 250_000, 65536, 4096, 1024, 8)):
        a = analytic_bytes(toks_pp, vocab, task, cap, P)
        rec["analytic"][label] = a
        print(f"[fig6] {label}: MR-1S {a['mr1s']/2**20:.1f} MiB/proc, "
              f"MR-2S {a['mr2s']/2**20:.1f} MiB/proc "
              f"(x{a['mr2s_over_mr1s']:.2f}, T={a['T']})")
    n = 500_000 if quick else 2_000_000
    out = run_py(MEASURE_CODE.format(n_tokens=n), n_devices=8)
    rec["measured"] = json.loads(out.strip().splitlines()[-1])
    save_json("fig6_memory.json", rec)
    return rec


if __name__ == "__main__":
    run()
