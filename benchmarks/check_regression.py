"""Bench guard — fail CI when a smoke artifact is malformed or regressed.

Compares a freshly produced benchmark artifact against the committed
repo-root ``BENCH_*.json`` trajectory file:

  * **schema**: every required key must be present (a benchmark that
    silently stopped emitting its headline number is a regression even
    if it exits 0);
  * **tolerance**: the overhead-style metrics may not be worse than the
    committed baseline by more than a stated margin. Margins are wide —
    CI smoke runs are tiny and the runners are noisy — so only a real
    structural regression (streaming no longer overlapping, the steal
    machinery ballooning) trips them.

    python -m benchmarks.check_regression fig8 fig9
    python -m benchmarks.check_regression fig9 --results results
"""
from __future__ import annotations

import argparse
import json
import os
import sys

try:
    from benchmarks.common import REPO
except ImportError:                      # invoked as a script from benchmarks/
    from common import REPO

# per-benchmark contract: fresh artifact name, committed baseline name,
# required keys (dotted paths), (metric, direction, tolerance) gates, and
# absolute (metric, floor) floors.
# Directions: "min" -> fresh may not drop more than `tol` below baseline;
# "max" -> fresh may not rise more than `tol` above baseline. Floors are
# baseline-independent: the fresh value must be >= the stated minimum
# (for scale-free metrics like a fairness index, where "worse than the
# baseline by N" is the wrong question).
CHECKS: dict[str, dict] = {
    "fig8": {
        "fresh": "fig8_io_overlap.json",
        "baseline": "BENCH_io_overlap.json",
        "required": ["per_task_size", "worst_overlap_win_pct",
                     "streamed_within_10pct"],
        "gates": [
            # streamed may regress vs resident by at most 25 percentage
            # points relative to the committed trajectory
            ("worst_overlap_win_pct", "min", 25.0),
        ],
    },
    "fig9": {
        "fresh": "fig9_imbalance.json",
        "baseline": "BENCH_imbalance.json",
        "required": ["model.rows", "real.per_skew",
                     "steal_overhead_pct_worst",
                     "criteria.steal_beats_2s_at_max_skew",
                     "criteria.oracle_exact"],
        "gates": [
            # the steal machinery's real-run overhead over plain 1s may
            # not balloon past baseline + 30 percentage points
            ("steal_overhead_pct_worst", "max", 30.0),
        ],
        "require_true": ["criteria.steal_beats_2s_at_max_skew",
                         "criteria.oracle_exact"],
    },
    "fig10": {
        "fresh": "fig10_keyskew.json",
        "baseline": "BENCH_keyskew.json",
        "required": ["model.rows", "real.per_skew",
                     "partitioner_overhead_pct_worst",
                     "criteria.sampled_beats_hash_at_max_skew",
                     "criteria.split_beats_hash_at_max_skew",
                     "criteria.win_split_vs_hash_reduce_pct",
                     "criteria.oracle_exact"],
        "gates": [
            # the modeled reduce-path win of the splitting partitioner
            # over static hash may shrink vs the committed trajectory by
            # at most 40 percentage points (smoke runs model a far
            # smaller grid, so the margin is wide on purpose)
            ("criteria.win_split_vs_hash_reduce_pct", "min", 40.0),
            # pre-pass + placement overhead on real runs must not balloon
            # structurally (e.g. the pre-pass re-reading the dataset);
            # smoke engine runs are ~0.1 s on a noisy shared core, so
            # only a blowup past ~100 points over baseline is signal
            ("partitioner_overhead_pct_worst", "max", 100.0),
        ],
        "require_true": ["criteria.sampled_beats_hash_at_max_skew",
                         "criteria.split_beats_hash_at_max_skew",
                         "criteria.oracle_exact"],
    },
    "fig11": {
        "fresh": "fig11_multitenant.json",
        "baseline": "BENCH_multitenant.json",
        "required": ["per_k", "criteria.max_K",
                     "criteria.fairshare_p95_win_pct",
                     "criteria.fair_vs_fifo_makespan_pct",
                     "criteria.jain_fair",
                     "criteria.all_jobs_exact"],
        "gates": [
            # fair share's p95-latency win over FIFO may shrink vs the
            # committed trajectory by at most 35 percentage points (the
            # smoke fleet is much smaller — K=8 vs 16 — so its win is
            # structurally lower; only a collapse to ~FIFO is signal)
            ("criteria.fairshare_p95_win_pct", "min", 35.0),
            # segment-granular slicing must stay ~free: the fair fleet's
            # makespan may not balloon past FIFO's by 25 points more
            # than the committed baseline shows
            ("criteria.fair_vs_fifo_makespan_pct", "max", 25.0),
        ],
        "floors": [
            # absolute fairness floor — Jain index of per-job normalized
            # service under fair share (FIFO sits near 1/K; a fair
            # scheduler that drops under 0.3 is broken regardless of
            # what the baseline says)
            ("criteria.jain_fair", 0.30),
        ],
        "require_true": ["criteria.all_jobs_exact",
                         "criteria.fair_jain_beats_fifo",
                         "criteria.priority_favors_high"],
    },
    "fig12": {
        "fresh": "fig12_roofline.json",
        "baseline": "BENCH_roofline.json",
        "required": ["triad_gbps", "model.rows", "real.per_vocab",
                     "criteria.fused_model_beats_unfused_measured_at_max",
                     "criteria.fused_bytes_win_pct_at_max",
                     "criteria.achieved_bw_frac_fused_at_max",
                     "criteria.records_equal",
                     "criteria.oracle_exact"],
        "gates": [
            # the fused path's bytes-moved win over unfused is structural
            # (one window pass instead of two); it may shrink vs the
            # committed trajectory by at most 15 percentage points (the
            # smoke grid tops out at a smaller window, where the
            # record-domain terms weigh more)
            ("criteria.fused_bytes_win_pct_at_max", "min", 15.0),
        ],
        "floors": [
            # the fused kernel must actually move its modeled bytes at a
            # sane fraction of the measured triad bandwidth — interpret
            # mode included, a kernel that falls under 2% is broken (or
            # the superlinear tiling regression came back) regardless of
            # what the baseline says
            ("criteria.achieved_bw_frac_fused_at_max", 0.02),
        ],
        "require_true": [
            # the falsifiable headline: modeled fused step time beats the
            # MEASURED unfused step wall at the largest window
            "criteria.fused_model_beats_unfused_measured_at_max",
            # exactness on real engine runs — the kernel's whole contract
            "criteria.records_equal",
            "criteria.oracle_exact",
        ],
    },
    "fig13": {
        "fresh": "fig13_elastic.json",
        "baseline": "BENCH_elastic.json",
        "required": ["P", "P_new", "K", "kill_tick",
                     "clean.wall_s", "recover.wall_s", "restart.wall_s",
                     "recover.recoveries",
                     "criteria.mttr_s",
                     "criteria.recovery_overhead_pct",
                     "criteria.restart_overhead_pct",
                     "criteria.recovery_win_vs_restart_pct",
                     "criteria.records_equal",
                     "criteria.all_jobs_elastic_restored",
                     "criteria.recovery_beats_restart"],
        "gates": [
            # surviving a mid-run kill (re-mesh + re-executed
            # since-last-snapshot suffix) may cost at most 75 points
            # more over the clean run than the committed trajectory
            # shows — the smoke fleet is tiny (P=2 -> 1, so the
            # survivors also have half the compute), so only a
            # structural blowup (fold recompiling per job, snapshots
            # re-read per tick) is signal
            ("criteria.recovery_overhead_pct", "max", 75.0),
        ],
        "require_true": [
            # exactness is the whole game: every job in every campaign
            # record-identical to its solo run, kills included
            "criteria.records_equal",
            # the kill was survived WITHOUT resubmission — every job
            # came back via elastic restore, none from scratch
            "criteria.all_jobs_elastic_restored",
            # and restoring beat the restart-from-scratch discipline
            "criteria.recovery_beats_restart",
        ],
    },
    "fig14": {
        "fresh": "fig14_crossjob.json",
        "baseline": "BENCH_crossjob.json",
        "required": ["model", "real.per_k", "criteria.max_K",
                     "criteria.cosched_makespan_win_pct",
                     "criteria.cosched_p95_win_pct",
                     "criteria.jain_fair", "criteria.jain_cosched",
                     "criteria.crossjob_steals_real",
                     "criteria.all_jobs_exact"],
        "gates": [
            # the co-scheduled fleet's modeled makespan win over fig11's
            # fair slicer is structural (K hot tails balanced in one
            # domain vs paid serially); it may shrink vs the committed
            # trajectory by at most 30 percentage points (the smoke
            # model runs at P=8 instead of P=64, where per-rank tails
            # average out more)
            ("criteria.cosched_makespan_win_pct", "min", 30.0),
        ],
        "floors": [
            # absolute fairness floor, as in fig11: the co-scheduled
            # fleet's Jain index over solo/latency must clear 0.30 —
            # a domain that starves its small members behind the giant
            # job's tail is broken regardless of the baseline
            ("criteria.jain_cosched", 0.30),
        ],
        "require_true": [
            # the headline: at the highest K the merged domain beats
            # the fair slicer on BOTH makespan and latency fairness
            "criteria.cosched_beats_fair_makespan",
            "criteria.cosched_beats_fair_jain",
            # exactness: every co-scheduled job reproduced its solo
            # records bit-for-bit, at every K, in both fleets
            "criteria.all_jobs_exact",
            # and the mechanism actually ran — real cross-rank steals
            # inside the merged domain, one domain per fleet
            "criteria.crossjob_stealing_active",
            "criteria.one_domain_per_fleet",
        ],
    },
    "fig15": {
        "fresh": "fig15_coded.json",
        "baseline": "BENCH_coded.json",
        "required": ["skews", "code_rates", "real.per_skew",
                     "bytes.per_step_blocks",
                     "criteria.shuffle_ratio_r2_at_max_skew",
                     "criteria.bytes_win_r2_pct",
                     "criteria.records_equal",
                     "criteria.oracle_exact"],
        "gates": [
            # the coded exchange's shuffle-bytes win over r=1 is
            # structural ((P/r)/(P-1) of the reference at fixed P=6);
            # it may shrink vs the committed trajectory by at most 10
            # percentage points before something is off with the
            # accounting or the exchange itself
            ("criteria.bytes_win_r2_pct", "min", 10.0),
        ],
        "floors": [
            # absolute floor: a silently-degenerate r=1 fallback (the
            # coded path quietly not engaging) scores a 0% win and must
            # fail regardless of what the baseline says
            ("criteria.bytes_win_r2_pct", 20.0),
        ],
        "require_true": [
            # the acceptance headline: r=2 shuffle bytes at most 0.65x
            # the r=1 reference at the largest skew point
            "criteria.r2_le_065_at_max_skew",
            # exactness on real runs, r in {2,3} and the stolen arm:
            # record-identical to r=1 and to the host oracle
            "criteria.records_equal",
            "criteria.oracle_exact",
        ],
    },
}


def dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def check(name: str, results_dir: str, baseline_dir: str) -> list[str]:
    spec = CHECKS[name]
    errors: list[str] = []
    fresh_path = os.path.join(results_dir, spec["fresh"])
    base_path = os.path.join(baseline_dir, spec["baseline"])
    if not os.path.isfile(fresh_path):
        return [f"{name}: fresh artifact {fresh_path} missing"]
    with open(fresh_path) as f:
        fresh = json.load(f)
    for key in spec["required"]:
        if dig(fresh, key) is None:
            errors.append(f"{name}: fresh artifact missing key {key!r}")
    for key in spec.get("require_true", []):
        if dig(fresh, key) is not True:
            errors.append(f"{name}: {key} is {dig(fresh, key)!r}, "
                          "expected true")
    for metric, floor in spec.get("floors", []):
        got = dig(fresh, metric)
        if got is None:
            errors.append(f"{name}: floor metric {metric!r} absent")
        elif got < floor:
            errors.append(f"{name}: {metric} below floor: "
                          f"{got:.2f} < {floor}")
    if not os.path.isfile(base_path):
        errors.append(f"{name}: committed baseline {base_path} missing")
        return errors
    with open(base_path) as f:
        base = json.load(f)
    for metric, direction, tol in spec["gates"]:
        got, ref = dig(fresh, metric), dig(base, metric)
        if got is None or ref is None:
            errors.append(f"{name}: gate metric {metric!r} absent "
                          f"(fresh={got!r}, baseline={ref!r})")
            continue
        if direction == "min" and got < ref - tol:
            errors.append(
                f"{name}: {metric} regressed: {got:.2f} < "
                f"baseline {ref:.2f} - tolerance {tol}")
        if direction == "max" and got > ref + tol:
            errors.append(
                f"{name}: {metric} regressed: {got:.2f} > "
                f"baseline {ref:.2f} + tolerance {tol}")
    return errors


def group_names(group: str) -> list[str]:
    """Expand a run.py registry group to the guarded benchmarks in it —
    the same single list ``--smoke-all`` sweeps, so CI's guard step needs
    no hand-maintained figure list either."""
    try:
        from benchmarks.run import REGISTRY
    except ImportError:                  # invoked as a script from benchmarks/
        from run import REGISTRY
    return [b.name for b in REGISTRY if b.name in CHECKS
            and (group == "all" or b.group == group)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("benchmarks", nargs="*", choices=sorted(CHECKS) + [[]],
                    help="which artifacts to guard (or use --group)")
    ap.add_argument("--group", default="",
                    choices=["", "bench", "chaos", "all"],
                    help="guard every registered benchmark in a run.py "
                         "group instead of naming them")
    ap.add_argument("--results", default=os.path.join(REPO, "results"),
                    help="directory holding the fresh artifacts")
    ap.add_argument("--baseline", default=REPO,
                    help="directory holding the committed BENCH_*.json "
                         "baselines (default: the repo root — smoke runs "
                         "never overwrite those)")
    args = ap.parse_args(argv)
    names = list(args.benchmarks) + (group_names(args.group)
                                     if args.group else [])
    if not names:
        ap.error("name benchmarks or pass --group")
    failures: list[str] = []
    for name in names:
        errs = check(name, args.results, args.baseline)
        for e in errs:
            print(f"FAIL {e}")
        if not errs:
            print(f"ok   {name}: schema + tolerances hold")
        failures.extend(errs)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
